"""Checkpoint store: atomic roundtrip, checksums, elastic restore, GC."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import latest_step, restore, save


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "b": jnp.arange(4.0)},
            "opt": {"m": jnp.zeros((8, 4)), "step": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    st = _state()
    save(tmp_path, 100, st, metadata={"data_step": 100})
    got, meta = restore(tmp_path, _state(seed=1))
    assert meta["data_step"] == 100
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.asarray(st["params"]["w"]))
    assert int(got["opt"]["step"]) == 7


def test_latest_and_gc(tmp_path):
    for s in (1, 2, 3, 4, 5):
        save(tmp_path, s, _state(s), keep_last=3)
    assert latest_step(tmp_path) == 5
    kept = sorted(p.name for p in Path(tmp_path).glob("step_*"))
    assert len(kept) == 3 and kept[0].endswith("00000003")


def test_atomicity_tmp_ignored(tmp_path):
    save(tmp_path, 1, _state())
    # a crashed writer leaves a .tmp dir: restore must ignore it
    (Path(tmp_path) / "step_00000002.tmp").mkdir()
    assert latest_step(tmp_path) == 1
    got, _ = restore(tmp_path, _state(9))
    assert got is not None


def test_checksum_detects_corruption(tmp_path):
    d = save(tmp_path, 3, _state())
    manifest = json.loads((d / "manifest.json").read_text())
    fn = manifest["leaves"]["params/w"]["file"]
    arr = np.load(d / fn)
    arr[0, 0] += 1.0
    np.save(d / fn, arr)
    with pytest.raises(IOError, match="checksum"):
        restore(tmp_path, _state(1))


def test_elastic_restore_with_sharding(tmp_path):
    """Restore re-shards onto a (trivial) mesh — the elastic path."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_mesh

    st = _state()
    save(tmp_path, 1, st)
    mesh = make_mesh((1, 1), ("data", "model"))
    shardings = jax.tree.map(
        lambda _: NamedSharding(mesh, P()), _state(1))
    got, _ = restore(tmp_path, _state(1), shardings=shardings)
    assert got["params"]["w"].sharding == NamedSharding(mesh, P())


def test_missing_leaf_rejected(tmp_path):
    save(tmp_path, 1, {"a": jnp.ones(3)})
    with pytest.raises(KeyError):
        restore(tmp_path, {"b": jnp.ones(3)})
