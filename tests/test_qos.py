"""Interference-class QoS plane: blame attribution, violation
prediction, audit joins, calibration hooks, and the arbiter debit."""
import pytest

from repro.core import paper_system
from repro.obs import (BlameLedger, CostModelCalibrator, MetricsRegistry,
                       PredictionLedger, QOS_VIOLATION_MODEL,
                       QOS_VIOLATION_TOLERANCE, SLOMonitor, SLOTarget,
                       TraceRecorder, ViolationPredictor, qos_chains)
from repro.pool import TenantDemand, TierBudgetArbiter
from repro.topology import Flow, TopologyGraph


def _shared_link_graph(bw=10.0, kind="upi"):
    """Two nodes, one contended link: FAST at a, SLOW at b."""
    g = TopologyGraph("t", origin="a")
    g.add_node("a", "socket", tier="FAST")
    g.add_node("b", "socket", tier="SLOW")
    g.add_link("a", "b", 100.0, bw, kind)
    return g


def _victim(offered=4.0):
    return Flow("b", "a", offered, cls="read", tenant="victim")


def _neighbor(offered=5.0, cls="write", tenant="noisy"):
    return Flow("b", "a", offered, cls=cls, tenant=tenant)


# ===================================================================== #
# BlameLedger: violation -> bottleneck link -> antagonist                #
# ===================================================================== #
def test_blame_names_antagonist_link_and_pressure():
    g = _shared_link_graph(bw=10.0)
    reg = MetricsRegistry()
    blame = BlameLedger(g, registry=reg)
    blame.publish_flows("victim", [_victim(4.0)], now=1.0)
    blame.publish_flows("noisy", [_neighbor(5.0)], now=1.0)
    blame.publish_flows("quiet", [_neighbor(1.0, cls="read",
                                            tenant="quiet")], now=1.0)
    ex = blame.on_violation("victim", "decode_latency.p99",
                            observed_s=0.05, threshold_s=0.01, now=2.0)
    assert ex.link == ("a", "b") and ex.link_kind == "upi"
    # victim-weighted utilization: (4 + 1.6*5 + 1*1) / 10
    assert ex.rho == pytest.approx((4 + 1.6 * 5 + 1.0) / 10.0)
    # writer pressure 1.6*5 beats the quiet reader's 1*1
    assert ex.antagonist == "noisy"
    assert ex.pressure["noisy"] == pytest.approx(8.0)
    assert ex.pressure["quiet"] == pytest.approx(1.0)
    assert ex.loads[("noisy", "write")] == pytest.approx(5.0)
    # blame mass is the pressure share, accumulated per excursion
    assert blame.noisy_neighbor_score("noisy") == pytest.approx(8 / 9)
    assert blame.noisy_neighbor_score("victim") == 0.0
    rep = blame.blame_report()
    assert rep["top_antagonist"] == "noisy"
    assert rep["top_link"] == "a-b"
    assert rep["victims"] == {"victim": 1}
    assert reg.counter("qos.excursions").value == 1
    assert blame.summary()["qos.noisy_neighbor.noisy"] > 0.8


def test_blame_retags_spoofed_flows_and_handles_missing_victim():
    g = _shared_link_graph()
    blame = BlameLedger(g)
    # a tenant cannot shed blame by tagging its flows as someone else
    blame.publish_flows("noisy", [Flow("b", "a", 5.0, cls="write",
                                       tenant="innocent")])
    blame.publish_flows("victim", [_victim()])
    ex = blame.on_violation("victim", "m", 1.0, 0.5)
    assert ex.antagonist == "noisy"
    # a victim with no published flows cannot be attributed
    assert blame.on_violation("ghost", "m", 1.0, 0.5) is None
    assert blame.total_excursions == 1


def test_blame_excursions_are_ring_bounded():
    g = _shared_link_graph()
    blame = BlameLedger(g, max_excursions=4)
    blame.publish_flows("victim", [_victim()])
    for i in range(9):
        blame.on_violation("victim", "m", 1.0, 0.5, now=float(i))
    assert len(blame.excursions) == 4
    assert blame.total_excursions == 9


# ===================================================================== #
# ViolationPredictor: forecast + admission gate + audit joins            #
# ===================================================================== #
def test_predictor_scales_baseline_by_slowdown():
    g = _shared_link_graph(bw=10.0)
    pred = ViolationPredictor(g)
    pred.set_target("victim", 0.02)
    pred.set_baseline("victim", 0.01)
    # lone victim: rho 0.4 -> latency stretch 1/(1-0.4)
    lone = pred.predict_p99("victim", [_victim(4.0)])
    assert lone == pytest.approx(0.01 / 0.6)
    assert pred.admission_ok([_victim(4.0)])
    # writer neighbor pushes the victim's weighted rho to 1.2 (clamped
    # at 0.95): predicted latency blows the 2x target
    flows = [_victim(4.0), _neighbor(5.0)]
    viol = pred.violations(flows)
    assert "victim" in viol
    p, thr = viol["victim"]
    assert thr == 0.02 and p > thr
    assert not pred.admission_ok(flows)
    # a tenant with no live flows keeps its baseline (no violation)
    assert pred.predict_p99("victim", []) is None


def test_predictor_merges_blame_book_with_exclusion():
    g = _shared_link_graph(bw=10.0)
    blame = BlameLedger(g)
    pred = ViolationPredictor(g, blame=blame)
    pred.set_target("victim", 0.02)
    pred.set_baseline("victim", 0.01)
    blame.publish_flows("victim", [_victim(4.0)])
    blame.publish_flows("noisy", [_neighbor(5.0)])
    # the book alone already predicts a violation
    assert not pred.admission_ok([])
    # excluding the noisy tenant's snapshot (its own live view) leaves
    # just the victim: healthy
    assert pred.admission_ok([], exclude="noisy")
    # candidate flows stack on top of the remaining book
    assert not pred.admission_ok([_neighbor(5.0)], exclude="noisy")


def test_predictor_observe_p99_keeps_best_baseline():
    g = _shared_link_graph()
    pred = ViolationPredictor(g)
    pred.observe_p99("victim", 0.02)
    pred.observe_p99("victim", 0.013)
    pred.observe_p99("victim", 0.05)       # worse: ignored
    pred.observe_p99("victim", 0.0)        # non-positive: ignored
    assert pred.baselines["victim"] == pytest.approx(0.013)


def test_predictor_audit_joins_under_model_tolerance():
    g = _shared_link_graph(bw=10.0)
    audit = PredictionLedger()
    pred = ViolationPredictor(g, audit=audit)
    # attaching the predictor registers the per-model tolerance
    assert audit.model_tolerance[QOS_VIOLATION_MODEL] == \
        QOS_VIOLATION_TOLERANCE
    pred.set_baseline("victim", 0.01)
    p = pred.file_prediction("e0", "victim",
                             extra_flows=[_victim(4.0)], epoch=0)
    assert p == pytest.approx(0.01 / 0.6)
    rec = pred.realize("e0", "victim", p * 1.2)   # within 35% tolerance
    assert rec is not None
    assert audit.accuracy(QOS_VIOLATION_MODEL) == pytest.approx(1.0)
    # a forecast off by more than the tolerance counts against accuracy
    pred.file_prediction("e1", "victim", extra_flows=[_victim(4.0)],
                         epoch=1)
    pred.realize("e1", "victim", p * 2.0)
    assert audit.accuracy(QOS_VIOLATION_MODEL) == pytest.approx(0.5)


# ===================================================================== #
# end-to-end: SLO hook -> blame -> trace chain                           #
# ===================================================================== #
def test_slo_violation_hook_drives_blame_and_trace_chain():
    g = _shared_link_graph(bw=10.0)
    tracer = TraceRecorder(clock=lambda: 0.0)
    blame = BlameLedger(g, tracer=tracer)
    slo = SLOMonitor([SLOTarget("decode_latency", 0.99, 0.01)],
                     tracer=tracer, min_samples=4)
    slo.add_violation_hook(
        lambda t, v, now: blame.on_violation("victim", t.key, v,
                                             t.threshold_s, now=now))
    blame.publish_flows("victim", [_victim(4.0)])
    blame.publish_flows("noisy", [_neighbor(5.0)])
    # saturation breadcrumb on the shared link before the excursion
    g.contended_flows([_victim(4.0), _neighbor(5.0)], tracer=tracer)
    for i in range(8):
        slo.observe("decode_latency", 0.05, now=float(i))
        slo.check(now=float(i))
    assert blame.total_excursions > 0
    chains = qos_chains(tracer.events)
    assert chains and chains[0]["blame"] is not None
    assert chains[0]["blame"].args["antagonist"] == "noisy"
    assert chains[0]["blame"].args["link"] == "a-b"
    assert chains[0]["saturations"], "clamped-rho breadcrumb missing"
    assert chains[0]["saturations"][0].args["kind"] == "upi"


# ===================================================================== #
# calibration: measured slowdown reprices the interference matrix        #
# ===================================================================== #
def test_calibrator_interference_scales_reprice_contention():
    g = _shared_link_graph(bw=10.0)
    cal = CostModelCalibrator(paper_system("A"), graph=g)
    base_w = g.interference.weight("upi", "read", "write")
    # contention repeatedly hits 1.5x harder than modeled
    for _ in range(8):
        cal.observe_interference("upi", "read", "write", 1.5)
    m = cal.calibrated_interference()
    assert m.weight("upi", "read", "write") > base_w
    # same-class and reverse-direction pairs are untouched
    assert m.weight("upi", "read", "read") == pytest.approx(1.0)
    assert m.weight("upi", "write", "read") == pytest.approx(
        g.interference.weight("upi", "write", "read"))
    # the calibrated graph carries the matrix: the victim's achieved
    # bandwidth under the writer drops further than the builder model
    cg = cal.calibrated_graph()
    flows = [_victim(4.0), _neighbor(5.0)]
    before = g.contended_flows(flows)[0]
    after = cg.contended_flows(flows)[0]
    assert after.achieved_GBps < before.achieved_GBps
    assert after.raw_rho > before.raw_rho
    # summary exposes the fitted pair scale
    key = "calibration.interference.upi.read-write.scale"
    assert cal.summary()[key] > 1.0
    # bad ratios are ignored
    cal.observe_interference("upi", "read", "write", 0.0)
    cal.observe_interference("upi", "read", "write", float("inf"))


def test_calibrator_without_interference_obs_keeps_base_matrix():
    g = _shared_link_graph()
    cal = CostModelCalibrator(paper_system("A"), graph=g)
    assert cal.calibrated_interference() is g.interference
    assert cal.calibrated_graph().interference is g.interference


# ===================================================================== #
# arbiter: blame debits fast-tier grants                                 #
# ===================================================================== #
class _StubBlame:
    def __init__(self, scores):
        self.scores = scores

    def noisy_neighbor_score(self, tenant):
        return self.scores.get(tenant, 0.0)


def _arbiter_with_blame(blame, capacity=100, **kw):
    from repro.pool import ResidencyLedger
    led = ResidencyLedger()
    for t in ("noisy", "quiet"):
        led.register_tenant(t)
    return TierBudgetArbiter(led, "LDRAM", capacity_bytes=capacity,
                             blame=blame, **kw)


def test_arbiter_debits_blamed_tenant_and_refills_victim():
    arb = _arbiter_with_blame(_StubBlame({"noisy": 1.0}),
                              blame_debit=0.5)
    demands = [TenantDemand("noisy", 100, 80, 1.0),
               TenantDemand("quiet", 100, 80, 1.0)]
    budgets = arb.split(demands)
    # fair share would be 50/50; the fully-blamed tenant loses half its
    # grant and the clean still-hungry tenant absorbs it
    assert budgets["noisy"] == 25
    assert budgets["quiet"] == 75
    assert arb.blame_debited_bytes == 25


def test_arbiter_blame_debit_noop_for_clean_tenants():
    arb = _arbiter_with_blame(_StubBlame({}), blame_debit=0.5)
    demands = [TenantDemand("noisy", 100, 80, 1.0),
               TenantDemand("quiet", 100, 80, 1.0)]
    assert arb.split(demands) == {"noisy": 50, "quiet": 50}
    assert arb.blame_debited_bytes == 0
