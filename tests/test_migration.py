"""Memory-tiering runtimes: reproduce the paper's §VI PMO findings."""
import pytest

from repro.core import (AutoNUMA, Block, make_blocks_from_plan,
                        MigrationExecutor, MigrationSim, NoBalance,
                        paper_system, Tiering08, TPP, trace_scattered_hotset,
                        trace_stable_hotset, trace_uniform)
from repro.topology import build_topology

MB64 = 64 * 1024**2
GiB = 1024**3


def _blocks(n_slow=48, n_fast=8):
    return ([Block("a", i, MB64, "CXL") for i in range(n_slow)]
            + [Block("a", 1000 + i, MB64, "LDRAM") for i in range(n_fast)])


def _run(policy, trace, fast_cap=40):
    tiers = paper_system("A")
    sim = MigrationSim([Block(b.obj, b.idx, b.nbytes, b.tier,
                              b.unmigratable) for b in _blocks()],
                       tiers, "LDRAM", policy,
                       fast_capacity_bytes=fast_cap * MB64)
    return sim.run(trace)


def test_migration_helps_stable_hotset():
    """BT/LU-style: hot pages with locality -> migration wins (PMO 5)."""
    ids = [(b.obj, b.idx) for b in _blocks()]
    trace = trace_stable_hotset(ids, epochs=25, hot_fraction=0.15)
    no = _run(NoBalance(), trace)
    auto = _run(AutoNUMA(), trace)
    assert auto.exec_time_s < no.exec_time_s
    assert auto.fast_hit_fraction > no.fast_hit_fraction


def test_migration_hurts_uniform_access():
    """FT/SP-style uniformly-touched sets: migration only adds traffic
    and profiling overhead (PMO 5).  Fast tier starts FULL (first touch
    placed it), so promotion can only churn."""
    blocks = ([Block("a", i, MB64, "CXL") for i in range(16)]
              + [Block("a", 100 + i, MB64, "LDRAM") for i in range(40)])
    ids = [(b.obj, b.idx) for b in blocks]
    trace = trace_uniform(ids, epochs=25)
    tiers = paper_system("A")

    def run(policy):
        sim = MigrationSim([Block(b.obj, b.idx, b.nbytes, b.tier)
                            for b in blocks], tiers, "LDRAM", policy,
                           fast_capacity_bytes=40 * MB64)
        return sim.run(trace)

    no = run(NoBalance())
    tpp = run(TPP())
    assert tpp.exec_time_s >= no.exec_time_s * 0.999


def test_tiering08_fewer_faults_than_tpp():
    """PMO 2: Tiering-0.8 profiles far less than TPP (59x in paper).
    Small fast capacity keeps a large slow-resident population, so TPP
    faults on every touched slow block every epoch."""
    ids = [(b.obj, b.idx) for b in _blocks(96, 8)]
    trace = trace_scattered_hotset(ids, epochs=30, hot_fraction=0.5)

    def run(policy):
        tiers = paper_system("A")
        sim = MigrationSim([Block("a", i, MB64, "CXL")
                            for i in range(96)]
                           + [Block("a", 1000 + i, MB64, "LDRAM")
                              for i in range(8)],
                           tiers, "LDRAM", policy,
                           fast_capacity_bytes=12 * MB64)
        return sim.run(trace)

    t08 = run(Tiering08())
    tpp = run(TPP())
    assert t08.stats.hint_faults < 0.5 * tpp.stats.hint_faults


def test_interleaved_blocks_never_fault():
    """PMO 3: pages placed by interleaving live in unmigratable regions
    and produce (orders of magnitude) fewer hint faults."""
    shares = {"a": [("LDRAM", 0.5), ("CXL", 0.5)]}
    blocks = make_blocks_from_plan(shares, {"a": 56 * MB64},
                                   block_bytes=MB64,
                                   interleaved_objs=["a"])
    assert all(b.unmigratable for b in blocks)
    tiers = paper_system("A")
    ids = [(b.obj, b.idx) for b in blocks]
    trace = trace_stable_hotset(ids, epochs=20)
    sim = MigrationSim(blocks, tiers, "LDRAM", AutoNUMA(),
                       fast_capacity_bytes=40 * MB64)
    res = sim.run(trace)
    assert res.stats.hint_faults == 0
    assert res.stats.promoted == 0


def test_capacity_pressure_demotes_coldest():
    ids = [(b.obj, b.idx) for b in _blocks(48, 8)]
    trace = trace_scattered_hotset(ids, epochs=30, hot_fraction=0.4)
    res = _run(AutoNUMA(), trace, fast_cap=12)
    assert res.stats.demoted > 0
    # fast tier never exceeded: promoted - demoted bounded by capacity
    assert res.stats.promoted >= res.stats.demoted


# ---------------------------------------------------------------------- #
# MigrationExecutor path pricing (repro.topology)                         #
# ---------------------------------------------------------------------- #
def _promote_cost(topology_name: str, nbytes: int) -> float:
    tb = build_topology(topology_name)
    ex = MigrationExecutor(tb.tiers, topology=tb.graph, page_bytes=4096)
    d = ex.delta({"a": [("CXL", 1.0)]}, {"a": [("LDRAM", 1.0)]},
                 {"a": nbytes})
    return ex.cost_s(d)


def test_executor_far_socket_moves_cost_more_for_equal_bytes():
    near = _promote_cost("vendor-a", GiB)
    far = _promote_cost("far-socket", GiB)
    assert far > near
    # the surcharge is the per-page round-trip over the extra UPI hop
    pages = GiB // 4096
    assert far - near == pytest.approx(pages * 2 * 87e-9, rel=1e-6)


def test_executor_contended_moves_serialize_disjoint_overlap():
    from conftest import dual_cxl_machine

    g, tiers = dual_cxl_machine()
    ex = MigrationExecutor(tiers, topology=g)
    nb = {"a": GiB, "b": GiB}
    solo = ex.cost_s(ex.delta({"a": [("CXL0", 1.0)]},
                              {"a": [("DRAM0", 1.0)]}, {"a": GiB}))
    # both promotions drain the SAME card: they serialize on its link
    shared = ex.cost_s(ex.delta(
        {"a": [("CXL0", 1.0)], "b": [("CXL0", 1.0)]},
        {"a": [("DRAM0", 1.0)], "b": [("DRAM0", 1.0)]}, nb))
    # one promotion per card, each on its own socket: paths are disjoint
    disjoint = ex.cost_s(ex.delta(
        {"a": [("CXL0", 1.0)], "b": [("CXL1", 1.0)]},
        {"a": [("DRAM0", 1.0)], "b": [("DRAM1", 1.0)]}, nb))
    assert shared == pytest.approx(2 * solo, rel=0.05)
    assert disjoint < shared
    # disjoint ~= one move's wire time + both moves' per-page overhead
    assert disjoint < 1.6 * solo


def test_executor_without_topology_keeps_slow_endpoint_pricing():
    tiers = paper_system("A")
    ex_flat = MigrationExecutor(tiers)
    ex_topo = MigrationExecutor(tiers,
                                topology=build_topology("vendor-a").graph)
    d = ex_flat.delta({"a": [("CXL", 1.0)]}, {"a": [("LDRAM", 1.0)]},
                      {"a": GiB})
    flat, topo = ex_flat.cost_s(d), ex_topo.cost_s(d)
    assert flat > 0 and topo > 0
    # both price the wire time at the CXL card's bandwidth
    assert topo == pytest.approx(flat, rel=0.2)
