"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced config of the same family — one forward/train step on CPU with
shape + finite-ness asserts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_smoke_config
from repro.models import lm


def _inputs(cfg, B=2, S=32):
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    cross = None
    if cfg.n_frontend_tokens:
        cross = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_frontend_tokens, cfg.d_model))
    return toks, jnp.roll(toks, -1, axis=1), cross


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks, labels, cross = _inputs(cfg)

    loss = lm.forward_loss(params, cfg, toks, labels, cross)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert 0.0 < float(loss) < 20.0

    grads = jax.grad(
        lambda p: lm.forward_loss(p, cfg, toks, labels, cross))(params)
    leaves = jax.tree.leaves(grads)
    assert leaves, "no gradients"
    for leaf in leaves:
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), \
            f"{arch}: non-finite grad"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_shapes(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks, _, cross = _inputs(cfg)
    logits, cache = lm.prefill(params, cfg, toks, cross)
    assert logits.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert int(cache["index"]) == toks.shape[1]
    # cache leaves carry the unit axis
    for k, vv in cache.items():
        if k != "index":
            assert vv.shape[0] == cfg.n_units, (arch, k, vv.shape)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step_updates_params(arch):
    from repro.launch.steps import make_train_step
    from repro.optim import AdamConfig, init_state

    cfg = get_smoke_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    acfg = AdamConfig(lr=1e-2)
    opt = init_state(params, acfg)
    toks, labels, cross = _inputs(cfg)
    batch = {"tokens": toks, "labels": labels}
    if cross is not None:
        batch["frames"] = cross
    step = make_train_step(cfg, acfg)
    new_params, new_opt, loss = step(params, opt, batch)
    assert np.isfinite(float(loss))
    assert int(new_opt["step"]) == 1
    # at least one leaf moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32),
                        np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(new_params)))
    assert moved, f"{arch}: params did not update"
