"""Fused tiered-gather kernels vs gather-then-compute oracles.

The fused paged-decode kernel reads KV blocks straight out of the
tier-resident pool layout through a scalar-prefetched block-index
table; the oracle stages the same blocks into a contiguous cache first
(the copy the kernel eliminates).  Agreement across block tables,
ragged kv_len, and routing patterns is what lets the engine swap the
staged path for the fused one.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ops, ref


def _paged_inputs(seed, B, H, KV, hd, bt, nb, num_blocks, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    q = (jax.random.normal(ks[0], (B, H, hd)) * 0.3).astype(dtype)
    k_pool = (jax.random.normal(ks[1], (num_blocks, bt, KV, hd))
              * 0.3).astype(dtype)
    v_pool = (jax.random.normal(ks[2], (num_blocks, bt, KV, hd))
              * 0.3).astype(dtype)
    tbl = jax.random.randint(ks[3], (B, nb), 0, num_blocks, jnp.int32)
    k_new = (jax.random.normal(ks[4], (B, KV, hd)) * 0.3).astype(dtype)
    v_new = (jax.random.normal(ks[5], (B, KV, hd)) * 0.3).astype(dtype)
    return q, k_pool, v_pool, tbl, k_new, v_new


# ---------------------- fused paged decode ---------------------------- #
@pytest.mark.parametrize("B,H,KV,hd,bt,nb,num_blocks", [
    (1, 4, 4, 64, 16, 2, 8),       # MHA, tiny pool
    (4, 8, 2, 64, 32, 4, 16),      # GQA
    (2, 16, 1, 32, 64, 3, 32),     # MQA, odd block count
])
def test_paged_decode_attention_sweep(B, H, KV, hd, bt, nb, num_blocks):
    q, kp, vp, tbl, kn, vn = _paged_inputs(0, B, H, KV, hd, bt, nb,
                                           num_blocks)
    # ragged: every row caches a different prefix of its blocks
    kv_len = jnp.asarray([(i * 7 + 3) % (nb * bt) for i in range(B)],
                         jnp.int32)
    got = ops.paged_decode_attention(q, kp, vp, tbl, kv_len, kn, vn,
                                     block_tokens=bt)
    want = ref.paged_decode_attention(q, kp, vp, tbl, kv_len, kn, vn)
    assert got.shape == (B, H, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("kv_len", [0, 31, 32, 33, 127])
def test_paged_decode_attention_block_boundaries(kv_len):
    """The new token lands exactly at/around block edges (and at 0:
    attention over nothing but the freshly scattered token)."""
    B, H, KV, hd, bt, nb = 2, 4, 2, 32, 32, 4
    q, kp, vp, tbl, kn, vn = _paged_inputs(1, B, H, KV, hd, bt, nb, 8)
    lens = jnp.full((B,), kv_len, jnp.int32)
    got = ops.paged_decode_attention(q, kp, vp, tbl, lens, kn, vn,
                                     block_tokens=bt)
    want = ref.paged_decode_attention(q, kp, vp, tbl, lens, kn, vn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_paged_decode_attention_shared_blocks_and_bf16():
    """Different sequences' tables may point at the same physical
    blocks (the pool reuses ids); bf16 pools stay within bf16 slack."""
    B, H, KV, hd, bt, nb = 3, 8, 2, 64, 16, 3
    q, kp, vp, _, kn, vn = _paged_inputs(2, B, H, KV, hd, bt, nb, 4,
                                         dtype=jnp.bfloat16)
    tbl = jnp.asarray([[0, 1, 2], [2, 1, 0], [1, 1, 3]], jnp.int32)
    lens = jnp.asarray([40, 17, 5], jnp.int32)
    got = ops.paged_decode_attention(q, kp, vp, tbl, lens, kn, vn,
                                     block_tokens=bt)
    want = ref.paged_decode_attention(q, kp, vp, tbl, lens, kn, vn)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


@settings(max_examples=8, deadline=None)
@given(nb=st.integers(1, 4), kv=st.sampled_from([1, 2]),
       rep=st.sampled_from([1, 4]), seed=st.integers(0, 10))
def test_paged_decode_attention_property(nb, kv, rep, seed):
    B, hd, bt = 2, 32, 16
    q, kp, vp, tbl, kn, vn = _paged_inputs(seed, B, kv * rep, kv, hd,
                                           bt, nb, 8)
    kv_len = jnp.asarray([seed % (nb * bt), (seed * 3 + 1) % (nb * bt)],
                         jnp.int32)
    got = ops.paged_decode_attention(q, kp, vp, tbl, kv_len, kn, vn,
                                     block_tokens=bt)
    want = ref.paged_decode_attention(q, kp, vp, tbl, kv_len, kn, vn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ------------------------ fused expert FFN ---------------------------- #
def _expert_inputs(seed, E, D, F, B, K, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = (jax.random.normal(ks[0], (B, D)) * 0.3).astype(dtype)
    wg = (jax.random.normal(ks[1], (E, D, F)) * 0.1).astype(dtype)
    wu = (jax.random.normal(ks[2], (E, D, F)) * 0.1).astype(dtype)
    wd = (jax.random.normal(ks[3], (E, F, D)) * 0.1).astype(dtype)
    ids = jax.random.randint(ks[4], (B, K), 0, E, jnp.int32)
    wts = jax.nn.softmax(jax.random.normal(ks[5], (B, K)), axis=-1)
    return x, wg, wu, wd, ids, wts.astype(dtype)


@pytest.mark.parametrize("E,D,F,B,K", [
    (4, 16, 32, 1, 1),
    (8, 64, 128, 6, 2),
    (16, 32, 64, 5, 4),
])
def test_fused_expert_ffn_sweep(E, D, F, B, K):
    x, wg, wu, wd, ids, wts = _expert_inputs(0, E, D, F, B, K)
    got = ops.fused_expert_ffn(x, wg, wu, wd, ids, wts)
    want = ref.expert_ffn(x, wg, wu, wd, ids, wts)
    assert got.shape == (B, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_fused_expert_ffn_duplicate_experts():
    """A token routed twice to the same expert accumulates both
    weighted contributions (top-k ties are legal routing output)."""
    E, D, F, B = 4, 32, 64, 3
    x, wg, wu, wd, _, _ = _expert_inputs(1, E, D, F, B, 2)
    ids = jnp.asarray([[2, 2], [0, 3], [1, 1]], jnp.int32)
    wts = jnp.asarray([[0.7, 0.3], [0.5, 0.5], [1.0, 0.0]], jnp.float32)
    got = ops.fused_expert_ffn(x, wg, wu, wd, ids, wts)
    want = ref.expert_ffn(x, wg, wu, wd, ids, wts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_fused_expert_ffn_matches_model_moe_dense_equivalent():
    """With every expert identical, the routed sum collapses to the
    plain FFN regardless of routing — a closed-form cross-check that
    needs no staging oracle at all."""
    E, D, F, B, K = 4, 32, 64, 5, 2
    x, wg, wu, wd, ids, wts = _expert_inputs(2, E, D, F, B, K)
    wg = jnp.broadcast_to(wg[:1], wg.shape)
    wu = jnp.broadcast_to(wu[:1], wu.shape)
    wd = jnp.broadcast_to(wd[:1], wd.shape)
    got = ops.fused_expert_ffn(x, wg, wu, wd, ids, wts)
    xf = x.astype(jnp.float32)
    h = jax.nn.silu(xf @ wg[0].astype(jnp.float32)) \
        * (xf @ wu[0].astype(jnp.float32))
    want = (h @ wd[0].astype(jnp.float32)) \
        * wts.sum(-1, keepdims=True).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
