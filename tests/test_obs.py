"""Observability plane (repro.obs): percentile sketches, trace ring +
exports, metrics registry, live SLO monitors, and the instrumented
control-plane decision chain end to end."""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import lm
from repro.obs import (Counter, LagRatioMonitor, MetricsRegistry,
                       PercentileSketch, SLOMonitor, SLOTarget,
                       TraceRecorder, qos_chains, replan_chains)
from repro.serving import ServingConfig, ServingEngine
from repro.serving.metrics import ServingMetrics


# ===================================================================== #
# PercentileSketch: bounded relative error vs exact percentiles         #
# ===================================================================== #
@pytest.mark.parametrize("seed", [0, 7])
def test_sketch_bounded_relative_error(seed):
    rs = np.random.RandomState(seed)
    values = rs.lognormal(mean=-2.0, sigma=1.5, size=4000)
    sk = PercentileSketch(rel_err=0.01)
    for v in values:
        sk.add(float(v))
    for q in (0.50, 0.90, 0.95, 0.99):
        exact = float(np.percentile(values, q * 100.0))
        got = sk.quantile(q)
        # the log-bucket guarantee is rel_err on the value; rank
        # interpolation differences add a little, hence 3x slack
        assert abs(got - exact) <= 3 * 0.01 * exact, (
            f"q={q}: sketch {got} vs exact {exact}")


def test_sketch_zero_and_negative_collapse_to_zero_bucket():
    sk = PercentileSketch()
    for v in (0.0, -1.0, -5.5, 0.0):
        sk.add(v)
    assert sk.quantile(0.5) == 0.0
    s = sk.summary()
    assert s["count"] == 4
    assert s["min"] == -5.5 and s["max"] == 0.0


def test_sketch_summary_moments_exact():
    sk = PercentileSketch()
    for v in (1.0, 2.0, 3.0, 4.0):
        sk.add(v)
    s = sk.summary()
    assert s["count"] == 4
    assert s["sum"] == pytest.approx(10.0)
    assert s["mean"] == pytest.approx(2.5)


# ===================================================================== #
# TraceRecorder: ring bound, exports, round-trips                       #
# ===================================================================== #
def _fake_clock(times):
    it = iter(times)
    last = [0.0]

    def clock():
        try:
            last[0] = next(it)
        except StopIteration:
            pass
        return last[0]
    return clock


def test_trace_ring_eviction_counts_drops():
    tr = TraceRecorder(clock=lambda: 0.0, max_events=10)
    for i in range(25):
        tr.event("e", seq=i)
    assert len(tr) == 10
    assert tr.dropped == 15
    # the survivors are the newest events
    assert [ev.args["seq"] for ev in tr.events] == list(range(15, 25))


def test_trace_jsonl_roundtrip(tmp_path):
    tr = TraceRecorder(clock=_fake_clock([0.5, 1.25]))
    tr.event("grant", cat="arbiter", tid="serve",
             nbytes=1024, source="predicted")
    tr.complete("move", cat="movesched", tid="train", ts=1.0, dur=0.75,
                obj="opt_state", resources=["upi", "CXL"])
    path = tmp_path / "trace.jsonl"
    assert tr.to_jsonl(str(path)) == 2
    back = TraceRecorder.read_jsonl(str(path))
    assert [ev.to_dict() for ev in back] == \
        [ev.to_dict() for ev in tr.events]
    assert back[1].ph == "X" and back[1].dur_s == 0.75


def test_trace_chrome_export_structure(tmp_path):
    tr = TraceRecorder(clock=lambda: 2.0, max_events=2)
    tr.event("decision", cat="replan", applied=True)
    tr.complete("round", ts=1.0, dur=0.5)
    tr.event("extra")                       # evicts "decision"
    path = tmp_path / "trace.json"
    assert tr.to_chrome(str(path)) == 2
    payload = json.loads(path.read_text())
    evs = payload["traceEvents"]
    assert [e["ph"] for e in evs] == ["X", "i"]
    assert evs[0]["ts"] == pytest.approx(1.0 * 1e6)   # microseconds
    assert evs[0]["dur"] == pytest.approx(0.5 * 1e6)
    assert evs[1]["s"] == "t"               # instant scope present
    assert payload["metadata"]["dropped_events"] == 1


def test_trace_span_times_block_and_attaches_args():
    tr = TraceRecorder(clock=_fake_clock([1.0, 3.5]))
    with tr.span("work", cat="test") as args:
        args["result"] = 42
    (ev,) = tr.events
    assert ev.ph == "X"
    assert ev.ts_s == 1.0 and ev.dur_s == pytest.approx(2.5)
    assert ev.args["result"] == 42


def test_trace_json_safe_numpy_args(tmp_path):
    tr = TraceRecorder(clock=lambda: 0.0)
    tr.event("e", nbytes=np.int64(7), frac=np.float32(0.5),
             shape=(3, 4))
    path = tmp_path / "t.jsonl"
    tr.to_jsonl(str(path))                  # must not raise
    (ev,) = TraceRecorder.read_jsonl(str(path))
    assert ev.args["nbytes"] == 7
    assert ev.args["shape"] == [3, 4]


# ===================================================================== #
# MetricsRegistry: get-or-create, conflicts, Prometheus text            #
# ===================================================================== #
def test_registry_get_or_create_is_idempotent():
    reg = MetricsRegistry()
    c1 = reg.counter("a.count")
    c1.inc(3)
    assert reg.counter("a.count") is c1
    assert reg.counter("a.count").value == 3


def test_registry_type_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_counter_rejects_negative_increment():
    with pytest.raises(ValueError):
        Counter("c").inc(-1)


def test_registry_set_gauges_skips_non_numeric():
    reg = MetricsRegistry()
    n = reg.set_gauges({"a": 1.5, "b": True, "c": "text", "d": 2},
                       prefix="pre")
    assert n == 2
    assert sorted(reg.names()) == ["pre.a", "pre.d"]
    assert reg.gauge("pre.a").value == 1.5


def test_registry_prometheus_text():
    reg = MetricsRegistry()
    reg.counter("serving.finished", help="done").inc(5)
    reg.gauge("pool.fast-frac").set(0.75)
    h = reg.histogram("serving.ttft_s")
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    text = reg.to_prometheus_text()
    assert "# TYPE serving_finished counter" in text
    assert "serving_finished 5" in text
    assert "pool_fast_frac 0.75" in text           # sanitized name
    assert 'serving_ttft_s{quantile="0.95"}' in text
    assert "serving_ttft_s_count 3" in text
    assert "serving_ttft_s_sum" in text


def test_registry_snapshot_expands_histograms():
    reg = MetricsRegistry()
    reg.histogram("h").observe(2.0)
    snap = reg.snapshot()
    assert snap["h.count"] == 1
    assert snap["h.p50"] == pytest.approx(2.0, rel=0.05)


# ===================================================================== #
# SLOMonitor: rolling-window violations under an injected clock         #
# ===================================================================== #
def test_slo_violations_counted_under_fake_clock():
    now = [0.0]
    tr = TraceRecorder(clock=lambda: now[0])
    reg = MetricsRegistry()
    mon = SLOMonitor([SLOTarget("ttft", 0.95, threshold_s=0.2)],
                     clock=lambda: now[0], registry=reg, tracer=tr)
    for i in range(20):
        mon.observe("ttft", 0.05)
    assert mon.check() == []                # all fast: no violation
    for i in range(20):
        mon.observe("ttft", 0.5)            # now the window is slow
    now[0] = 3.0
    violated = mon.check()
    assert len(violated) == 1
    target, value = violated[0]
    assert target.key == "ttft.p95" and value > 0.2
    assert mon.violations["ttft.p95"] == 1
    assert reg.counter("slo.violations.ttft.p95").value == 1
    (ev,) = tr.filter(name="slo.violation")
    assert ev.ts_s == 3.0 and ev.args["threshold_s"] == 0.2
    s = mon.summary()
    assert s["checks"] == 2
    assert s["targets"][0]["violations"] == 1


def test_slo_window_is_rolling():
    mon = SLOMonitor([SLOTarget("ttft", 0.50, threshold_s=1.0)],
                     window=4)
    for v in (5.0, 5.0, 5.0, 5.0, 0.1, 0.1, 0.1, 0.1):
        mon.observe("ttft", v)
    assert mon.check() == []        # the slow samples rolled out


def test_slo_min_sample_warmup_gates_violations():
    """A 2-sample 'p99' is an arrival artifact, not a tail: targets
    stay ineligible (and silent) until the window passes warmup."""
    mon = SLOMonitor([SLOTarget("decode_latency", 0.99,
                                threshold_s=0.01)], min_samples=4)
    key = "decode_latency.p99"
    for _ in range(3):
        mon.observe("decode_latency", 0.5)   # way over threshold
        assert mon.check() == []             # but under warmup
    assert mon.violations[key] == 0
    assert mon.eligible_checks[key] == 0
    assert mon.violation_rate(key) is None   # no denominator yet
    mon.observe("decode_latency", 0.5)       # 4th sample: eligible
    violated = mon.check()
    assert len(violated) == 1 and violated[0][0].key == key
    assert mon.eligible_checks[key] == 1
    assert mon.violation_rate(key) == pytest.approx(1.0)
    # checks counted regardless of eligibility; summary carries both
    s = mon.summary()
    assert s["checks"] == 4
    assert s["targets"][0]["eligible_checks"] == 1
    assert s["targets"][0]["violation_rate"] == pytest.approx(1.0)


def test_slo_violation_rate_gauge_tracks_eligible_fraction():
    reg = MetricsRegistry()
    mon = SLOMonitor([SLOTarget("ttft", 0.95, threshold_s=0.2)],
                     registry=reg, min_samples=4)
    for _ in range(8):
        mon.observe("ttft", 0.05)
    assert mon.check() == []                 # eligible, healthy
    assert reg.gauge("slo.violation_rate.ttft.p95").value == 0.0
    for _ in range(8):
        mon.observe("ttft", 0.5)
    assert len(mon.check()) == 1             # second check violates
    assert mon.violation_rate("ttft.p95") == pytest.approx(0.5)
    assert reg.gauge("slo.violation_rate.ttft.p95").value == \
        pytest.approx(0.5)
    # an unknown target key has no rate
    assert mon.violation_rate("nope.p99") is None


def test_slo_violation_hooks_fire_with_target_value_and_clock():
    fired = []
    mon = SLOMonitor([SLOTarget("decode_latency", 0.99,
                                threshold_s=0.01)], min_samples=2)
    mon.add_violation_hook(
        lambda t, v, now: fired.append((t.key, v, now)))
    mon.add_violation_hook(
        lambda t, v, now: fired.append(("second", v, now)))
    for _ in range(4):
        mon.observe("decode_latency", 0.08)
    assert mon.check(now=7.5)                # explicit clock wins
    assert [f[0] for f in fired] == ["decode_latency.p99", "second"]
    key, value, now = fired[0]
    assert value > 0.01 and now == 7.5
    # a healthy check fires nothing further
    fired.clear()
    mon2 = SLOMonitor([SLOTarget("ttft", 0.95, threshold_s=10.0)],
                      min_samples=2)
    mon2.add_violation_hook(lambda t, v, now: fired.append(t))
    for _ in range(4):
        mon2.observe("ttft", 0.1)
    assert mon2.check() == [] and not fired


def test_slo_p999_key_not_aliased_to_p100():
    t = SLOTarget("decode_latency", 0.999, threshold_s=0.1)
    assert t.key == "decode_latency.p99.9"
    assert SLOTarget("ttft", 0.95, 0.1).key == "ttft.p95"
    assert SLOTarget("ttft", 0.99, 0.1).key == "ttft.p99"


def test_slo_p999_warmup_needs_a_real_tail():
    """An extreme-tail target needs >= 1/(1-q) samples before its
    empirical quantile is a tail at all; p95/p99 keep the caller's
    min_samples contract untouched."""
    t999 = SLOTarget("decode_latency", 0.999, threshold_s=0.01)
    assert t999.warmup_samples(4) == 1000
    assert SLOTarget("x", 0.95, 0.1).warmup_samples(4) == 4
    assert SLOTarget("x", 0.99, 0.1).warmup_samples(4) == 4

    mon = SLOMonitor([t999], window=2048, min_samples=4)
    key = "decode_latency.p99.9"
    for _ in range(999):
        mon.observe("decode_latency", 0.5)   # way over threshold
    assert mon.check() == []                 # 999 samples: still warmup
    assert mon.eligible_checks[key] == 0
    mon.observe("decode_latency", 0.5)       # 1000th: eligible
    violated = mon.check()
    assert len(violated) == 1 and violated[0][0].key == key
    assert mon.last_quantiles[key] == pytest.approx(0.5)


def test_slo_window_autogrows_to_hold_p999_warmup():
    """A p99.9 target inside a 256-sample window could never become
    eligible — the monitor grows the window to fit the warmup."""
    mon = SLOMonitor([SLOTarget("decode_latency", 0.999,
                                threshold_s=0.1)], window=256)
    assert mon.window >= 1000
    # without extreme-tail targets the requested window is respected
    mon2 = SLOMonitor([SLOTarget("ttft", 0.95, 0.1)], window=256)
    assert mon2.window == 256


def test_slo_p999_discriminates_tail_from_body():
    """1-in-1000 spikes: p95 stays quiet, p99.9 fires."""
    mon = SLOMonitor([SLOTarget("decode_latency", 0.95, threshold_s=0.2),
                      SLOTarget("decode_latency", 0.999,
                                threshold_s=0.2)])
    for i in range(2000):
        mon.observe("decode_latency", 2.0 if i % 500 == 499 else 0.05)
    violated = mon.check()
    assert [t.key for t, _ in violated] == ["decode_latency.p99.9"]


# ===================================================================== #
# LagRatioMonitor: online burst-entry / steady ratio                    #
# ===================================================================== #
def _feed_cycles(mon, cycles, entry_rate, steady_rate,
                 burst_len=4, lull_len=4):
    for _ in range(cycles):
        for pos in range(burst_len):
            rate = entry_rate if pos == 0 else steady_rate
            mon.observe_epoch("burst", rate, 1.0)
        for _ in range(lull_len):
            mon.observe_epoch("lull", 10.0, 1.0)


def test_lag_ratio_matches_synthetic_phases():
    mon = LagRatioMonitor(warmup_occurrences=2, steady_from=2)
    _feed_cycles(mon, cycles=4, entry_rate=80.0, steady_rate=100.0)
    # warmup discards the first two burst occurrences entirely
    assert len(mon.entry_rates["burst"]) == 2
    assert mon.ratio("burst") == pytest.approx(0.8)
    # the busiest phase is picked automatically
    assert mon.ratio() == pytest.approx(0.8)
    assert mon.summary()["phase"] == "burst"


def test_lag_ratio_none_until_past_warmup():
    mon = LagRatioMonitor(warmup_occurrences=2)
    _feed_cycles(mon, cycles=2, entry_rate=50.0, steady_rate=100.0)
    assert mon.ratio("burst") is None


def test_lag_ratio_ignores_zero_time_epochs():
    mon = LagRatioMonitor(warmup_occurrences=0, steady_from=2)
    mon.observe_epoch("burst", 100.0, 0.0)   # skipped, but still pos 0
    for _ in range(3):
        mon.observe_epoch("burst", 100.0, 1.0)
    assert mon.ratio("burst") is None        # no entry sample recorded


# ===================================================================== #
# ServingMetrics: live preemption counting + omitted-key rows           #
# ===================================================================== #
def test_summary_counts_preemptions_of_unfinished_requests():
    m = ServingMetrics()
    m.on_submit(1, 0.0, 8)
    m.on_submit(2, 0.0, 8)
    m.on_preempt(1, 0.1)
    m.on_preempt(1, 0.2)
    m.on_preempt(2, 0.3)
    # request 1 finishes (scheduler agrees on 2); request 2 never does
    m.on_finish(1, 1.0, preemptions=2)
    s = m.summary()
    assert s["preemptions"] == 3.0          # 2 finished + 1 in flight
    assert s["finished"] == 1.0


def test_on_finish_takes_max_of_live_and_scheduler_counts():
    m = ServingMetrics()
    m.on_submit(1, 0.0, 8)
    m.on_finish(1, 1.0, preemptions=4)      # no live on_preempt calls
    assert m.summary()["preemptions"] == 4.0


def test_per_request_rows_omit_undefined_latencies():
    m = ServingMetrics()
    m.on_submit(1, 0.0, 8)                  # never admitted: no tokens
    m.on_submit(2, 0.0, 8)
    m.on_token(2, 0.5)
    for t in (0.6, 0.7):
        m.on_token(2, t)
    m.on_finish(2, 0.7, preemptions=0)
    rows = dict(m.per_request_rows())
    assert "ttft_s" not in rows[1] and "decode_tok_s" not in rows[1]
    assert rows[2]["ttft_s"] == pytest.approx(0.5)
    assert rows[2]["decode_tok_s"] > 0


def test_serving_metrics_publish_to_registry_and_slo():
    reg = MetricsRegistry()
    slo = SLOMonitor([SLOTarget("ttft", 0.95, threshold_s=0.1)],
                     registry=reg, min_samples=1)
    m = ServingMetrics(registry=reg, slo=slo)
    m.on_submit(1, 0.0, 8)
    m.on_token(1, 0.4)                      # ttft 0.4 > threshold
    m.on_token(1, 0.45)
    m.on_finish(1, 0.45, preemptions=0)
    assert slo.check()                      # violation observed
    snap = reg.snapshot()
    assert snap["serving.ttft_s.count"] == 1
    assert snap["serving.finished"] == 1


# ===================================================================== #
# End-to-end: the instrumented predictive engine's decision chain       #
# ===================================================================== #
@pytest.fixture(scope="module")
def tiny():
    cfg = get_smoke_config("llama3-8b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_engine_trace_reconstructs_decision_chain(tiny, tmp_path):
    """A predictive serve leaves a trace from which the full replan
    chain — phase -> grant -> verdict -> scheduled moves — rebuilds."""
    cfg, params = tiny
    eng = ServingEngine(cfg, params, ServingConfig(
        block_tokens=8, max_batch=2, max_context=32, policy="tiering08",
        adaptive=True, predictive=True, replan_every=4,
        slo_p95_ttft_s=1e-6))               # violates: everything is slower
    rs = np.random.RandomState(0)
    for i in range(4):
        eng.submit(rs.randint(0, cfg.vocab, (8,)).astype(np.int32),
                   max_new_tokens=8, arrival_s=0.002 * i)
    rep = eng.run()
    assert rep.summary["finished"] == 4.0

    chains = replan_chains(eng.tracer.events)
    assert chains, "no epoch-keyed control-plane events recorded"
    assert any(c["decisions"] for c in chains.values())
    assert any(c["grants"] for c in chains.values())
    assert any(c["phases"] for c in chains.values())
    # grants carry the demand source the predictive arbiter decided on
    grant = next(c["grants"][0] for c in chains.values() if c["grants"])
    assert grant.args["source"] in ("measured", "predicted")

    # the impossible TTFT target must have been caught live
    assert rep.slo["targets"][0]["violations"] > 0

    # exports round-trip through both formats
    jl = tmp_path / "t.jsonl"
    assert eng.tracer.to_jsonl(str(jl)) == len(eng.tracer.events)
    assert len(TraceRecorder.read_jsonl(str(jl))) == len(eng.tracer.events)
    ch = tmp_path / "t.json"
    eng.tracer.to_chrome(str(ch))
    assert json.loads(ch.read_text())["traceEvents"]

    # the registry saw the run: summary gauges + latency histograms
    snap = eng.registry.snapshot()
    assert snap["serving.summary.finished"] == 4.0
    assert snap["serving.ttft_s.count"] == 4
    assert any(k.startswith("ledger.") for k in snap)


def test_engine_qos_plane_blames_excursions_live(tiny):
    """qos=True end to end inside the engine: an impossible decode SLO
    fires live violations, each joined by the blame hook to a topology
    link while the engine's own class-tagged flows are the book."""
    cfg, params = tiny
    eng = ServingEngine(cfg, params, ServingConfig(
        block_tokens=8, max_batch=2, max_context=32, policy="static",
        topology="far-socket", qos=True, fast_block_budget=1,
        slo_p99_decode_s=1e-9))             # violates: everything is slower
    assert eng.blame is not None and eng.predictor is not None
    rs = np.random.RandomState(1)
    for i in range(4):
        eng.submit(rs.randint(0, cfg.vocab, (8,)).astype(np.int32),
                   max_new_tokens=8, arrival_s=0.002 * i)
    rep = eng.run()
    assert rep.summary["finished"] == 4.0
    assert rep.slo["targets"][0]["violations"] > 0
    blame = rep.slo["blame"]
    assert blame["total_excursions"] > 0
    assert "serving" in blame["victims"]
    # solo tenant: each excursion still pins a real bottleneck link,
    # but there is no neighbor to rank as top antagonist
    assert all(ex["link"] is not None for ex in blame["excursions"])
    assert blame["top_antagonist"] is None
    # the trace joins each violation to its qos.blame event
    chains = qos_chains(eng.tracer.events)
    assert chains and any(c["blame"] is not None for c in chains)
    joined = next(c for c in chains if c["blame"] is not None)
    assert joined["blame"].args["victim"] == "serving"
    # predictive admission replaced the flat floor: its counters exist
    assert rep.telemetry["qos_deferrals"] >= 0.0
    assert rep.telemetry["slo_preemptions"] >= 0.0
    assert eng.registry.counter("qos.excursions").value > 0


def test_serve_cli_writes_obs_artifacts(tmp_path):
    """The launch CLI contract CI smokes: --trace-out/--metrics-out
    leave parseable, non-empty artifacts behind."""
    from repro.launch import serve
    trace = tmp_path / "trace.jsonl"
    metrics = tmp_path / "metrics.prom"
    serve.main(["--smoke", "--scheduler", "continuous", "--adaptive",
                "--num-requests", "3", "--new-tokens", "6",
                "--trace-out", str(trace),
                "--metrics-out", str(metrics)])
    events = TraceRecorder.read_jsonl(str(trace))
    assert events
    assert any(ev.name == "sched.admit" for ev in events)
    text = metrics.read_text()
    assert "# TYPE" in text and "serving_" in text
