"""Launch layer: cell building, jaxpr cost walker, HLO collective parse."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as H
from repro.launch import jaxpr_cost as JC
from repro.launch.mesh import dp_axes, dp_size, make_mesh, tp_size


def test_mesh_helpers():
    m = make_mesh((1, 1), ("data", "model"))
    assert dp_axes(m) == ("data",)
    assert dp_size(m) == 1 and tp_size(m) == 1


def test_jaxpr_cost_dot():
    def f(a, b):
        return a @ b
    a = jnp.zeros((64, 128))
    b = jnp.zeros((128, 32))
    c = JC.step_cost(f, a, b)
    assert c["flops"] == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_jaxpr_cost_scan_multiplies_trips():
    def f(xs, w):
        def body(c, x):
            return c + (x @ w).sum(), None
        out, _ = jax.lax.scan(body, 0.0, xs)
        return out
    xs = jnp.zeros((7, 16, 32))
    w = jnp.zeros((32, 8))
    c = JC.step_cost(f, xs, w)
    per_trip = 2 * 16 * 32 * 8
    assert c["flops"] >= 7 * per_trip
    assert c["flops"] < 7 * per_trip * 1.5


def test_jaxpr_cost_grad_counts_backward():
    def loss(w, x):
        return jnp.sum((x @ w) ** 2)
    w = jnp.zeros((32, 16))
    x = jnp.zeros((8, 32))
    fwd = JC.step_cost(loss, w, x)["flops"]
    both = JC.step_cost(jax.grad(loss), w, x)["flops"]
    assert both > 1.8 * fwd  # bwd ≈ 2x fwd for a matmul


def test_collective_parser_trip_counts():
    hlo = """
HloModule m

%body (p: (s32[], f32[])) -> (s32[], f32[]) {
  %ar = f32[1024]{0} all-reduce(%x), replica_groups=[4,8]<=[32], to_apply=%add
}

ENTRY %main () -> f32[] {
  %w = (s32[], f32[]) while(%t), condition=%c, body=%body, backend_config={"known_trip_count":{"n":"10"}}
}
"""
    stats = H.collective_bytes(hlo, 32)
    # 1024 f32 = 4096 bytes; all-reduce wire = 2*(7/8)*4096; x10 trips
    want = 2 * (7 / 8) * 4096 * 10
    assert stats.wire_bytes == pytest.approx(want, rel=0.01)
    assert stats.counts["all-reduce"] == 1


def test_collective_parser_plain():
    hlo = """
ENTRY %main () -> f32[] {
  %ag = bf16[256,128]{1,0} all-gather(%x), replica_groups=[16,16]<=[256]
}
"""
    stats = H.collective_bytes(hlo, 256)
    want = 256 * 128 * 2 * (15 / 16)
    assert stats.wire_bytes == pytest.approx(want, rel=0.01)


def test_build_cell_tiny_mesh_lowers():
    """A full train cell lowers+compiles on a 1x1 mesh (wiring check)."""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.launch.specs import build_cell, SHAPES
    cfg = get_smoke_config("stablelm-1.6b")
    cfg = dataclasses.replace(cfg, vocab=128)
    mesh = make_mesh((1, 1), ("data", "model"))
    # shrink the shape for CPU compile
    import repro.configs.base as B
    shape = B.ShapeConfig("train_4k", 64, 2, "train")
    import repro.launch.specs as SP
    old = SP.SHAPES
    SP.SHAPES = dict(old, train_4k=shape)
    try:
        cell = build_cell("stablelm-1.6b", "train_4k", mesh,
                          cfg_override=cfg)
        with mesh:
            compiled = cell.jit().lower(*cell.args).compile()
        assert compiled.cost_analysis() is not None
    finally:
        SP.SHAPES = old


def test_roofline_terms_math():
    stats = H.CollectiveStats(wire_bytes=50e9)
    r = H.roofline_terms(197e12 * 256, 819e9 * 256, stats, 256, 1e15)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(1.0)
    assert r.dominant in ("compute", "memory", "collective")


def test_vmem_fused_accounting_reduces_softmax_traffic():
    """Block-sized attention intermediates stop hitting HBM under the
    VMEM-residency model (the Pallas-kernel fusion, §Perf O7)."""
    import jax.numpy as jnp

    def attn(q, k, v):
        s = jnp.einsum("qd,kd->qk", q, k)
        p = jax.nn.softmax(s, axis=-1)
        return p @ v

    q = jnp.zeros((128, 64))
    k = jnp.zeros((128, 64))
    v = jnp.zeros((128, 64))
    base = JC.step_cost(attn, q, k, v)
    fused = JC.step_cost(attn, q, k, v, vmem_bytes=64 * 1024**2,
                         n_chips=1)
    assert fused["bytes"] < base["bytes"]
    # q/k/v always charged (persistent inputs)
    assert fused["bytes"] >= 3 * 128 * 64 * 4


def test_cast_absorbs_read_at_source_width():
    import jax.numpy as jnp

    def deq(c):
        return (c.astype(jnp.float32) * 2.0).sum()

    c8 = jnp.zeros((1024, 128), jnp.int8)
    cost = JC.step_cost(deq, c8)
    # charged at int8 width (+ small reduce output), not fp32
    assert cost["bytes"] < 1024 * 128 * 2
