"""repro.pool: residency ledger, fair-share arbiter, tiered state store,
and the ledger-backed refactor of the KV pool + adaptive replanner."""
import dataclasses

import numpy as np
import pytest

from repro.core import GiB, paper_system
from repro.core.migration import MigrationExecutor
from repro.pool import (LedgerError, ResidencyLedger, TenantDemand,
                        TierBudgetArbiter, TieredStateStore)
from repro.serving import FAST_KIND, PagedKVPool, TieredKVCache
from repro.telemetry import AccessTrace, AdaptiveReplanner, ReplanConfig

G = GiB


def _tiers(ldram_gib=96):
    t = {k: v for k, v in paper_system("A").items()
         if k in ("LDRAM", "CXL")}
    t["LDRAM"] = dataclasses.replace(t["LDRAM"], capacity_GiB=ldram_gib)
    return t


# ===================================================================== #
# ResidencyLedger: accounting invariants                                 #
# ===================================================================== #
def test_ledger_register_alloc_free_roundtrip():
    led = ResidencyLedger()
    led.register_tenant("a")
    led.register("a", "obj", {"LDRAM": 10, "CXL": 30})
    assert led.object_bytes("a", "obj") == 40
    assert led.bytes_on("LDRAM") == 10
    led.record_alloc("a", "obj", "CXL", 5)
    assert led.object_bytes("a", "obj", "CXL") == 35
    led.record_free("a", "obj", "CXL", 35)
    assert led.object_bytes("a", "obj") == 10
    led.record_free("a", "obj", "LDRAM", 999)   # clamped, then retired
    assert not led.has("a", "obj")
    assert led.counters.allocs == 1 and led.counters.frees == 1


def test_ledger_unknown_tenant_rejected():
    led = ResidencyLedger()
    with pytest.raises(LedgerError):
        led.register("ghost", "x", {"LDRAM": 1})


def test_ledger_move_accounting_clamps_to_source():
    led = ResidencyLedger()
    led.register_tenant("a")
    led.register("a", "x", {"CXL": 100})
    assert led.record_move("a", "x", "CXL", "LDRAM", 60) == 60
    assert led.record_move("a", "x", "CXL", "LDRAM", 60) == 40  # clamp
    assert led.placement("a", "x") == {"LDRAM": 100}
    assert led.counters.migrated_bytes == 100
    # shares view normalizes to fractions
    assert led.shares("a")["x"] == [("LDRAM", 1.0)]


def test_ledger_tenant_isolation_and_tier_occupancy():
    led = ResidencyLedger()
    led.register_tenant("a")
    led.register_tenant("b")
    led.register("a", "x", {"LDRAM": 30})
    led.register("b", "x", {"LDRAM": 50, "CXL": 20})  # same obj name ok
    assert led.bytes_on("LDRAM", "a") == 30
    assert led.bytes_on("LDRAM", "b") == 50
    assert led.bytes_on("LDRAM") == 80
    assert led.tier_occupancy("LDRAM") == {"a": 30, "b": 50}
    assert led.tenant_bytes("b") == 70


def test_ledger_budget_and_capacity_gate_placement():
    led = ResidencyLedger(capacity_bytes={"LDRAM": 100})
    led.register_tenant("a")
    led.register_tenant("b")
    led.register("a", "x", {"LDRAM": 40})
    led.set_budget("a", "LDRAM", 50)
    assert led.headroom("a", "LDRAM") == 10          # budget binds
    assert led.can_place("a", "LDRAM", 10)
    assert not led.can_place("a", "LDRAM", 11)
    # capacity binds across tenants even without a budget
    led.register("b", "y", {"LDRAM": 55})
    assert led.headroom("b", "LDRAM") == 5
    # budget shrink below usage -> over_budget is visible
    led.set_budget("a", "LDRAM", 25)
    assert led.over_budget("a", "LDRAM") == 15
    assert led.headroom("a", "LDRAM") < 0


def test_ledger_priced_move_gated_and_recorded():
    tiers = _tiers()
    led = ResidencyLedger(tiers, capacity_bytes={"LDRAM": 64 * G})
    led.register_tenant("a")
    led.register("a", "x", {"CXL": 10 * G})
    moved, cost = led.move("a", "x", "CXL", "LDRAM", 10 * G)
    assert moved == 10 * G and cost > 0
    assert led.placement("a", "x") == {"LDRAM": 10 * G}
    # a full fast tier denies the move
    led.register("a", "big", {"CXL": 60 * G})
    moved, _ = led.move("a", "big", "CXL", "LDRAM", 60 * G)
    assert moved == 54 * G                 # partial grant up to capacity
    assert led.counters.denied_moves == 0
    moved, _ = led.move("a", "big", "CXL", "LDRAM", G)
    assert moved == 0
    assert led.counters.denied_moves == 1


def test_ledger_resize_growth_lands_on_grow_tier():
    led = ResidencyLedger()
    led.register_tenant("a")
    led.register("a", "x", {"LDRAM": 50, "CXL": 50})
    led.resize("a", "x", 200, grow_tier="CXL")
    assert led.placement("a", "x") == {"LDRAM": 50, "CXL": 150}
    led.resize("a", "x", 100)              # shrink: proportional
    assert led.object_bytes("a", "x") == 100
    assert led.placement("a", "x")["LDRAM"] == 25


# ===================================================================== #
# TierBudgetArbiter                                                      #
# ===================================================================== #
def _demand(t, hot, rate, weight=1.0, resident=None):
    return TenantDemand(t, resident if resident is not None else hot,
                        hot, rate, weight)


def _arbiter(objective="fair_share", cap=64 * G, **kw):
    led = ResidencyLedger(capacity_bytes={"LDRAM": cap})
    return TierBudgetArbiter(led, "LDRAM", objective=objective, **kw), led


def test_arbiter_fair_share_caps_at_demand_and_waterfills():
    arb, _ = _arbiter()
    split = arb.split([_demand("a", 10 * G, 1.0),
                       _demand("b", 100 * G, 1.0)])
    # a's ask is satisfied; the slack water-fills to b
    assert split["a"] == 10 * G
    assert split["b"] == 54 * G
    assert sum(split.values()) <= 64 * G


def test_arbiter_fair_share_equal_when_both_hungry():
    arb, _ = _arbiter()
    split = arb.split([_demand("a", 100 * G, 1.0),
                       _demand("b", 100 * G, 1.0)])
    assert split["a"] == split["b"] == 32 * G


def test_arbiter_priority_weighted_split():
    arb, _ = _arbiter(objective="priority")
    split = arb.split([_demand("a", 100 * G, 1.0, weight=3.0),
                       _demand("b", 100 * G, 1.0, weight=1.0)])
    assert split["a"] == 48 * G and split["b"] == 16 * G


def test_arbiter_throughput_fills_intense_tenant_first():
    arb, _ = _arbiter(objective="throughput")
    hot = _demand("hot", 40 * G, rate=80.0 * G)      # 2 sweeps/epoch
    cold = _demand("cold", 60 * G, rate=6.0 * G)     # 0.1 sweeps/epoch
    split = arb.split([hot, cold])
    assert split["hot"] == 40 * G                     # full hot set
    assert split["cold"] == 24 * G                    # the remainder


def test_arbiter_unclaimed_capacity_stays_free():
    arb, _ = _arbiter()
    split = arb.split([_demand("a", 4 * G, 1.0, resident=40 * G),
                       _demand("b", 8 * G, 1.0, resident=40 * G)])
    assert sum(split.values()) == 12 * G     # no hoarding hand-out


def test_arbiter_measures_demand_from_traces_and_applies():
    led = ResidencyLedger(capacity_bytes={"LDRAM": 64 * G})
    for name in ("serve", "train"):
        led.register_tenant(name, trace=AccessTrace())
        led.register(name, "obj", {"CXL": 40 * G})
    # serve streams its object; train is idle (cold)
    led.trace("serve").record("obj", read_bytes=40 * G)
    led.trace("serve").advance_epoch()
    led.trace("train").advance_epoch()
    arb = TierBudgetArbiter(led, "LDRAM", window_epochs=2)
    d = arb.rebalance(epoch=1)
    assert d.budget_of("serve") == 40 * G
    assert d.budget_of("train") == 0
    assert led.budget("serve", "LDRAM") == 40 * G
    # cold objects below hot_threshold contribute no demand
    dm = arb.demand("train")
    assert dm.hot_bytes == 0 and dm.resident_bytes == 40 * G


def test_arbiter_rejects_unknown_objective_and_missing_capacity():
    led = ResidencyLedger()
    with pytest.raises(ValueError, match="objective"):
        TierBudgetArbiter(led, "LDRAM", capacity_bytes=G,
                          objective="chaos")
    with pytest.raises(ValueError, match="capacity"):
        TierBudgetArbiter(led, "LDRAM")


# ===================================================================== #
# TieredStateStore: real re-placement through the ledger                 #
# ===================================================================== #
def _store(cap_bytes=None):
    led = ResidencyLedger(
        _tiers(), capacity_bytes={"LDRAM": cap_bytes} if cap_bytes
        else None)
    return TieredStateStore(led, "train"), led


def test_state_store_put_gather_roundtrip():
    import jax.numpy as jnp
    store, led = _store()
    tree = {"m": jnp.arange(64, dtype=jnp.float32).reshape(16, 4),
            "v": jnp.ones((8,), jnp.float32)}
    store.put("opt", tree, [("CXL", 1.0)])
    assert led.object_bytes("train", "opt") == 16 * 4 * 4 + 8 * 4
    assert led.object_bytes("train", "opt", "CXL") == store.nbytes("opt")
    got = store.gather("opt")
    np.testing.assert_array_equal(np.asarray(got["m"]),
                                  np.asarray(tree["m"]))


def test_state_store_move_fn_replaces_blocks_and_records():
    import jax.numpy as jnp
    store, led = _store()
    x = jnp.zeros((32, 8), jnp.float32)          # 1 KiB
    store.put("opt", {"m": x}, [("CXL", 1.0)], )
    nbytes = store.nbytes("opt")
    moved = store.move_fn("opt", "CXL", "LDRAM", nbytes)
    assert moved == nbytes
    assert led.placement("train", "opt") == {"LDRAM": nbytes}
    assert store.shares("opt") == [("LDRAM", 1.0)]
    assert led.counters.migrated_bytes == nbytes
    # unknown objects and same-tier moves are no-ops
    assert store.move_fn("ghost", "CXL", "LDRAM", 10) == 0
    assert store.move_fn("opt", "LDRAM", "LDRAM", 10) == 0


def test_state_store_move_fn_respects_budget():
    import jax.numpy as jnp
    store, led = _store()
    led.set_budget("train", "LDRAM", 0)
    store.put("opt", {"m": jnp.zeros((16, 4), jnp.float32)},
              [("CXL", 1.0)])
    assert store.move_fn("opt", "CXL", "LDRAM", 10 ** 9) == 0
    assert led.object_bytes("train", "opt", "LDRAM") == 0


def test_state_store_update_preserves_placement():
    import jax.numpy as jnp
    store, led = _store()
    store.put("opt", {"m": jnp.zeros((16, 4), jnp.float32)},
              [("LDRAM", 0.5), ("CXL", 0.5)])
    before = led.placement("train", "opt")
    store.update("opt", {"m": jnp.ones((16, 4), jnp.float32)})
    assert led.placement("train", "opt") == before
    np.testing.assert_array_equal(
        np.asarray(store.gather("opt")["m"]), np.ones((16, 4)))


# ===================================================================== #
# PagedKVPool through the ledger                                         #
# ===================================================================== #
def test_pool_residency_mirrored_in_ledger():
    pool = PagedKVPool(8, 4, fast_block_budget=4)
    pool.alloc(1, 3)
    led = pool.ledger
    assert led.bytes_on(pool.slow_kind, pool.tenant) == 3
    assert pool.blocks_on(pool.slow_kind) == 3
    pool.migrate(pool.table[1][0], FAST_KIND)
    assert pool.fast_used() == 1
    assert led.bytes_on(FAST_KIND, pool.tenant) == 1
    pool.free_seq(1)
    assert led.tenant_bytes(pool.tenant) == 0
    assert pool.fast_used() == 0


def test_pool_fast_budget_lives_in_ledger():
    pool = PagedKVPool(8, 4, fast_block_budget=2)
    assert pool.fast_block_budget == 2
    assert pool.ledger.budget(pool.tenant, FAST_KIND) == 2
    pool.fast_block_budget = 5                # arbiter-style update
    assert pool.ledger.budget(pool.tenant, FAST_KIND) == 5


def test_two_pools_share_one_arbitrated_fast_capacity():
    """Two tenants on one ledger contend for a shared fast-tier
    capacity: tenant budgets gate promotions on both pools."""
    led = ResidencyLedger(capacity_bytes={FAST_KIND: 4})
    pa = PagedKVPool(8, 4, ledger=led, tenant="a")
    pb = PagedKVPool(8, 4, ledger=led, tenant="b")
    led.set_budget("a", FAST_KIND, 3)
    led.set_budget("b", FAST_KIND, 3)
    pa.alloc(1, 4)
    pb.alloc(1, 4)
    assert sum(pa.migrate(b, FAST_KIND) for b in pa.table[1]) == 3
    # b's budget says 3, but the shared capacity only has 1 left
    assert sum(pb.migrate(b, FAST_KIND) for b in pb.table[1]) == 1
    assert led.bytes_on(FAST_KIND) == 4
    assert pa.fast_used() == 3 and pb.fast_used() == 1


def test_tiered_kv_cache_reads_through_ledger():
    import jax.numpy as jnp
    cache = {"kv_k": jnp.zeros((4, 2, 8, 2, 4), jnp.bfloat16),
             "kv_v": jnp.zeros((4, 2, 8, 2, 4), jnp.bfloat16)}
    tk = TieredKVCache([("device", 0.5), ("pinned_host", 0.5)])
    tk.stash(cache)
    total = sum(cache[k].nbytes for k in ("kv_k", "kv_v"))
    on = {k: tk.bytes_on(k) for k in ("device", "pinned_host")}
    assert sum(on.values()) == total
    assert on["device"] > 0 and on["pinned_host"] > 0
    assert tk.ledger.tenant_bytes(tk.tenant) == total


# ===================================================================== #
# Replanner x ledger: budgets are mandatory                              #
# ===================================================================== #
def _hot_trace(spec, epochs=3):
    tr = AccessTrace()
    for _ in range(epochs):
        for obj, nbytes in spec.items():
            tr.record(obj, read_bytes=nbytes)
        tr.advance_epoch()
    return tr


def test_replanner_budget_shrink_forces_compliance():
    """An arbiter shrinking the tenant's fast budget below its holding
    must trigger a mandatory replan that vacates the excess, even when
    the hysteresis gate would have vetoed the move."""
    tiers = _tiers()
    nb = {"u": 60 * G}
    tr = _hot_trace({"u": 10 * G})
    led = ResidencyLedger(tiers)
    rp = AdaptiveReplanner(
        tr, tiers, "LDRAM",
        cfg=ReplanConfig(replan_every=1, min_speedup=100.0),
        executor=MigrationExecutor(tiers),
        ledger=led, tenant="t")
    d0 = rp.maybe_replan(1, nb)
    assert d0.reason == "initial"
    held = led.bytes_on("LDRAM", "t")
    assert held > 0
    led.set_budget("t", "LDRAM", held // 4)
    tr.record("u", read_bytes=10 * G)
    tr.advance_epoch()
    d = rp.maybe_replan(2, nb)
    assert d.applied and d.reason == "budget"
    from repro.core.migration import HUGE_PAGE_BYTES
    assert led.bytes_on("LDRAM", "t") <= held // 4 + HUGE_PAGE_BYTES
    # within budget again: the 100x hysteresis blocks further churn
    tr.record("u", read_bytes=10 * G)
    tr.advance_epoch()
    d2 = rp.maybe_replan(3, nb)
    assert d2 is None or not d2.applied


def test_replanner_prices_from_client_residency():
    """With a shared ledger, the replanner's view of 'where things are'
    is the client's recorded residency, not its own last plan."""
    tiers = _tiers()
    led = ResidencyLedger(tiers)
    led.register_tenant("t")
    led.register("t", "u", {"LDRAM": 20 * G, "CXL": 40 * G})
    tr = _hot_trace({"u": 60 * G})
    rp = AdaptiveReplanner(tr, tiers, "LDRAM",
                           cfg=ReplanConfig(replan_every=1),
                           executor=MigrationExecutor(tiers),
                           ledger=led, tenant="t")
    d = rp.maybe_replan(1, {"u": 60 * G})
    assert d.reason == "initial"
    # client-origin residency survives initial adoption untouched
    assert led.placement("t", "u") == {"LDRAM": 20 * G, "CXL": 40 * G}
    assert rp.plan.fraction_on("u", "LDRAM") == pytest.approx(1 / 3)

def test_replanner_budget_shrink_bypasses_phase_cache():
    """A phase-cached plan predates an arbiter shrink; the mandatory
    compliance replan must re-plan against the capped capacity view,
    not 'apply' the stale cached plan as a no-op."""
    tiers = _tiers()
    nb = {"u": 60 * G}
    tr = _hot_trace({"u": 60 * G})
    led = ResidencyLedger(tiers)
    rp = AdaptiveReplanner(
        tr, tiers, "LDRAM",
        cfg=ReplanConfig(replan_every=1),
        executor=MigrationExecutor(tiers),
        ledger=led, tenant="t")
    rp.maybe_replan(1, nb, phase="P")           # cached under P
    held = led.bytes_on("LDRAM", "t")
    assert held > 0
    led.set_budget("t", "LDRAM", held // 2)
    tr.record("u", read_bytes=60 * G)
    tr.advance_epoch()
    d = rp.maybe_replan(2, nb, phase="P")       # same phase signature
    assert d.applied and d.reason == "budget"
    assert not d.cached                          # cache was bypassed
    assert d.moved_bytes > 0                     # a real vacate, not a no-op
    # compliant within move (huge-page) granularity
    from repro.core.migration import HUGE_PAGE_BYTES
    assert led.bytes_on("LDRAM", "t") <= held // 2 + HUGE_PAGE_BYTES
