"""Prediction audit plane + self-calibrating cost model: ledger joins,
drift detection, probe fits, online correction, and the wiring into the
migration/replan/arbiter stack."""
import dataclasses
import math

import pytest

from repro.core import GiB, paper_system
from repro.core.costmodel import plan_step_cost
from repro.core.policies import PlacementPlan
from repro.core.migration import MigrationExecutor
from repro.core.objects import DataObject
from repro.obs import (CostModelCalibrator, DriftDetector, LagRatioMonitor,
                       MetricsRegistry, PredictionLedger, TierProbe,
                       TraceRecorder, probe_testbed)
from repro.pool import ResidencyLedger, TierBudgetArbiter
from repro.telemetry import (AccessTrace, AdaptiveReplanner, ReplanConfig)
from repro.topology import two_socket_system

G = GiB


def _tiers(ldram_gib=64):
    t = {k: v for k, v in paper_system("A").items()
         if k in ("LDRAM", "CXL")}
    t["LDRAM"] = dataclasses.replace(t["LDRAM"], capacity_GiB=ldram_gib)
    return t


# ===================================================================== #
# DriftDetector                                                          #
# ===================================================================== #
def test_drift_detector_fires_once_on_crossing_and_latches():
    det = DriftDetector(bound=0.5, window=8, min_samples=4)
    for _ in range(3):
        assert not det.observe(0.9)        # below min_samples: no fire
    assert det.observe(0.9)                # 4th sample crosses -> fires
    assert det.drifting and det.fires == 1
    assert not det.observe(0.9)            # latched: no re-fire
    assert det.fires == 1
    for _ in range(8):                     # window drains below bound
        det.observe(0.01)
    assert not det.drifting
    for _ in range(8):
        det.observe(0.9)
    assert det.drifting
    assert det.fires == 2                  # re-crossing fires exactly once


def test_drift_detector_p95_interpolates():
    det = DriftDetector(window=64, min_samples=1)
    for v in (0.0, 1.0):
        det.observe(v)
    assert det.p95() == pytest.approx(0.95)


# ===================================================================== #
# PredictionLedger: join semantics and edge cases                        #
# ===================================================================== #
def test_ledger_joins_signed_relative_error():
    led = PredictionLedger(tolerance=0.25)
    led.predict("m", "k", 10.0)
    rec = led.realize("m", "k", 12.0)
    assert rec.rel_err == pytest.approx(0.2)
    led.predict("m", "k2", 10.0)
    rec2 = led.realize("m", "k2", 7.0)
    assert rec2.rel_err == pytest.approx(-0.3)
    assert led.accuracy("m") == pytest.approx(0.5)   # one of two in tol
    assert not led.has_pending("m", "k")
    s = led.summary()
    assert s["audit.matched"] == 2.0
    assert s["prediction.accuracy.m"] == pytest.approx(0.5)


def test_ledger_realized_without_prediction_is_unmatched():
    led = PredictionLedger()
    assert led.realize("m", "never-predicted", 1.0) is None
    assert led.unmatched == 1 and led.matched == 0
    assert led.models() == []              # no record was created
    assert led.p95_abs_rel_err("m") is None
    assert led.accuracy("m") is None


def test_ledger_duplicate_join_key_overwrites_and_counts():
    led = PredictionLedger()
    led.predict("m", "k", 10.0)
    led.predict("m", "k", 20.0)            # stale forecast replaced
    assert led.duplicates == 1
    assert led.pending_count("m") == 1
    rec = led.realize("m", "k", 20.0)
    assert rec.predicted == 20.0           # latest prediction wins
    assert rec.rel_err == pytest.approx(0.0)


def test_ledger_zero_predicted_value_yields_no_residual():
    led = PredictionLedger()
    led.predict("m", "k", 0.0)
    rec = led.realize("m", "k", 5.0)
    assert rec is not None and rec.rel_err is None
    assert led.zero_predicted == 1 and led.matched == 1
    # the join is recorded but produces no residual statistics
    assert led.rel_errors("m") == []
    assert led.accuracy("m") is None


def test_ledger_pending_bound_evicts_oldest():
    led = PredictionLedger(max_pending=2)
    led.predict("m", 1, 1.0)
    led.predict("m", 2, 1.0)
    led.predict("m", 3, 1.0)
    assert led.expired == 1
    assert not led.has_pending("m", 1)     # oldest evicted unjoined
    assert led.has_pending("m", 2) and led.has_pending("m", 3)


def test_ledger_resource_attribution_is_occupancy_weighted():
    led = PredictionLedger()
    led.predict("m", "k", 10.0)
    led.realize("m", "k", 15.0, resources={"upi": 3.0, "cxl": 1.0})
    bias = led.resource_bias()
    assert bias["upi"] == pytest.approx(0.5)
    assert bias["cxl"] == pytest.approx(0.5)
    # a second join touching only one resource shifts that mean only
    led.predict("m", "k2", 10.0)
    led.realize("m", "k2", 10.0, resources=["upi"])
    assert led.resource_bias()["upi"] < 0.5
    assert led.resource_bias()["cxl"] == pytest.approx(0.5)


def test_ledger_publishes_gauges_and_trace_events():
    reg = MetricsRegistry()
    tr = TraceRecorder()
    led = PredictionLedger(registry=reg, tracer=tr)
    led.predict("move", "a", 1.0)
    led.realize("move", "a", 1.1)
    led.realize("move", "ghost", 1.0)      # unmatched
    assert "prediction.accuracy.move" in reg.names()
    assert "prediction.residual.move" in reg.names()
    audits = [e for e in tr.events if e.name == "prediction.audit"]
    assert len(audits) == 2
    assert audits[0].args["matched"] is True
    assert audits[1].args["matched"] is False


def test_ledger_drift_fires_into_counter_and_report():
    led = PredictionLedger(drift_bound=0.3, drift_window=8,
                           drift_min_samples=4)
    for i in range(6):
        led.predict("m", i, 10.0)
        led.realize("m", i, 16.0)          # 60% error every time
    rep = led.report()
    assert rep["models"]["m"]["drifting"] is True
    assert rep["models"]["m"]["drift_fires"] == 1
    assert led.drifting() == ["m"]


# ===================================================================== #
# CostModelCalibrator: startup fit                                       #
# ===================================================================== #
def _perturbed_testbed():
    """Builder-belief (model) vs drifted-truth (true) tier/graph pairs."""
    tb = two_socket_system("A")
    model_tiers = {k: v for k, v in tb.tiers.items() if k != "NVMe"}
    overrides = {}
    for key, ln in tb.graph.links.items():
        if ln.kind == "cxl":
            overrides[key] = (ln.latency_ns * 2.0, ln.bw_GBps * 0.5)
        elif ln.kind == "upi":
            overrides[key] = (ln.latency_ns * 2.0, ln.bw_GBps)
    true_graph = tb.graph.rebuilt(overrides)
    true_tiers = dict(model_tiers)
    true_tiers["CXL"] = dataclasses.replace(
        true_tiers["CXL"],
        peak_bw_GBps=true_tiers["CXL"].peak_bw_GBps * 0.5)
    return model_tiers, tb.graph, true_tiers, true_graph


def test_fit_recovers_perturbed_testbed_exactly():
    model_tiers, model_graph, true_tiers, true_graph = _perturbed_testbed()
    calib = CostModelCalibrator(model_tiers, graph=model_graph)
    n = calib.fit_probes(probe_testbed(true_graph, true_tiers,
                                       origin="socket0"))
    assert n == len(model_tiers) and calib.fitted
    want = true_graph.effective_tiers(true_tiers, "socket0")
    got = calib.calibrated_tiers(origin="socket0")
    for name in want:
        assert got[name].peak_bw_GBps == pytest.approx(
            want[name].peak_bw_GBps, rel=1e-6), name
        assert (got[name].unloaded_latency_ns + got[name].hop_latency_ns
                ) == pytest.approx(
            want[name].unloaded_latency_ns + want[name].hop_latency_ns,
            rel=1e-6), name


def test_fit_without_graph_corrects_descriptor():
    tiers = _tiers()
    calib = CostModelCalibrator(tiers)
    calib.fit_probes([TierProbe("CXL", bw_GBps=19.2, latency_ns=371.0)])
    got = calib.calibrated_tiers()
    assert got["CXL"].peak_bw_GBps == pytest.approx(19.2)
    assert got["CXL"].unloaded_latency_ns == pytest.approx(371.0)
    assert got["LDRAM"] is tiers["LDRAM"]  # unprobed tier untouched


def test_fit_ignores_unknown_tiers_and_bad_probes():
    calib = CostModelCalibrator(_tiers())
    assert calib.fit_probes([TierProbe("NOPE", 10.0),
                             TierProbe("CXL", 0.0)]) == 0
    assert not calib.fitted


# ===================================================================== #
# CostModelCalibrator: online loop                                       #
# ===================================================================== #
def test_online_ratio_converges_to_true_bandwidth():
    tiers = _tiers()
    calib = CostModelCalibrator(tiers, ewma_alpha=0.5)
    # truth: CXL at half speed -> realized/predicted ratio starts at 2
    for _ in range(40):
        view = calib.calibrated_tiers()
        predicted_bw = view["CXL"].peak_bw_GBps
        true_bw = tiers["CXL"].peak_bw_GBps / 2.0
        calib.observe_time_ratio(predicted_bw / true_bw, tiers=["CXL"])
    view = calib.calibrated_tiers()
    assert view["CXL"].peak_bw_GBps == pytest.approx(
        tiers["CXL"].peak_bw_GBps / 2.0, rel=0.02)
    assert view["LDRAM"].peak_bw_GBps == tiers["LDRAM"].peak_bw_GBps


def test_online_ratio_rejects_degenerate_inputs_and_clamps():
    calib = CostModelCalibrator(_tiers(), min_scale=0.1, max_scale=2.0)
    for bad in (0.0, -1.0, float("nan"), float("inf")):
        calib.observe_time_ratio(bad, tiers=["CXL"])
    assert calib.observations == 0
    # unknown tier attribution falls back to the global bucket
    calib.observe_time_ratio(2.0, tiers=["NOPE"])
    assert "*" in calib.online_scale and "NOPE" not in calib.online_scale
    for _ in range(200):
        calib.observe_time_ratio(1000.0, tiers=["CXL"])
    assert calib.online_scale["CXL"] >= 0.1  # clamped, not collapsed


# ===================================================================== #
# Calibrated views threaded through the planners                         #
# ===================================================================== #
def test_plan_step_cost_with_calibrator_prices_measured_numbers():
    model_tiers, model_graph, true_tiers, true_graph = _perturbed_testbed()
    calib = CostModelCalibrator(model_tiers, graph=model_graph)
    calib.fit_probes(probe_testbed(true_graph, true_tiers,
                                   origin="socket0"))
    objs = [DataObject("a", 32 * G, read_bytes_per_step=32 * G)]
    # fixed plan touching the mis-modeled CXL card, so the true price
    # genuinely differs from the builder-default one
    plan = PlacementPlan(shares={"a": [("LDRAM", 0.6), ("CXL", 0.4)]},
                         policy="fixed",
                         tier_bytes={"LDRAM": int(0.6 * 32 * G),
                                     "CXL": int(0.4 * 32 * G)})
    truth = plan_step_cost(objs, plan, true_tiers, topology=true_graph,
                           origin="socket0").phased_s
    calibrated = plan_step_cost(objs, plan, model_tiers,
                                topology=model_graph, origin="socket0",
                                calibrator=calib).phased_s
    uncal = plan_step_cost(objs, plan, model_tiers, topology=model_graph,
                           origin="socket0").phased_s
    assert calibrated == pytest.approx(truth, rel=1e-6)
    assert uncal != pytest.approx(truth, rel=0.01)


def test_executor_recalibrate_reprices_moves():
    model_tiers, model_graph, true_tiers, true_graph = _perturbed_testbed()
    calib = CostModelCalibrator(model_tiers, graph=model_graph)
    calib.fit_probes(probe_testbed(true_graph, true_tiers,
                                   origin="socket0"))
    ex = MigrationExecutor(model_tiers, topology=model_graph)
    old = {"a": [("LDRAM", 1.0)]}
    new = {"a": [("CXL", 1.0)]}
    nb = {"a": 8 * G}
    before = ex.cost_s(ex.delta(old, new, nb))
    ex.calibrator = calib
    ex.recalibrate()
    after = ex.cost_s(ex.delta(old, new, nb))
    ex_true = MigrationExecutor(true_tiers, topology=true_graph)
    truth = ex_true.cost_s(ex_true.delta(old, new, nb))
    # the probe fit splits error between link and descriptor, so path
    # pricing is close to truth rather than bit-exact — but it must be
    # strictly better than the builder defaults and within a few percent
    assert after == pytest.approx(truth, rel=0.05)
    assert abs(after - truth) < abs(before - truth)
    assert before < after                   # slow card now priced slower


def test_executor_audits_only_physical_moves():
    tiers = _tiers()
    led = PredictionLedger()
    ex = MigrationExecutor(tiers, move_fn=lambda o, s, d, n: n)
    ex.audit = led
    d = ex.delta({"a": [("LDRAM", 1.0)]}, {"a": [("CXL", 1.0)]},
                 {"a": G})
    ex.execute(d)
    assert led.predictions == 0             # bookkeeping moves: no audit
    ex.physical_moves = True
    ex.execute(d)
    assert led.predictions == 1 and led.matched == 1
    rec = led.records("migration.move_time")[0]
    assert rec.realized is not None and rec.realized >= 0.0


def test_replanner_audits_step_cost_predictions():
    tiers = _tiers()
    tr = AccessTrace()
    led = PredictionLedger()
    for _ in range(3):
        tr.record("u", read_bytes=80 * G, write_bytes=40 * G)
        tr.advance_epoch()
    rp = AdaptiveReplanner(tr, tiers, "LDRAM",
                           cfg=ReplanConfig(replan_every=1),
                           tenant="t0", audit=led)
    nb = {"u": 40 * G}
    rp.maybe_replan(1, nb)                  # initial adoption: no costs
    rp.maybe_replan(2, nb)                  # files the first prediction
    assert led.pending_count("replan.step_cost") == 1
    tr.record("u", read_bytes=80 * G, write_bytes=40 * G)
    tr.advance_epoch()
    rp.maybe_replan(3, nb)                  # joins it against old_cost
    assert led.matched == 1
    errs = led.rel_errors("replan.step_cost")
    assert len(errs) == 1 and abs(errs[0]) < 0.5


def test_arbiter_audits_demand_and_phase_predictions():
    tiers = _tiers()
    led = ResidencyLedger(tiers, capacity_bytes={"LDRAM": 64 * G})
    tr = AccessTrace()
    led.register_tenant("serve", trace=tr)
    led.register("serve", "kv", {"CXL": 48 * G})
    audit = PredictionLedger()
    arb = TierBudgetArbiter(led, "LDRAM", objective="fair_share",
                            window_epochs=1, predictive=True,
                            audit=audit)

    def emit(burst):
        if burst:
            tr.record("kv", read_bytes=120 * G, write_bytes=2 * G)
        else:
            tr.record("kv", read_bytes=1 * G)
        tr.advance_epoch()

    epoch = 0
    for _ in range(3):                      # learn the 2/6 cycle
        for i in range(8):
            epoch += 1
            arb.rebalance(epoch)
            emit(i < 2)
    assert audit.matched > 0
    models = set(audit.models())
    assert "arbiter.demand" in models
    assert "arbiter.phase" in models
    acc = audit.accuracy("arbiter.phase", tolerance=0.0)
    assert acc is not None and acc > 0.5    # learned cycle mostly hits


# ===================================================================== #
# LagRatioMonitor guards (satellite): empty / zero steady window         #
# ===================================================================== #
def test_lag_ratio_empty_and_zero_windows_return_none():
    mon = LagRatioMonitor(warmup_occurrences=0, steady_from=1)
    assert mon.ratio() is None              # nothing observed at all
    # zero/neg/NaN epoch times are rejected, never divided by
    mon.observe_epoch("p", 100.0, 0.0)
    mon.observe_epoch("p", 100.0, -1.0)
    mon.observe_epoch("p", 100.0, float("nan"))
    assert mon.ratio() is None
    # entry sample exists but the steady window stays empty
    mon2 = LagRatioMonitor(warmup_occurrences=0, steady_from=5)
    mon2.observe_epoch("p", 100.0, 1.0)
    mon2.observe_epoch("p", 100.0, 1.0)
    assert mon2.ratio("p") is None
    # an all-zero steady window yields None, not inf
    mon3 = LagRatioMonitor(warmup_occurrences=0, steady_from=1)
    mon3.observe_epoch("p", 100.0, 1.0)    # entry
    mon3.observe_epoch("p", 0.0, 1.0)      # steady rate 0
    assert mon3.ratio("p") is None


def test_lag_ratio_still_computes_on_good_data():
    mon = LagRatioMonitor(warmup_occurrences=0, steady_from=1)
    for _ in range(2):
        mon.observe_epoch("burst", 50.0, 1.0)   # entry epochs
        mon.observe_epoch("burst", 100.0, 1.0)  # steady epochs
        mon.observe_epoch("lull", 1.0, 1.0)     # phase break
    assert mon.ratio("burst") == pytest.approx(0.5)
