"""Predictive control plane: phase-signature prediction, pre-granted
arbiter budgets, plan prefetch, ledger-driven scheduler preemption, and
cross-tenant move scheduling."""
import dataclasses

import numpy as np

from repro.core import GiB, ObjectLevelInterleave, paper_system, PlacementPlan
from repro.core.migration import BlockMove, MigrationExecutor, PlacementDelta
from repro.pool import (MoveScheduler, PhaseDemandTable, ResidencyLedger,
                        TierBudgetArbiter)
from repro.serving import (ContinuousBatchingScheduler, FAST_KIND,
                           PagedKVPool, Request)
from repro.telemetry import (AccessTrace, AdaptiveReplanner, PhaseDetector,
                             ReplanConfig, traffic_signature)
from repro.topology import two_socket_system

G = GiB


def _tiers(ldram_gib=64):
    t = {k: v for k, v in paper_system("A").items()
         if k in ("LDRAM", "CXL")}
    t["LDRAM"] = dataclasses.replace(t["LDRAM"], capacity_GiB=ldram_gib)
    return t


def _emit(trace, burst):
    """One epoch of burst (hot, heavy) or lull (trickle) traffic."""
    if burst:
        trace.record("kv", read_bytes=120 * G, write_bytes=2 * G)
        trace.record("w", read_bytes=35 * G)
    else:
        trace.record("kv", read_bytes=1 * G)
        trace.record("w", read_bytes=2 * G)
    trace.advance_epoch()


# ===================================================================== #
# PhaseDetector: recurrence signatures + prediction                      #
# ===================================================================== #
def test_signature_separates_intensity_not_just_mix():
    tr = AccessTrace()
    _emit(tr, True)
    burst_sig = traffic_signature(tr.last_completed())
    _emit(tr, False)
    lull_sig = traffic_signature(tr.last_completed())
    # same label (streaming reads), very different intensity
    assert burst_sig != lull_sig
    assert burst_sig[0] == lull_sig[0] == "streaming"


def test_detector_learns_cycle_and_predicts_successor():
    tr = AccessTrace()
    det = PhaseDetector(tr)
    for _ in range(3):                       # 3 cycles of 2-burst/6-lull
        for _ in range(2):
            _emit(tr, True)
            det.update()
        for _ in range(6):
            _emit(tr, False)
            det.update()
    lull_sig = det.signature
    burst_sig = det.likely_successor(lull_sig)
    assert burst_sig is not None and burst_sig != lull_sig
    assert det.typical_duration(lull_sig) == 6
    assert det.typical_duration(burst_sig) == 2
    # we just observed the last lull epoch of cycle 3 (run == 6):
    # the next epoch must flip to the burst signature
    assert det.epochs_in_signature == 6
    assert det.expected_signature(1) == burst_sig
    assert det.expected_signature(2) == burst_sig
    # mid-lull, the phase is expected to continue
    _emit(tr, True)
    det.update()
    _emit(tr, True)
    det.update()
    _emit(tr, False)
    det.update()
    assert det.expected_signature(1) == lull_sig


def test_detector_evicts_stale_signatures():
    tr = AccessTrace()
    det = PhaseDetector(tr, signature_ttl_epochs=4)
    _emit(tr, True)
    det.update()
    old = det.signature
    for _ in range(8):
        _emit(tr, False)
        det.update()
    assert old not in det._sig_seen          # TTL'd out


# ===================================================================== #
# PhaseDemandTable                                                       #
# ===================================================================== #
def test_phase_demand_table_ema_ttl_and_bound():
    t = PhaseDemandTable(ttl_epochs=10, max_entries=2, alpha=0.5)
    t.observe("a", 100, 10.0, epoch=1)
    t.observe("a", 200, 20.0, epoch=2)       # EMA moves halfway
    assert t.lookup("a", 3).hot_bytes == 150
    t.observe("b", 50, 5.0, epoch=3)
    t.observe("c", 70, 7.0, epoch=4)         # bound of 2: oldest evicted
    t.evict_stale(4)
    assert len(t.entries) == 2 and "a" not in t.entries
    assert t.lookup("b", 20) is None         # TTL expired at lookup
    t.evict_stale(20)
    assert not t.entries


# ===================================================================== #
# Predictive arbiter: burst budget granted before the burst             #
# ===================================================================== #
def _cycle_arbiter(predictive):
    tiers = _tiers()
    led = ResidencyLedger(tiers, capacity_bytes={"LDRAM": 64 * G})
    tr = AccessTrace()
    led.register_tenant("serve", trace=tr)
    led.register("serve", "kv", {"CXL": 48 * G})
    led.register("serve", "w", {"CXL": 14 * G})
    arb = TierBudgetArbiter(led, "LDRAM", objective="fair_share",
                            window_epochs=1, predictive=predictive)
    burst_len, lull_len = 2, 6
    grants = []
    epoch = 0
    for _ in range(3):                       # 3 cycles; cycle 3 measured
        for i in range(burst_len + lull_len):
            epoch += 1
            dec = arb.rebalance(epoch)
            grants.append(dec.budget_of("serve"))
            _emit(tr, burst=i < burst_len)
    return grants, burst_len + lull_len


def test_predictive_arbiter_grants_burst_budget_at_entry():
    reactive, period = _cycle_arbiter(False)
    predictive, _ = _cycle_arbiter(True)
    entry = 2 * period                       # cycle-3 burst entry (0-idx)
    steady = 2 * period + 1                  # second burst epoch
    # reactive lags: at burst entry it still grants the lull-sized
    # budget, only the next rebalance sees the burst traffic
    assert reactive[entry] < reactive[steady]
    # predictive pre-grants: entry already gets the burst-sized budget
    assert predictive[entry] >= reactive[steady]
    assert predictive[entry] > 2 * reactive[entry]


def test_predictive_arbiter_falls_back_to_measured():
    tiers = _tiers()
    led = ResidencyLedger(tiers, capacity_bytes={"LDRAM": 64 * G})
    led.register_tenant("quiet")             # no trace at all
    led.register("quiet", "x", {"CXL": 8 * G})
    arb = TierBudgetArbiter(led, "LDRAM", predictive=True)
    dec = arb.rebalance(1)
    assert dec.demands[0].source == "measured"


# ===================================================================== #
# prefetch_phase: proven plans pre-staged for predicted phases           #
# ===================================================================== #
def _burst_replanner():
    tiers = _tiers()
    led = ResidencyLedger(tiers, capacity_bytes={"LDRAM": 64 * G})
    tr = AccessTrace()
    led.register_tenant("serve", trace=tr)
    led.register("serve", "kv", {"CXL": 48 * G}, origin="plan")
    led.register("serve", "w", {"CXL": 14 * G}, origin="plan")
    seed = PlacementPlan({"kv": [("CXL", 1.0)], "w": [("CXL", 1.0)]},
                         "first_touch", {})
    rp = AdaptiveReplanner(
        tr, tiers, "LDRAM",
        policy=ObjectLevelInterleave("LDRAM", ["CXL"],
                                     bandwidth_weighted=True),
        cfg=ReplanConfig(replan_every=1, window_epochs=1,
                         amortize_steps=32),
        executor=MigrationExecutor(tiers), initial_plan=seed,
        default_tier="CXL", ledger=led, tenant="serve")
    return rp, tr, led


def test_prefetch_applies_proven_plan_before_phase():
    rp, tr, led = _burst_replanner()
    nbytes = {"kv": 48 * G, "w": 14 * G}
    _emit(tr, True)
    d = rp.maybe_replan(1, nbytes, phase="burst")
    assert d.applied and d.reason == "win"   # promoted; cached proven
    moved_up = led.bytes_on("LDRAM", "serve")
    assert moved_up > 0
    # phase flips to lull; the mandatory-free path is not triggered
    # (no budget), so the placement drifts back down via a lull replan
    _emit(tr, False)
    d = rp.maybe_replan(2, nbytes, phase="lull")
    if d is not None and d.applied:
        pass                                  # lull plan adopted
    rp.ledger.set_residency("serve", "kv", {"CXL": 48 * G})
    rp.ledger.set_residency("serve", "w", {"CXL": 14 * G})
    rp.plan = PlacementPlan({"kv": [("CXL", 1.0)], "w": [("CXL", 1.0)]},
                            "lull", {})
    # prediction says the burst returns next epoch: pre-stage its plan
    d = rp.prefetch_phase(3, nbytes, "burst")
    assert d is not None and d.applied and d.reason == "prefetch"
    assert led.bytes_on("LDRAM", "serve") == moved_up
    assert rp.prefetches == 1


def test_prefetch_skips_demotion_dominant_and_unknown_phases():
    rp, tr, led = _burst_replanner()
    nbytes = {"kv": 48 * G, "w": 14 * G}
    _emit(tr, True)
    rp.maybe_replan(1, nbytes, phase="burst")     # burst plan proven
    # unknown signature: nothing cached
    assert rp.prefetch_phase(2, nbytes, "never-seen") is None
    # placement already matches the burst plan: nothing to move
    assert rp.prefetch_phase(2, nbytes, "burst") is None
    # a lull plan that mostly releases the fast tier must NOT be
    # pre-staged: demoting early would run the live burst cold
    lull_plan = PlacementPlan({"kv": [("CXL", 1.0)],
                               "w": [("CXL", 1.0)]}, "lull", {})
    rp._phase_plans["lull"] = (lull_plan, True, rp._budget_key())
    assert rp.prefetch_phase(2, nbytes, "lull") is None
    assert rp.prefetches == 0                     # nothing pre-staged


def test_phase_cache_invalidated_when_grant_changes():
    rp, tr, led = _burst_replanner()
    nbytes = {"kv": 48 * G, "w": 14 * G}
    led.set_budget("serve", "LDRAM", 32 * G)
    _emit(tr, True)
    d = rp.maybe_replan(1, nbytes, phase="burst")
    assert d.applied
    cached, proven = rp._cached_plan("burst")
    assert cached is not None and proven
    # the arbiter re-splits: the plan computed under 32G is stale
    led.set_budget("serve", "LDRAM", 48 * G)
    cached, proven = rp._cached_plan("burst")
    assert cached is None


# ===================================================================== #
# Ledger-driven preemption: arbiter shrink -> scheduler eviction         #
# ===================================================================== #
def _running_pool_sched(num_blocks=12, fast_budget=6):
    pool = PagedKVPool(num_blocks, 4, fast_block_budget=fast_budget)
    sched = ContinuousBatchingScheduler(pool)
    reqs = []
    for rid, prio in ((0, 2.0), (1, 0.0), (2, 1.0)):
        r = Request(rid=rid, prompt=np.zeros(6, np.int32),
                    max_new_tokens=4, priority=prio)
        sched.submit(r)
        reqs.append(r)
    admitted = sched.admit()
    assert len(admitted) == 2                   # max_prefill_per_iter
    admitted += sched.admit()
    assert len(admitted) == 3
    for r in reqs:
        pool.alloc(r.rid, 2, kind=FAST_KIND)    # every seq holds fast
    return pool, sched, reqs


def test_budget_shrink_preempts_lowest_priority_first():
    pool, sched, reqs = _running_pool_sched()
    assert sched.preempt_over_budget() == []    # within budget: no-op
    # arbiter shrink: budget drops from 6 to 2 fast blocks
    pool.ledger.set_budget(pool.tenant, FAST_KIND,
                           2 * pool.block_nbytes())
    victims = sched.preempt_over_budget()
    # rid1 (prio 0.0) then rid2 (prio 1.0) evicted; rid0 (2.0) survives
    assert [v.rid for v in victims] == [1, 2]
    assert [r.rid for r in sched.running] == [0]
    assert sched.budget_preemptions == 2
    # the ledger reconciled: eviction freed the fast bytes
    assert pool.ledger.over_budget(pool.tenant, FAST_KIND) == 0
    assert pool.fast_used() == 2
    # victims rejoin the queue front for recompute, LIFO
    assert [r.rid for r in sched.waiting] == [2, 1]
    assert all(r.preemptions == 1 for r in victims)


def test_budget_preemption_stops_when_no_fast_holders():
    pool = PagedKVPool(8, 4, fast_block_budget=4)
    sched = ContinuousBatchingScheduler(pool)
    r = Request(rid=0, prompt=np.zeros(6, np.int32), max_new_tokens=4)
    sched.submit(r)
    sched.admit()
    pool.alloc(0, 2)                            # slow blocks only
    pool.ledger.set_budget(pool.tenant, FAST_KIND, 0)
    assert sched.preempt_over_budget() == []    # nothing to free
    assert sched.running == [r]


# ===================================================================== #
# MoveScheduler: coalescing, ordering, shared-link makespan             #
# ===================================================================== #
def _far_socket():
    tb = two_socket_system("A", cxl_socket=1)
    tiers = {k: v for k, v in tb.tiers.items() if k != "NVMe"}
    return tiers, tb.graph


def test_movesched_serializes_in_priority_order_on_shared_link():
    tiers, graph = _far_socket()
    ex = MigrationExecutor(tiers, topology=graph)
    led = ResidencyLedger(tiers)
    led.register_tenant("hi", weight=2.0)
    led.register_tenant("lo", weight=1.0)
    ms = MoveScheduler(ex, ledger=led)
    # both promotions ride the SAME bottleneck CXL link (and the UPI
    # hop behind it): one shared path, pure serialization
    ms.submit("lo", PlacementDelta([BlockMove("opt", "CXL", "LDRAM",
                                              8 * G)]))
    ms.submit("hi", PlacementDelta([BlockMove("kv", "CXL", "LDRAM",
                                              8 * G)]))
    r = ms.flush(1)
    assert [m.tenant for m in r.moves] == ["hi", "lo"]   # weight order
    hi, lo = r.moves
    # the shared link serializes them: lo queues behind hi's traffic
    # and finishes last, despite being submitted first
    assert hi.start_s == 0.0
    assert lo.start_s > 0.0
    assert lo.finish_s > hi.finish_s
    assert r.makespan_s <= r.independent_s * (1 + 1e-9)
    assert r.tenant_finish_s("hi") < r.tenant_finish_s("lo")


def test_movesched_batched_beats_independent_on_partial_overlap():
    # hi's move bottlenecks on the (serve-only) CXL link; lo's rides
    # the shared UPI — batching overlaps the disjoint portions, so the
    # round is strictly faster than per-tenant execution
    tiers, graph = _far_socket()
    ex = MigrationExecutor(tiers, topology=graph)
    ms = MoveScheduler(ex)
    ms.submit("hi", PlacementDelta([BlockMove("kv", "CXL", "LDRAM",
                                              16 * G)]), priority=2.0)
    ms.submit("lo", PlacementDelta([BlockMove("opt", "RDRAM", "LDRAM",
                                              16 * G)]), priority=1.0)
    r = ms.flush(1)
    assert r.makespan_s < r.independent_s * 0.999


def test_movesched_coalesces_same_direction_and_nets_opposing():
    tiers, graph = _far_socket()
    ms = MoveScheduler(MigrationExecutor(tiers, topology=graph))
    ms.submit("t", PlacementDelta([
        BlockMove("kv", "CXL", "LDRAM", 6 * G),
        BlockMove("kv", "CXL", "LDRAM", 2 * G),     # merges
        BlockMove("kv", "LDRAM", "CXL", 3 * G),     # nets away
    ]))
    r = ms.flush(1)
    assert len(r.moves) == 1
    assert r.moves[0].move == BlockMove("kv", "CXL", "LDRAM", 5 * G)
    assert r.coalesced_bytes == 6 * G


def test_movesched_demotions_first_at_equal_priority():
    tiers, graph = _far_socket()
    ms = MoveScheduler(MigrationExecutor(tiers, topology=graph))
    ms.submit("a", PlacementDelta([BlockMove("x", "CXL", "LDRAM", G)]))
    ms.submit("b", PlacementDelta([BlockMove("y", "LDRAM", "CXL", G)]))
    r = ms.flush(1)
    # b's demotion frees contended fast capacity before a's promotion
    assert [m.tenant for m in r.moves] == ["b", "a"]


def test_movesched_preempts_for_urgent_mid_round_arrival():
    """A strictly-higher-priority delta submitted from inside a move_fn
    splices ahead of the interrupted tenant's remaining blocks, which
    then resume — with the counter and round record reflecting it."""
    tiers, graph = _far_socket()
    ms = MoveScheduler(MigrationExecutor(tiers, topology=graph))
    order = []

    def hi_fn(obj, src, dst, nb):
        order.append(("hi", obj))
        return nb

    def lo_fn(obj, src, dst, nb):
        order.append(("lo", obj))
        if obj == "lo.b0":            # emergency lands mid-copy
            ms.submit("hi", PlacementDelta(
                [BlockMove("hi.kv", "CXL", "LDRAM", G)]),
                move_fn=hi_fn, priority=5.0)
        return nb

    ms.submit("lo", PlacementDelta(
        [BlockMove(f"lo.b{i}", "CXL", "LDRAM", G) for i in range(3)]),
        move_fn=lo_fn, priority=1.0)
    r = ms.flush(1)
    assert [t for t, _ in order] == ["lo", "hi", "lo", "lo"]
    assert ms.preemptions == 1
    assert ms.summary()["preemptions"] == 1.0
    assert len(r.moves) == 4          # the spliced move joins the round
    assert not ms.has_pending         # urgent delta was consumed


def test_movesched_equal_priority_arrival_waits_for_next_flush():
    tiers, graph = _far_socket()
    ms = MoveScheduler(MigrationExecutor(tiers, topology=graph))
    order = []

    def lo_fn(obj, src, dst, nb):
        order.append(obj)
        if obj == "a.b0":
            ms.submit("peer", PlacementDelta(
                [BlockMove("peer.x", "CXL", "LDRAM", G)]), priority=1.0)
        return nb

    ms.submit("a", PlacementDelta(
        [BlockMove(f"a.b{i}", "CXL", "LDRAM", G) for i in range(2)]),
        move_fn=lo_fn, priority=1.0)
    r1 = ms.flush(1)
    assert ms.preemptions == 0
    assert order == ["a.b0", "a.b1"]  # no splice at equal priority
    assert len(r1.moves) == 2
    assert ms.has_pending             # queued for the next round
    r2 = ms.flush(2)
    assert [m.move.obj for m in r2.moves] == ["peer.x"]


def test_movesched_chunked_copy_preempts_inside_one_block():
    """chunk_bytes gives preemption points inside a single long copy;
    on_done still reports the original move with its bytes summed and
    stats count the object's promotion once."""
    from repro.core.migration import MigrationStats
    tiers, graph = _far_socket()
    ms = MoveScheduler(MigrationExecutor(tiers, topology=graph))
    order, realized = [], []
    stats = MigrationStats()

    def hi_fn(obj, src, dst, nb):
        order.append(("hi", nb))
        return nb

    def lo_fn(obj, src, dst, nb):
        order.append(("lo", nb))
        if len(order) == 1:
            ms.submit("hi", PlacementDelta(
                [BlockMove("hi.kv", "CXL", "LDRAM", G)]),
                move_fn=hi_fn, priority=9.0)
        return nb

    ms.submit("lo", PlacementDelta(
        [BlockMove("lo.big", "CXL", "LDRAM", 4 * G)]),
        move_fn=lo_fn, priority=1.0, chunk_bytes=2 * G,
        on_done=lambda moves: realized.extend(moves), stats=stats)
    ms.flush(1)
    # first 2G chunk, then the urgent move, then the copy's remainder
    assert order == [("lo", 2 * G), ("hi", G), ("lo", 2 * G)]
    assert ms.preemptions == 1
    assert len(realized) == 1
    move, done = realized[0]
    assert move == BlockMove("lo.big", "CXL", "LDRAM", 4 * G)
    assert done == 4 * G
    assert stats.promoted == 1        # once per object, not per chunk
    assert stats.migrated_bytes == 4 * G


def test_movesched_runs_deferred_replanner_callbacks():
    tiers = _tiers()
    led = ResidencyLedger(tiers, capacity_bytes={"LDRAM": 64 * G})
    ms = MoveScheduler(MigrationExecutor(tiers), ledger=led)
    tr = AccessTrace()
    led.register_tenant("serve", trace=tr)
    led.register("serve", "kv", {"CXL": 48 * G}, origin="plan")
    led.register("serve", "w", {"CXL": 14 * G}, origin="plan")
    seed = PlacementPlan({"kv": [("CXL", 1.0)], "w": [("CXL", 1.0)]},
                         "first_touch", {})
    rp = AdaptiveReplanner(
        tr, tiers, "LDRAM",
        policy=ObjectLevelInterleave("LDRAM", ["CXL"],
                                     bandwidth_weighted=True),
        cfg=ReplanConfig(replan_every=1, window_epochs=1,
                         amortize_steps=32),
        executor=MigrationExecutor(tiers), initial_plan=seed,
        default_tier="CXL", ledger=led, tenant="serve",
        move_scheduler=ms)
    _emit(tr, True)
    d = rp.maybe_replan(1, {"kv": 48 * G, "w": 14 * G}, phase="burst")
    assert d.applied and d.deferred
    assert d.moved_bytes == 0                   # not executed yet
    assert led.bytes_on("LDRAM", "serve") == 0
    # residency is not adopted until the flush: a second replan (or
    # prefetch) before it must not re-derive and double-submit the
    # same delta
    assert rp.maybe_replan(1, {"kv": 48 * G, "w": 14 * G},
                           phase="burst") is None
    assert rp.prefetch_phase(1, {"kv": 48 * G, "w": 14 * G},
                             "burst") is None
    assert ms.pending_moves == 2                # still one submission
    r = ms.flush(1)
    assert d.moved_bytes > 0                    # callback adopted moves
    assert led.bytes_on("LDRAM", "serve") == d.moved_bytes
    assert r.moved_bytes("serve") == d.moved_bytes
    # the live plan is the realized residency
    assert dict(rp.plan.shares)["kv"]
