"""ExpertPool: per-expert heat, tier residency, predictive prefetch."""
import pytest

from repro.core.migration import MigrationExecutor
from repro.pool import MoveScheduler
from repro.serving import ExpertPool, FAST_KIND, PagedKVPool
from repro.serving.engine import kind_tiers

NB = 1 << 20                           # one expert's weight bytes


def _pool(policy="lru", budget=4, n_experts=8, n_layers=2, **kw):
    return ExpertPool(n_layers=n_layers, n_experts=n_experts,
                      expert_nbytes=NB, fast_expert_budget=budget,
                      policy=policy, **kw)


def test_expert_pool_validates_args():
    with pytest.raises(ValueError, match="policy"):
        _pool(policy="clock")
    with pytest.raises(ValueError):
        ExpertPool(0, 8, NB, fast_expert_budget=2)
    with pytest.raises(ValueError):
        ExpertPool(2, 8, 0, fast_expert_budget=2)


def test_expert_pool_heat_accounting_per_expert():
    p = _pool()
    p.record_routing(0, [1, 1, 3], step=0)
    p.record_routing(1, [5], step=0)
    assert p.counters.accesses == 4
    assert p.counters.fast_hits == 0          # everyone starts slow
    assert p.touch_count[(0, 1)] == 2
    assert p.touch_count[(0, 3)] == 1
    assert p.touch_count[(1, 5)] == 1
    assert p.last_step[(1, 5)] == 0
    assert (0, 5) not in p.touch_count        # layers are independent
    # each activation is one read of the expert's weight block
    assert p.trace.total_events == 4
    assert sum(t.total_bytes
               for t in p.trace._current.values()) == 4 * NB


def test_expert_pool_lru_promotes_recent_within_budget():
    p = _pool(budget=3)
    p.record_routing(0, [0, 1, 2, 3, 4], step=0)
    p.step(0)
    # only the budget's worth promoted, all on the fast tier
    assert p.fast_residents() == 3
    assert p.counters.promoted == 3
    assert p.ledger.bytes_on(FAST_KIND, "experts") == 3 * NB
    # the most recently routed experts win the slots
    p.record_routing(0, [6, 7], step=1)
    p.step(1)
    assert p.kind_of(0, 6) == FAST_KIND
    assert p.kind_of(0, 7) == FAST_KIND
    assert p.fast_residents() == 3
    assert p.counters.demoted == 2
    # hits now land fast and the ratio reflects them
    p.record_routing(0, [6, 7], step=2)
    assert p.counters.fast_hits == 2
    assert 0 < p.fast_hit_ratio() < 1


def test_expert_pool_budget_never_exceeded_under_churn():
    p = _pool(budget=2, n_experts=16, n_layers=1)
    for s in range(12):
        p.record_routing(0, [(s * 3 + i) % 16 for i in range(4)], step=s)
        p.step(s)
        assert p.fast_residents() <= 2
        assert p.ledger.bytes_on(FAST_KIND, "experts") <= 2 * NB


def test_expert_pool_predictive_prefetches_recurring_phase():
    """Alternating routing phases: after the recurrence is learned, the
    next phase's experts are promoted ahead and then hit while fast."""
    p = _pool(policy="predictive", budget=4, n_experts=16, n_layers=1)
    phase_a, phase_b = [0, 1, 2, 3], [8, 9, 10, 11]
    epoch = 0
    for _ in range(6):                 # several full A->B->A cycles
        for phase in (phase_a, phase_b):
            for _ in range(3):
                for s in range(4):
                    p.record_routing(0, phase, step=epoch)
                p.step(epoch)
                epoch += 1
    assert p.counters.prefetch_promotes > 0
    assert p.counters.prefetch_hits > 0
    assert p.prefetch_hit_ratio() > 0.5
    s = p.summary()
    assert s["expert.prefetch_promotes"] == p.counters.prefetch_promotes
    assert s["expert.prefetch_hit_ratio"] == p.prefetch_hit_ratio()
    # predictive beats what pure recency would have served
    assert p.fast_hit_ratio() > 0.5


def test_expert_pool_lru_never_counts_prefetch():
    p = _pool(policy="lru", budget=2, n_experts=8, n_layers=1)
    for e in range(8):
        p.record_routing(0, [e % 8, (e + 1) % 8], step=e)
        p.step(e)
    assert p.counters.prefetch_promotes == 0
    assert p.prefetch_hit_ratio() is None
    assert "expert.prefetch_hit_ratio" not in p.summary()


def test_expert_pool_moves_flow_through_movesched():
    ms = MoveScheduler(MigrationExecutor(kind_tiers(PagedKVPool(4, 4))))
    p = _pool(budget=2, movesched=ms)
    p.record_routing(0, [0, 1], step=0)
    p.step(0)
    assert p.fast_residents() == 2
    assert len(ms.rounds) == 1
    assert ms.rounds[0].moved_bytes("experts") == 2 * NB
    objs = {sm.move.obj for sm in ms.rounds[0].moves}
    assert objs == {"expert.L0.E0", "expert.L0.E1"}


def test_expert_pool_gather_flows_class_tagged():
    from repro.topology import TopologyGraph
    g = TopologyGraph("pcie", origin="hbm")
    g.add_node("hbm", "chip", tier=FAST_KIND)
    g.add_node("host", "host", tier="pinned_host")
    g.add_link("hbm", "host", 600.0, 32.0, "pcie")

    p = _pool(policy="predictive", budget=2, n_experts=8, n_layers=1)
    p.record_routing(0, [0, 1, 2], step=0)   # all slow: 3 misses
    assert p.gather_flows(g) == []           # epoch not closed yet
    p.step(0)
    flows = p.gather_flows(g, period_s=0.1)
    assert len(flows) == 1                   # no prefetch yet
    f = flows[0]
    assert f.cls == "read" and f.tenant == "experts"
    assert f.offered_GBps == pytest.approx(3 * NB / 0.1 / 1e9)
    # a second epoch with no misses publishes nothing
    p.record_routing(0, [0, 1], step=1)
    p.step(1)
    assert all(fl.cls != "read" for fl in p.gather_flows(g))
