"""Tests run on the single real CPU device (no forced device count —
the 512-device override belongs ONLY to the dry-run)."""
import os

# keep any externally-set XLA_FLAGS from leaking a device-count override
flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" in flags:
    os.environ["XLA_FLAGS"] = " ".join(
        f for f in flags.split() if "host_platform_device_count" not in f)

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
