"""Tests run on the single real CPU device (no forced device count —
the 512-device override belongs ONLY to the dry-run)."""
import os

# keep any externally-set XLA_FLAGS from leaking a device-count override
flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" in flags:
    os.environ["XLA_FLAGS"] = " ".join(
        f for f in flags.split() if "host_platform_device_count" not in f)

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


def dual_cxl_machine():
    """Shared fixture: system-A-like box with one DRAM node and one CXL
    card behind EACH socket — used to exercise origin-dependent tier
    ordering and disjoint-path move overlap."""
    import dataclasses

    from repro.core import MemoryTier
    from repro.topology import TopologyGraph

    g = TopologyGraph("dual-cxl", origin="socket0")
    g.add_node("socket0")
    g.add_node("socket1")
    g.add_node("numa0", kind="numa", tier="DRAM0")
    g.add_node("numa1", kind="numa", tier="DRAM1")
    g.add_node("cxl0", kind="cxl", tier="CXL0")
    g.add_node("cxl1", kind="cxl", tier="CXL1")
    g.add_link("socket0", "numa0", 0.0, 460.8, kind="local")
    g.add_link("socket1", "numa1", 0.0, 460.8, kind="local")
    g.add_link("socket0", "socket1", 87.0, 230.0, kind="upi")
    g.add_link("socket0", "cxl0", 153.0, 38.4, kind="cxl")
    g.add_link("socket1", "cxl1", 153.0, 38.4, kind="cxl")
    dram = MemoryTier("DRAM0", 118, 460.8, 22.0, 256, kind="dram")
    cxl = MemoryTier("CXL0", 118, 38.4, 9.0, 128, kind="cxl")
    tiers = {
        "DRAM0": dram,
        "DRAM1": dataclasses.replace(dram, name="DRAM1"),
        "CXL0": cxl,
        "CXL1": dataclasses.replace(cxl, name="CXL1"),
    }
    return g, tiers
