"""End-to-end train.py --adaptive: the replanner's decisions must land
as *real* optimizer-state moves in the residency ledger (ROADMAP:
executing replans for training state)."""
import pytest

from repro.launch import train as train_cli


def _run(argv):
    return train_cli.main(argv)


def test_train_adaptive_migrates_opt_state_into_ledger(capsys):
    telem = _run(["--arch", "llama3-8b", "--smoke", "--steps", "6",
                  "--batch", "2", "--seq", "32",
                  "--adaptive", "--replan-every", "2"])
    assert telem is not None
    led = telem.ledger
    # real moves happened and were recorded
    assert led.counters.migrated_bytes > 0
    assert telem.replanner.replans_applied >= 1
    # the ledger's placement is consistent with the applied plan: the
    # hot fp32 state won fast-tier residency
    fast_bytes = telem.opt_bytes_on(telem.fast)
    assert fast_bytes > 0
    place = led.placement(telem.tenant, telem.OPT_OBJ)
    assert sum(place.values()) == telem.store.nbytes(telem.OPT_OBJ)
    plan_fast = telem.replanner.plan.fraction_on(telem.OPT_OBJ,
                                                 telem.fast)
    got_fast = fast_bytes / telem.store.nbytes(telem.OPT_OBJ)
    assert got_fast == pytest.approx(plan_fast, abs=0.05)
    # the physical store agrees with the ledger (single source of truth)
    assert telem.store.bytes_on(telem.OPT_OBJ, telem.fast) == fast_bytes
    out = capsys.readouterr().out
    assert "opt_state moved=" in out


def test_train_without_adaptive_returns_no_telemetry():
    telem = _run(["--arch", "llama3-8b", "--smoke", "--steps", "1",
                  "--batch", "2", "--seq", "16"])
    assert telem is None


@pytest.mark.parametrize("flags", [
    ["--replan-every", "4"],
    ["--sample-rate", "0.5"],
])
def test_train_adaptive_knobs_require_adaptive(flags):
    """Bugfix: --replan-every / --sample-rate without --adaptive used
    to be silently accepted; they must error like --topology does."""
    with pytest.raises(SystemExit):
        _run(["--arch", "llama3-8b", "--smoke", "--steps", "1"] + flags)


def test_train_topology_still_requires_adaptive():
    with pytest.raises(SystemExit):
        _run(["--arch", "llama3-8b", "--smoke", "--steps", "1",
              "--topology", "vendor-a"])


def test_train_tenant_requires_adaptive():
    with pytest.raises(SystemExit):
        _run(["--arch", "llama3-8b", "--smoke", "--steps", "1",
              "--tenant", "team-a"])
