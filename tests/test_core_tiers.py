"""Tier performance models: reproduce the paper's §III characterization."""
import pytest
from _hyp import given, st

from repro.core import (assign_streams, interleave_bandwidth, MemoryTier,
                        paper_system, tpu_v5e_tiers)


@pytest.mark.parametrize("sys", ["A", "B", "C"])
def test_cxl_latency_is_two_hop(sys):
    """Fig. 2: CXL latency ≈ two NUMA hops (worse than RDRAM's one hop)."""
    t = paper_system(sys)
    hop = t["RDRAM"].unloaded_latency_ns - t["LDRAM"].unloaded_latency_ns
    cxl_delta = t["CXL"].unloaded_latency_ns - t["LDRAM"].unloaded_latency_ns
    assert cxl_delta > hop, "CXL must be slower than one hop"
    assert cxl_delta < 3.5 * hop, "CXL ≈ two-hop distance"


@pytest.mark.parametrize("sys", ["A", "B", "C"])
def test_cxl_saturates_early(sys):
    """Fig. 3: CXL bandwidth saturates by ~4-8 streams; DRAM much later."""
    t = paper_system(sys)
    cxl, ld = t["CXL"], t["LDRAM"]
    # 8 streams reach >=85% of peak on CXL (dual-channel CXL-C is latest)
    assert cxl.bandwidth(8) >= 0.85 * cxl.peak_bw_GBps
    # LDRAM at 8 streams is far from peak
    assert ld.bandwidth(8) < 0.8 * ld.peak_bw_GBps


def test_cxl_bandwidth_ratio_range():
    """Sec. I: CXL peak is 9.8%-80.3% of local DRAM across vendors."""
    for sysname in "ABC":
        t = paper_system(sysname)
        ratio = t["CXL"].peak_bw_GBps / t["LDRAM"].peak_bw_GBps
        assert 0.05 <= ratio <= 0.85


def test_loaded_latency_blowup():
    """Fig. 4: near peak bandwidth, LDRAM latency approaches CXL levels."""
    t = paper_system("A")
    ld = t["LDRAM"]
    unloaded = ld.loaded_latency(0.0)
    loaded = ld.loaded_latency(0.97 * ld.peak_bw_GBps)
    assert loaded > 3 * unloaded
    # loaded LDRAM is in the ballpark of (or worse than) unloaded CXL
    assert loaded > t["CXL"].unloaded_latency_ns


def test_stream_assignment_matches_paper_shape():
    """Sec. III: optimal assignment gives CXL few streams, DRAM many
    (the paper's 6/23/23 trick on system B)."""
    t = {k: v for k, v in paper_system("B").items() if k != "NVMe"}
    alloc, agg = assign_streams(t, 52)
    assert alloc["CXL"] <= 8
    assert alloc["LDRAM"] >= 15 and alloc["RDRAM"] >= 15
    # aggregate beats any single tier's peak
    assert agg > t["LDRAM"].peak_bw_GBps


def test_uniform_interleave_gated_by_slow_tier():
    """Sec. V takeaway: uniform interleave can undermine performance —
    a slow CXL serving an equal share gates the aggregate."""
    t = paper_system("A")
    both = interleave_bandwidth({"LDRAM": t["LDRAM"], "CXL": t["CXL"]})
    assert both < t["LDRAM"].peak_bw_GBps
    # bandwidth-proportional shares recover aggregate bandwidth
    w = {"LDRAM": 0.92, "CXL": 0.08}
    prop = interleave_bandwidth({"LDRAM": t["LDRAM"], "CXL": t["CXL"]}, w)
    assert prop > both


def test_tpu_tiers_sane():
    t = tpu_v5e_tiers()
    assert t["HBM"].peak_bw_GBps > 30 * t["HOST"].peak_bw_GBps
    assert t["HOST"].capacity_GiB > t["HBM"].capacity_GiB


@given(st.floats(0.1, 64.0))
def test_bandwidth_monotone(streams):
    tier = paper_system("A")["CXL"]
    assert tier.bandwidth(streams) <= tier.bandwidth(streams + 1) + 1e-9
    assert 0 <= tier.bandwidth(streams) <= tier.peak_bw_GBps + 1e-9


@given(st.floats(0.0, 1.0))
def test_loaded_latency_monotone(frac):
    tier = paper_system("B")["LDRAM"]
    lo = tier.loaded_latency(frac * tier.peak_bw_GBps * 0.9)
    hi = tier.loaded_latency(min((frac + 0.05), 1.0)
                             * tier.peak_bw_GBps * 0.9)
    assert hi >= lo - 1e-9


def test_stream_assignment_topology_caps_shared_bottleneck():
    """Topology-aware assign_streams: two tiers behind one narrow
    shared link cannot both water-fill — the link caps their combined
    marginal gain, so streams route to the independent local tier."""
    from repro.topology import TopologyGraph

    local = MemoryTier("LOCAL", 110, 200.0, 20.0, 256, kind="dram")
    far_a = MemoryTier("FAR_A", 110, 200.0, 20.0, 256, kind="dram")
    far_b = MemoryTier("FAR_B", 110, 200.0, 20.0, 256, kind="dram")
    tiers = {"LOCAL": local, "FAR_A": far_a, "FAR_B": far_b}

    g = TopologyGraph("shared-upi", origin="s0")
    g.add_node("s0", "socket")
    g.add_node("n_local", "numa", tier="LOCAL")
    g.add_node("s1", "socket")
    g.add_node("n_a", "numa", tier="FAR_A")
    g.add_node("n_b", "numa", tier="FAR_B")
    g.add_link("s0", "n_local", 0.0, 500.0, "local")
    g.add_link("s0", "s1", 90.0, 60.0, "upi")      # narrow shared hop
    g.add_link("s1", "n_a", 0.0, 500.0, "local")
    g.add_link("s1", "n_b", 0.0, 500.0, "local")

    flat_alloc, flat_agg = assign_streams(tiers, 30)
    topo_alloc, topo_agg = assign_streams(tiers, 30, topology=g)
    # flat water-filling splits streams evenly over identical tiers
    assert flat_alloc["FAR_A"] + flat_alloc["FAR_B"] >= 18
    # behind the 60 GB/s link, far streams stop paying once it is full:
    # the local tier gets the majority of streams instead
    assert topo_alloc["LOCAL"] > flat_alloc["LOCAL"]
    assert topo_alloc["LOCAL"] > topo_alloc["FAR_A"] + topo_alloc["FAR_B"]
    # delivered aggregate is honest: local peak + the link's capacity
    assert topo_agg <= local.peak_bw_GBps + 60.0 + 1e-6
    assert topo_agg < flat_agg
