"""benchmarks/run.py CLI contract: --list, unknown names fail loudly."""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RUN = os.path.join(ROOT, "benchmarks", "run.py")


def _run(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    return subprocess.run([sys.executable, RUN, *args], env=env,
                          capture_output=True, text=True, timeout=120)


def test_list_prints_all_modules():
    r = _run("--list")
    assert r.returncode == 0
    names = r.stdout.split()
    assert "tier_characterization" in names
    assert "adaptive_replan_bench" in names
    assert "multi_tenant_bench" in names


def test_unknown_benchmark_fails_loudly():
    r = _run("definitely_not_a_benchmark")
    assert r.returncode == 2
    assert "unknown benchmark" in r.stderr
    assert "tier_characterization" in r.stderr   # lists what exists


def test_unknown_mixed_with_known_still_fails():
    r = _run("tier_characterization", "typo")
    assert r.returncode == 2
    assert "typo" in r.stderr
