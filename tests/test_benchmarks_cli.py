"""benchmarks/run.py CLI contract: --list, unknown names fail loudly."""
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RUN = os.path.join(ROOT, "benchmarks", "run.py")


def _run(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    return subprocess.run([sys.executable, RUN, *args], env=env,
                          capture_output=True, text=True, timeout=120)


def test_list_prints_all_modules():
    r = _run("--list")
    assert r.returncode == 0
    names = r.stdout.split()
    assert "tier_characterization" in names
    assert "adaptive_replan_bench" in names
    assert "multi_tenant_bench" in names


def test_unknown_benchmark_fails_loudly():
    r = _run("definitely_not_a_benchmark")
    assert r.returncode == 2
    assert "unknown benchmark" in r.stderr
    assert "tier_characterization" in r.stderr   # lists what exists


def test_unknown_mixed_with_known_still_fails():
    r = _run("tier_characterization", "typo")
    assert r.returncode == 2
    assert "typo" in r.stderr


def test_json_artifact_schema(tmp_path):
    out = tmp_path / "bench.json"
    r = _run("--smoke", "--json", str(out), "tier_characterization")
    assert r.returncode == 0
    payload = json.loads(out.read_text())
    assert payload["schema_version"] == 1
    assert payload["smoke"] is True
    assert payload["totals"]["benchmarks"] == 1
    assert payload["totals"]["failed"] == 0
    (entry,) = payload["benchmarks"]
    assert entry["name"] == "tier_characterization"
    assert entry["status"] == "ok"
    assert entry["wall_s"] >= 0
    assert entry["metrics"], "metric rows must be captured"
    row = entry["metrics"][0]
    assert set(row) == {"name", "value", "unit"}
    # the CSV stdout and the artifact agree on the row count
    csv_rows = [l for l in r.stdout.splitlines() if "," in l]
    assert len(csv_rows) == len(entry["metrics"])
