"""repro.telemetry: trace, sampler, phase detection, adaptive replan."""
import dataclasses

import numpy as np
import pytest

from repro.core import GiB, ObjectLevelInterleave, paper_system
from repro.core.migration import (migration_time_s, MigrationExecutor,
                                  MigrationStats)
from repro.telemetry import (AccessSampler, AccessTrace, AdaptiveReplanner,
                             classify_traffic, PhaseDetector, ReplanConfig,
                             SamplerConfig, traffic_distance)

G = GiB


def _tiers(ldram_gib=96):
    t = {k: v for k, v in paper_system("A").items()
         if k in ("LDRAM", "CXL")}
    t["LDRAM"] = dataclasses.replace(t["LDRAM"], capacity_GiB=ldram_gib)
    return t


# ---------------------------------------------------------------------- #
# events                                                                  #
# ---------------------------------------------------------------------- #
def test_trace_epoch_buckets_and_aggregation():
    tr = AccessTrace()
    tr.record("a", read_bytes=100, write_bytes=50, random_fraction=0.5)
    tr.record("a", read_bytes=100)
    tr.record("b", write_bytes=30, phase="prefill")
    tr.advance_epoch()
    tr.record("a", read_bytes=200)
    tr.advance_epoch()
    agg = tr.object_traffic()
    assert agg["a"].read_bytes == 400
    assert agg["a"].write_bytes == 50
    assert agg["a"].epochs == 2
    assert agg["a"].read_bytes_per_epoch == 200
    assert agg["b"].write_bytes == 30
    assert tr.phase_events == {"prefill": 1}
    # windowed view sees only the newest epoch
    last = tr.object_traffic(window=1)
    assert last["a"].read_bytes == 200 and "b" not in last


def test_trace_ring_buffer_drops_oldest():
    tr = AccessTrace(capacity_epochs=4)
    for i in range(10):
        tr.record("x", read_bytes=i + 1)
        tr.advance_epoch()
    assert tr.epochs_recorded == 4
    assert tr.dropped_epochs == 6
    # only epochs 6..9 (values 7..10) survive
    assert tr.object_traffic()["x"].read_bytes == 7 + 8 + 9 + 10


def test_trace_zero_byte_events_ignored():
    tr = AccessTrace()
    tr.record("a", read_bytes=0, write_bytes=0)
    assert tr.total_events == 0


def test_to_data_objects_covers_cold_objects():
    tr = AccessTrace()
    tr.record("hot", read_bytes=10 * G, random_fraction=0.8)
    tr.advance_epoch()
    objs = tr.to_data_objects({"hot": 20 * G, "cold": 5 * G},
                              pin_fast=["cold"])
    by = {o.name: o for o in objs}
    assert by["hot"].read_bytes_per_step == 10 * G
    assert by["hot"].random_fraction == pytest.approx(0.8)
    assert by["cold"].bytes_per_step == 0
    assert by["cold"].pin_fast


# ---------------------------------------------------------------------- #
# sampler                                                                 #
# ---------------------------------------------------------------------- #
def test_sampler_estimate_accuracy_and_overhead():
    tr = AccessTrace()
    sm = AccessSampler(tr, SamplerConfig(sample_rate=1e-6))
    true_bytes = 0
    for _ in range(8):
        sm.observe("u", read_bytes=10 * G, write_bytes=2 * G)
        true_bytes += 12 * G
        sm.advance_epoch()
    got = tr.object_traffic()["u"].total_bytes
    assert got == pytest.approx(true_bytes, rel=0.02)
    # overhead: one cost per sample, samples ~ lines * rate
    exp_samples = true_bytes / 64 * 1e-6
    assert sm.samples == pytest.approx(exp_samples, rel=0.02)
    assert sm.overhead_s == pytest.approx(sm.samples * 2e-6)


def test_sampler_deterministic_carry_accumulates_small_events():
    tr = AccessTrace()
    sm = AccessSampler(tr, SamplerConfig(sample_rate=0.01))
    # each event is far below one sample period; the carry must still
    # record the aggregate eventually
    for _ in range(1000):
        sm.observe("tiny", read_bytes=640)   # 10 lines -> 0.1 samples
    sm.advance_epoch()
    assert tr.object_traffic()["tiny"].read_bytes == pytest.approx(
        640_000, rel=0.05)


def test_sampler_full_rate_is_exact():
    tr = AccessTrace()
    sm = AccessSampler(tr, SamplerConfig(sample_rate=1.0))
    sm.observe("a", read_bytes=4096, write_bytes=128)
    sm.advance_epoch()
    t = tr.object_traffic()["a"]
    assert t.read_bytes == 4096 and t.write_bytes == 128
    assert sm.overhead_s > 0


def test_sampler_tier_cost_scales_overhead():
    cheap = AccessSampler(AccessTrace(), SamplerConfig(sample_rate=1.0))
    tiers = _tiers()
    costly = AccessSampler(AccessTrace(), SamplerConfig(
        sample_rate=1.0, tier=tiers["CXL"]))
    cheap.observe("a", read_bytes=64 * 100)
    costly.observe("a", read_bytes=64 * 100)
    assert costly.overhead_s > cheap.overhead_s


def test_sampler_rejects_nonpositive_rate():
    with pytest.raises(ValueError):
        SamplerConfig(sample_rate=0.0)


def test_sampler_forget_prunes_carry_state():
    tr = AccessTrace()
    sm = AccessSampler(tr, SamplerConfig(sample_rate=0.01))
    for i in range(100):
        sm.observe(f"seq{i}", read_bytes=640, write_bytes=640)
        sm.forget(f"seq{i}")
    assert len(sm._carry) == 0
    # live objects keep their carry
    sm.observe("live", read_bytes=640)
    assert len(sm._carry) == 1


# ---------------------------------------------------------------------- #
# phases                                                                  #
# ---------------------------------------------------------------------- #
def _emit_epoch(tr, spec):
    for obj, (r, w, rf) in spec.items():
        tr.record(obj, read_bytes=r, write_bytes=w, random_fraction=rf)
    tr.advance_epoch()


def test_classify_traffic_labels():
    tr = AccessTrace()
    _emit_epoch(tr, {"a": (100 * G, 0, 0.0)})
    assert classify_traffic(tr.last_completed()) == "streaming"
    _emit_epoch(tr, {"a": (10 * G, 0, 0.9)})
    assert classify_traffic(tr.last_completed()) == "random"
    _emit_epoch(tr, {"a": (10 * G, 10 * G, 0.0)})
    assert classify_traffic(tr.last_completed()) == "write_heavy"
    assert classify_traffic({}) == "idle"


def test_traffic_distance_bounds():
    assert traffic_distance({"a": 1.0}, {"a": 1.0}) == 0.0
    assert traffic_distance({"a": 1.0}, {"b": 1.0}) == pytest.approx(1.0)


def test_phase_detector_fires_on_shift_and_debounces():
    tr = AccessTrace()
    det = PhaseDetector(tr, threshold=0.35, min_phase_epochs=2)
    shifts = []
    for _ in range(5):
        _emit_epoch(tr, {"u": (50 * G, 10 * G, 0.0)})
        s = det.update()
        if s:
            shifts.append(s)
    assert not shifts                      # stable phase: no shift
    for _ in range(5):
        _emit_epoch(tr, {"a": (20 * G, 0, 0.9)})
        s = det.update()
        if s:
            shifts.append(s)
    assert len(shifts) == 1                # one boundary, debounced
    assert shifts[0].new_label == "random"
    assert det.label == "random"
    assert det.phase_id == 1


def test_phase_detector_idle_epochs_do_not_flap():
    tr = AccessTrace()
    det = PhaseDetector(tr, min_phase_epochs=1)
    for _ in range(3):
        _emit_epoch(tr, {"u": (10 * G, 0, 0.0)})
        det.update()
    tr.advance_epoch()                     # empty epoch
    det.update()
    assert det.label == "streaming" or det.label == "idle"
    assert len(det.shifts) <= 1


# ---------------------------------------------------------------------- #
# executor                                                                #
# ---------------------------------------------------------------------- #
def test_executor_delta_conserves_bytes():
    ex = MigrationExecutor(_tiers())
    old = {"a": [("LDRAM", 1.0)], "b": [("CXL", 1.0)]}
    new = {"a": [("LDRAM", 0.25), ("CXL", 0.75)], "b": [("CXL", 1.0)]}
    d = ex.delta(old, new, {"a": 100 * G, "b": 10 * G})
    assert d.total_bytes == 75 * G
    assert d.bytes_out_of("LDRAM") == 75 * G
    assert d.bytes_into("CXL") == 75 * G
    assert all(m.obj == "a" for m in d.moves)   # b unchanged


def test_executor_ignores_appearing_objects():
    ex = MigrationExecutor(_tiers())
    d = ex.delta({}, {"new": [("LDRAM", 1.0)]}, {"new": G})
    assert d.total_bytes == 0               # allocation, not migration


def test_executor_cost_priced_on_slow_endpoint():
    tiers = _tiers()
    ex = MigrationExecutor(tiers)
    d = ex.delta({"a": [("LDRAM", 1.0)]}, {"a": [("CXL", 1.0)]},
                 {"a": 10 * G})
    exp = migration_time_s(10 * G, tiers["CXL"], streams=ex.streams,
                           page_bytes=ex.page_bytes)
    assert ex.cost_s(d) == pytest.approx(exp)


def test_executor_execute_counts_promotions_and_partial_moves():
    tiers = _tiers()
    done = []

    def move_fn(obj, src, dst, nbytes):
        done.append((obj, src, dst, nbytes))
        return nbytes // 2                  # capacity denies half

    ex = MigrationExecutor(tiers, move_fn=move_fn)
    d = ex.delta({"a": [("CXL", 1.0)]}, {"a": [("LDRAM", 1.0)]},
                 {"a": 4 * G})
    stats = ex.execute(d, MigrationStats())
    assert done == [("a", "CXL", "LDRAM", 4 * G)]
    assert stats.migrated_bytes == 2 * G
    assert stats.promoted == 1 and stats.demoted == 0


# ---------------------------------------------------------------------- #
# replanner                                                               #
# ---------------------------------------------------------------------- #
def _observed_trace(spec, epochs=4):
    tr = AccessTrace()
    for _ in range(epochs):
        _emit_epoch(tr, spec)
    return tr


def test_replanner_adopts_initial_plan_then_holds_on_stable_traffic():
    tr = _observed_trace({"u": (80 * G, 40 * G, 0.0)})
    rp = AdaptiveReplanner(tr, _tiers(), "LDRAM",
                           cfg=ReplanConfig(replan_every=1))
    nb = {"u": 40 * G}
    d0 = rp.maybe_replan(1, nb)
    assert d0.applied and d0.reason == "initial"
    d1 = rp.maybe_replan(2, nb)
    assert not d1.applied                 # same traffic -> no win
    assert rp.replans_applied == 1


def test_replanner_respects_cadence():
    tr = _observed_trace({"u": (80 * G, 0, 0.0)})
    rp = AdaptiveReplanner(tr, _tiers(), "LDRAM",
                           cfg=ReplanConfig(replan_every=5))
    assert rp.maybe_replan(3, {"u": 40 * G}) is None
    assert rp.maybe_replan(5, {"u": 40 * G}) is not None


def test_replanner_no_traffic_no_decision():
    rp = AdaptiveReplanner(AccessTrace(), _tiers(), "LDRAM")
    assert rp.maybe_replan(0, {"u": G}, force=True) is None


def test_replanner_migrates_on_phase_shift_and_wins():
    """The bandwidth-hot object changes: the replanner must hand the
    freed fast-tier capacity to the newly-hot object and predict a win
    that survives the migration-cost gate."""
    tiers = _tiers()
    nb = {"u": 60 * G, "w": 60 * G}
    tr = _observed_trace({"u": (120 * G, 60 * G, 0.0)})
    rp = AdaptiveReplanner(
        tr, tiers, "LDRAM",
        cfg=ReplanConfig(replan_every=1, window_epochs=2,
                         amortize_steps=16))
    rp.maybe_replan(1, nb)
    plan_a = rp.plan
    u_fast_before = sum(f for t, f in rp.plan.shares["u"]
                        if t == "LDRAM")
    # phase shift: u goes cold, w becomes the streamed hot object
    for _ in range(4):
        _emit_epoch(tr, {"w": (120 * G, 60 * G, 0.0)})
    d = rp.maybe_replan(2, nb)
    assert d is not None and d.applied and d.reason == "win"
    assert d.predicted_speedup > 1.05
    assert rp.moved_bytes > 0
    assert rp.plan is not plan_a
    # 'w' now holds at least the fast share 'u' used to have
    w_fast = sum(f for t, f in rp.plan.shares["w"] if t == "LDRAM")
    assert w_fast >= u_fast_before - 0.05


def test_replanner_feeds_realized_shares_after_denied_moves():
    """When move_fn denies part of a delta, the live plan must reflect
    the *realized* residency, not the intended plan, so the next
    costing pass prices reality (ROADMAP follow-on)."""
    tiers = _tiers()
    nb = {"u": 60 * G, "w": 60 * G}
    tr = _observed_trace({"u": (120 * G, 60 * G, 0.0)})

    def half_denying_move(obj, src, dst, nbytes):
        return nbytes // 2               # fast budget rejects half

    rp = AdaptiveReplanner(
        tr, tiers, "LDRAM",
        cfg=ReplanConfig(replan_every=1, window_epochs=2,
                         amortize_steps=16),
        executor=MigrationExecutor(tiers, move_fn=half_denying_move))
    rp.maybe_replan(1, nb)               # initial plan, no moves yet
    for _ in range(4):
        _emit_epoch(tr, {"w": (120 * G, 60 * G, 0.0)})
    d = rp.maybe_replan(2, nb)
    assert d.applied
    assert d.denied_bytes > 0
    assert d.moved_bytes + d.denied_bytes > 0
    assert d.moved_bytes == pytest.approx(d.denied_bytes, rel=0.01)
    # intended: hand w the whole fast tier; realized: only half arrived
    intended = rp.policy.plan(
        tr.to_data_objects(nb, window=2), rp.tiers)
    w_intended = sum(f for t, f in intended.shares["w"] if t == "LDRAM")
    w_live = sum(f for t, f in rp.plan.shares["w"] if t == "LDRAM")
    assert w_live < w_intended - 0.05
    assert rp.summary()["denied_bytes"] > 0


class _CountingOLI(ObjectLevelInterleave):
    def __init__(self, *a, **k):
        super().__init__(*a, **k)
        self.calls = 0

    def plan(self, objs, tiers):
        self.calls += 1
        return super().plan(objs, tiers)


def test_replanner_phase_cache_skips_replanning_and_hysteresis():
    """Recurring phase signatures reuse the plan that already won: no
    policy re-run, no hysteresis margin (ROADMAP follow-on)."""
    tiers = _tiers()
    nb = {"u": 60 * G, "w": 60 * G}
    pol = _CountingOLI("LDRAM", ["CXL"], bandwidth_weighted=True)
    tr = AccessTrace()
    rp = AdaptiveReplanner(
        tr, tiers, "LDRAM", policy=pol,
        cfg=ReplanConfig(replan_every=1, window_epochs=2,
                         amortize_steps=32))
    phase_a = {"u": (120 * G, 60 * G, 0.0)}
    phase_b = {"w": (120 * G, 60 * G, 0.0)}
    for _ in range(3):
        _emit_epoch(tr, phase_a)
    rp.maybe_replan(1, nb, phase="A")    # initial (cached under A)
    for _ in range(4):
        _emit_epoch(tr, phase_b)
    rp.maybe_replan(2, nb, phase="B")
    calls_after_first_cycle = pol.calls
    assert rp.plan_cache_hits == 0
    # the phases recur: cached plans are reused, the policy never re-runs
    for _ in range(4):
        _emit_epoch(tr, phase_a)
    da = rp.maybe_replan(3, nb, phase="A")
    for _ in range(4):
        _emit_epoch(tr, phase_b)
    db = rp.maybe_replan(4, nb, phase="B")
    assert pol.calls == calls_after_first_cycle
    assert rp.plan_cache_hits == 2
    assert da.cached and db.cached
    assert da.applied and da.reason == "cached_win"
    # unknown signature still plans fresh
    _emit_epoch(tr, phase_a)
    rp.maybe_replan(5, nb, phase="C")
    assert pol.calls == calls_after_first_cycle + 1
    # a cached plan for a drifted object inventory is not trusted
    _emit_epoch(tr, phase_a)
    rp.maybe_replan(6, {"u": 60 * G, "new_obj": 10 * G}, phase="A")
    assert pol.calls == calls_after_first_cycle + 2


def test_replanner_hysteresis_blocks_marginal_wins():
    tiers = _tiers()
    nb = {"u": 60 * G}
    tr = _observed_trace({"u": (120 * G, 0, 0.0)})
    rp = AdaptiveReplanner(
        tr, tiers, "LDRAM",
        cfg=ReplanConfig(replan_every=1, min_speedup=10.0))
    rp.maybe_replan(1, nb)
    for _ in range(4):
        _emit_epoch(tr, {"u": (10 * G, 0, 0.4)})
    d = rp.maybe_replan(2, nb)
    assert d is None or not d.applied     # 10x hysteresis: never passes


# ---------------------------------------------------------------------- #
# serving-engine integration                                              #
# ---------------------------------------------------------------------- #
def _smoke_engine(adaptive):
    import jax
    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.serving import ServingConfig, ServingEngine

    cfg = get_smoke_config("llama3-8b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, ServingConfig(
        block_tokens=16, max_batch=3, max_context=64, policy="static",
        num_blocks=12, fast_block_budget=4, adaptive=adaptive,
        replan_every=4))
    rs = np.random.RandomState(0)
    for i in range(4):
        eng.submit(rs.randint(0, cfg.vocab, (16,)).astype(np.int32),
                   max_new_tokens=8, arrival_s=0.0)
    return eng


def test_engine_emits_telemetry_and_replans():
    eng = _smoke_engine(adaptive=True)
    rep = eng.run()
    t = rep.telemetry
    assert t["trace_events"] > 0
    assert t["profiling_samples"] > 0
    assert t["replans_considered"] >= 1
    assert rep.summary["finished"] == 4.0
    # telemetry sees both prefill writes and decode reads
    assert set(eng.trace.phase_events) >= {"prefill", "decode"}


def test_engine_without_adaptive_still_traces():
    eng = _smoke_engine(adaptive=False)
    rep = eng.run()
    assert rep.telemetry["trace_events"] > 0
    assert "replans_considered" not in rep.telemetry
    assert rep.summary["finished"] == 4.0
    # finished sequences were retired from the sampler's carry state
    assert len(eng.sampler._carry) == 0


def test_engine_replan_every_zero_disables_replans():
    import jax
    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.serving import ServingConfig, ServingEngine

    cfg = get_smoke_config("llama3-8b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, ServingConfig(
        block_tokens=16, max_batch=2, max_context=64, policy="static",
        num_blocks=8, fast_block_budget=4, adaptive=True,
        replan_every=0))
    eng.submit(np.zeros(16, np.int32), max_new_tokens=4)
    rep = eng.run()                      # must not ZeroDivisionError
    assert rep.telemetry["replans_considered"] == 0.0


# ---------------------------------------------------------------------- #
# metrics percentiles                                                     #
# ---------------------------------------------------------------------- #
def test_metrics_percentiles_and_migrated_bytes_per_token():
    from repro.serving import percentile, ServingMetrics

    assert percentile([], 95) == 0.0
    assert percentile([3.0], 50) == 3.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 50) == pytest.approx(2.5)
    assert percentile(list(range(1, 101)), 95) == pytest.approx(95.05)

    m = ServingMetrics()
    for rid, (ttft, n) in enumerate([(0.1, 4), (0.2, 4), (0.9, 4)]):
        m.on_submit(rid, 0.0, 8)
        m.on_admit(rid, ttft)
        t = ttft
        for k in range(n):
            m.on_token(rid, t)
            t += 0.05
        m.on_finish(rid, t, 0)
    s = m.summary({"migrated_bytes": 1200})
    assert s["p50_ttft_s"] == pytest.approx(0.2)
    assert s["p95_ttft_s"] == pytest.approx(0.83)
    assert s["p50_latency_s"] > 0
    assert s["migrated_bytes_per_token"] == pytest.approx(100.0)
