"""Placement policies: §V-B selection criteria + plan invariants."""
import pytest
from _hyp import given, settings, st

from repro.core import (DataObject, FirstTouch, GiB, ObjectLevelInterleave,
                        paper_system, select_interleave_candidates,
                        TierPreferred, UniformInterleave)


def _objs():
    return [
        DataObject("big_stream", 50 * GiB, read_bytes_per_step=100 * GiB),
        DataObject("big_random", 40 * GiB, read_bytes_per_step=80 * GiB,
                   random_fraction=0.95),
        DataObject("small", 1 * GiB, read_bytes_per_step=10 * GiB),
        DataObject("cold", 30 * GiB, read_bytes_per_step=0),
    ]


def test_selection_criteria():
    """§V-B: ≥10% footprint AND access-intensive AND not latency-bound."""
    sel = {o.name for o in select_interleave_candidates(_objs())}
    assert "big_stream" in sel          # big + hot + streaming
    assert "big_random" not in sel      # latency-sensitive (OLI gathers it)
    assert "small" not in sel           # < 10% footprint
    assert "cold" not in sel            # no traffic


def test_oli_places_hungry_across_tiers():
    tiers = paper_system("A")
    plan = ObjectLevelInterleave("LDRAM", ["CXL"]).plan(_objs(), tiers)
    assert 0.3 < plan.fraction_on("big_stream", "CXL") < 0.7
    # latency-sensitive object gathered on the fast tier
    assert plan.fraction_on("big_random", "LDRAM") > 0.99


def test_oli_saves_fast_memory_vs_preferred():
    """OLI observation 1: OLI reduces fast-memory use (~32% in paper)."""
    tiers = paper_system("A")
    objs = _objs()
    pref = TierPreferred("LDRAM").plan(objs, tiers)
    oli = ObjectLevelInterleave("LDRAM", ["CXL"]).plan(objs, tiers)
    assert oli.fast_bytes("LDRAM") < 0.85 * pref.fast_bytes("LDRAM")


def test_preferred_spills_in_numa_order():
    import dataclasses
    tiers = dict(paper_system("A"))
    tiers["LDRAM"] = dataclasses.replace(tiers["LDRAM"], capacity_GiB=60)
    plan = TierPreferred("LDRAM").plan(_objs(), tiers)
    # first object fills LDRAM (60 of 50 fits); later objects spill to RDRAM
    assert plan.fraction_on("big_stream", "LDRAM") == 1.0
    assert plan.fraction_on("big_random", "RDRAM") > 0.5


def test_uniform_interleave_equal_shares():
    tiers = paper_system("A")
    plan = UniformInterleave(["LDRAM", "CXL"]).plan(_objs(), tiers)
    f = plan.fraction_on("big_stream", "LDRAM")
    assert abs(f - 0.5) < 0.02


@st.composite
def _random_objs(draw):
    n = draw(st.integers(1, 8))
    out = []
    for i in range(n):
        nbytes = draw(st.integers(1, 200)) * GiB
        traffic = draw(st.integers(0, 400)) * GiB
        rf = draw(st.sampled_from([0.0, 0.3, 0.9]))
        out.append(DataObject(f"o{i}", nbytes, traffic,
                              random_fraction=rf))
    return out


@settings(max_examples=40, deadline=None)
@given(_random_objs(), st.sampled_from(["A", "B", "C"]),
       st.sampled_from(["pref", "uniform", "oli", "first"]))
def test_plans_cover_every_byte(objs, sysname, polname):
    """Invariant: every plan accounts for 100% of every object."""
    tiers = paper_system(sysname)
    pol = {"pref": TierPreferred("LDRAM"),
           "uniform": UniformInterleave(["LDRAM", "CXL"]),
           "oli": ObjectLevelInterleave("LDRAM", ["CXL"]),
           "first": FirstTouch("LDRAM")}[polname]
    plan = pol.plan(objs, tiers)
    for o in objs:
        total = sum(f for _, f in plan.shares[o.name])
        assert total == pytest.approx(1.0, abs=0.02), \
            f"{polname} lost bytes of {o.name}: {total}"
