"""Optimizer: math vs oracle, compression error-feedback, chunked path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as kref
from repro.optim import AdamConfig, apply_update, init_state


def _tree(key, stacked=False):
    k1, k2 = jax.random.split(key)
    if stacked:
        return {"w": (jax.random.normal(k1, (24, 8, 4)) * 0.1
                      ).astype(jnp.bfloat16),
                "b": jnp.zeros((4,), jnp.bfloat16)}
    return {"w": (jax.random.normal(k1, (8, 4)) * 0.1
                  ).astype(jnp.bfloat16),
            "b": jnp.zeros((4,), jnp.bfloat16)}


def test_adam_matches_reference():
    cfg = AdamConfig(lr=1e-2, weight_decay=0.0, grad_clip=1e9)
    params = _tree(jax.random.PRNGKey(0))
    state = init_state(params, cfg)
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(7), p.shape,
                                    jnp.float32) * 0.01, params)
    new_params, new_state = apply_update(params, state, grads, cfg)
    # reference on leaf "w"
    want, m2, v2 = kref.fused_adam(
        state["master"]["w"], state["m"]["w"], state["v"]["w"],
        grads["w"], lr=cfg.lr, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps,
        wd=0.0, b1c=1 - cfg.b1, b2c=1 - cfg.b2)
    np.testing.assert_allclose(np.asarray(new_state["master"]["w"]),
                               np.asarray(want), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(new_state["m"]["w"]),
                               np.asarray(m2), rtol=1e-6)
    assert new_params["w"].dtype == jnp.bfloat16


def test_chunked_update_equals_unchunked():
    """lax.map-streamed update (stacked leaves) == direct math."""
    cfg = AdamConfig(lr=3e-3, grad_clip=1e9)
    params = _tree(jax.random.PRNGKey(1), stacked=True)
    state = init_state(params, cfg)
    grads = jax.tree.map(
        lambda p: jnp.full(p.shape, 0.01, jnp.float32), params)
    new_params, new_state = apply_update(params, state, grads, cfg)
    want, _, _ = kref.fused_adam(
        state["master"]["w"], state["m"]["w"], state["v"]["w"],
        grads["w"], lr=cfg.lr, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps,
        wd=cfg.weight_decay, b1c=1 - cfg.b1, b2c=1 - cfg.b2)
    np.testing.assert_allclose(np.asarray(new_state["master"]["w"]),
                               np.asarray(want), rtol=1e-5, atol=1e-6)


def test_grad_clip():
    cfg = AdamConfig(lr=1.0, grad_clip=0.001, weight_decay=0.0)
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    state = init_state(params, cfg)
    grads = {"w": jnp.full((4,), 100.0)}
    _, new_state = apply_update(params, state, grads, cfg)
    # clipped: effective grad norm <= clip
    m = np.asarray(new_state["m"]["w"])
    assert np.linalg.norm(m / (1 - cfg.b1)) <= 0.0011


def test_compression_error_feedback():
    """bf16 compression keeps a residual; over steps the applied updates
    converge to the uncompressed sum (error feedback property)."""
    cfg_c = AdamConfig(lr=1e-3, compress_grads=True, grad_clip=1e9,
                       weight_decay=0.0)
    cfg_u = AdamConfig(lr=1e-3, compress_grads=False, grad_clip=1e9,
                       weight_decay=0.0)
    params = {"w": jnp.zeros((64,), jnp.bfloat16)}
    sc = init_state(params, cfg_c)
    su = init_state(params, cfg_u)
    pc, pu = params, params
    g = {"w": jnp.linspace(1e-4, 3e-3, 64)}  # small: bf16 rounding bites
    for _ in range(50):
        pc, sc = apply_update(pc, sc, g, cfg_c)
        pu, su = apply_update(pu, su, g, cfg_u)
    a = np.asarray(sc["master"]["w"])
    b = np.asarray(su["master"]["w"])
    # compressed tracks uncompressed within a few percent
    np.testing.assert_allclose(a, b, rtol=0.05, atol=1e-5)
    assert "err" in sc and np.any(np.asarray(sc["err"]["w"]) != 0)


def test_fused_kernel_path_matches():
    cfg_f = AdamConfig(lr=1e-2, use_fused_kernel=True, grad_clip=1e9)
    cfg_r = AdamConfig(lr=1e-2, use_fused_kernel=False, grad_clip=1e9)
    params = _tree(jax.random.PRNGKey(2))
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(8), p.shape,
                                    jnp.float32) * 0.01, params)
    pf, sf = apply_update(params, init_state(params, cfg_f), grads, cfg_f)
    pr, sr = apply_update(params, init_state(params, cfg_r), grads, cfg_r)
    for a, b in zip(jax.tree.leaves(sf["master"]),
                    jax.tree.leaves(sr["master"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)
