"""Hypothesis compatibility shim for the property tests.

``hypothesis`` is an optional dev dependency.  When it is installed the
real library is re-exported unchanged; when it is absent, a minimal
deterministic fallback keeps the property tests *active* (seeded random
draws over the same strategy surface) instead of skipping them.

Only the strategy combinators this suite uses are implemented:
integers, floats, sampled_from, one_of, none, booleans, composite.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

        def example(self, rng):
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

        def filter(self, pred, _tries=100):
            def draw(rng):
                for _ in range(_tries):
                    x = self._draw(rng)
                    if pred(x):
                        return x
                raise ValueError("filter predicate never satisfied")
            return _Strategy(draw)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            # bias toward the endpoints like hypothesis does
            def draw(rng):
                r = rng.random()
                if r < 0.05:
                    return min_value
                if r < 0.10:
                    return max_value
                return rng.uniform(min_value, max_value)
            return _Strategy(draw)

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(lambda rng: items[rng.randrange(len(items))])

        @staticmethod
        def one_of(*strategies):
            return _Strategy(
                lambda rng: strategies[rng.randrange(len(strategies))]
                .example(rng))

        @staticmethod
        def none():
            return _Strategy(lambda rng: None)

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def composite(fn):
            def build(*args, **kwargs):
                def draw_composite(rng):
                    return fn(lambda s: s.example(rng), *args, **kwargs)
                return _Strategy(draw_composite)
            return build

    st = _Strategies()

    def settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
        def deco(fn):
            fn._hyp_max_examples = max_examples
            return fn
        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_hyp_max_examples",
                            _DEFAULT_EXAMPLES)
                for i in range(n):
                    rng = random.Random(0xC0FFEE + 1000003 * i)
                    drawn = [s.example(rng) for s in arg_strategies]
                    kdrawn = {name: s.example(rng)
                              for name, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **kdrawn)
            # hide the strategy parameters from pytest's fixture
            # resolution (hypothesis does the same)
            wrapper.__signature__ = inspect.Signature()
            if hasattr(wrapper, "__wrapped__"):
                del wrapper.__wrapped__
            return wrapper
        return deco
