"""interleave.objects_from_pytree over nested pytree structures."""
import jax.numpy as jnp
import numpy as np

from repro.core.interleave import objects_from_pytree


def _tree():
    return {
        "layers": [
            {"attn": (jnp.zeros((4, 8), jnp.float32),
                      jnp.zeros((8,), jnp.bfloat16))},
            {"mlp": [jnp.zeros((2, 2), jnp.float32)]},
        ],
        "embed": jnp.zeros((16, 4), jnp.float32),
    }


def test_nested_dict_list_tuple_names_and_sizes():
    objs = objects_from_pytree(_tree())
    by_name = {o.name: o for o in objs}
    assert set(by_name) == {
        "embed",
        "layers/0/attn/0",
        "layers/0/attn/1",
        "layers/1/mlp/0",
    }
    assert by_name["embed"].nbytes == 16 * 4 * 4
    assert by_name["layers/0/attn/0"].nbytes == 4 * 8 * 4
    assert by_name["layers/0/attn/1"].nbytes == 8 * 2    # bf16
    assert by_name["layers/1/mlp/0"].nbytes == 2 * 2 * 4


def test_default_traffic_is_one_streaming_read():
    objs = objects_from_pytree(_tree())
    for o in objs:
        assert o.read_bytes_per_step == o.nbytes
        assert o.write_bytes_per_step == 0
        assert o.random_fraction == 0.0
        assert o.group == "params"


def test_traffic_fn_receives_joined_names():
    seen = {}

    def traffic(name, leaf):
        seen[name] = leaf.shape
        return 2 * leaf.nbytes, leaf.nbytes, 0.25

    objs = objects_from_pytree(_tree(), traffic_fn=traffic,
                               group="opt_state")
    assert "layers/1/mlp/0" in seen
    for o in objs:
        assert o.read_bytes_per_step == 2 * o.nbytes
        assert o.write_bytes_per_step == o.nbytes
        assert o.random_fraction == 0.25
        assert o.group == "opt_state"


def test_numpy_leaves_supported():
    objs = objects_from_pytree((np.zeros((3, 3), np.float64),
                                [np.zeros(5, np.int32)]))
    by_name = {o.name: o.nbytes for o in objs}
    assert by_name == {"0": 72, "1/0": 20}
