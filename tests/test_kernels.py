"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ops, ref


# --------------------------- fused adam ------------------------------- #
@pytest.mark.parametrize("n", [1, 127, 128, 1000, 4096, 70000])
@pytest.mark.parametrize("gdtype", [jnp.float32, jnp.bfloat16])
def test_fused_adam_sweep(n, gdtype):
    k = jax.random.PRNGKey(n)
    master = jax.random.normal(k, (n,), jnp.float32)
    m = jax.random.normal(jax.random.PRNGKey(1), (n,)) * 0.1
    v = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (n,))) * 0.01
    g = jax.random.normal(jax.random.PRNGKey(3), (n,)).astype(gdtype)
    kw = dict(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.1,
              b1c=0.1, b2c=0.05)
    got = ops.fused_adam(master, m, v, g, **kw)
    want = ref.fused_adam(master, m, v, g, **kw)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("shape", [(3, 5), (16, 128), (2, 3, 4, 5)])
def test_fused_adam_nd_shapes(shape):
    k = jax.random.PRNGKey(0)
    master = jax.random.normal(k, shape, jnp.float32)
    m = jnp.zeros(shape)
    v = jnp.zeros(shape)
    g = jax.random.normal(jax.random.PRNGKey(1), shape)
    kw = dict(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8, wd=0.0,
              b1c=0.1, b2c=0.001)
    got = ops.fused_adam(master, m, v, g, **kw)
    want = ref.fused_adam(master, m, v, g, **kw)
    assert got[0].shape == shape
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


# ------------------------- flash attention ---------------------------- #
@pytest.mark.parametrize("B,Sq,Sk,H,KV,hd", [
    (1, 128, 128, 4, 4, 64),      # MHA square
    (2, 256, 256, 8, 2, 64),      # GQA
    (1, 384, 128, 4, 1, 32),      # MQA, Sq > Sk
    (2, 130, 259, 4, 4, 64),      # ragged (padding path)
])
def test_flash_attention_sweep(B, Sq, Sk, H, KV, hd):
    k0 = jax.random.PRNGKey(0)
    q = jax.random.normal(k0, (B, Sq, H, hd), jnp.float32) * 0.5
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Sk, KV, hd)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Sk, KV, hd)) * 0.5
    got = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    q = (jax.random.normal(jax.random.PRNGKey(0), (1, 128, 4, 64))
         * 0.5).astype(jnp.bfloat16)
    k = (jax.random.normal(jax.random.PRNGKey(1), (1, 128, 4, 64))
         * 0.5).astype(jnp.bfloat16)
    v = (jax.random.normal(jax.random.PRNGKey(2), (1, 128, 4, 64))
         * 0.5).astype(jnp.bfloat16)
    got = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_matches_model_chunked_attention():
    """Kernel vs the model's pure-JAX chunked attention (same algorithm)."""
    from repro.models.modules import chunked_attention
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 256, 8, 64)) * 0.3
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 256, 4, 64)) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 256, 4, 64)) * 0.3
    a = ops.flash_attention(q, k, v, causal=True)
    b = chunked_attention(q, k, v, causal=True, chunk_q=64, chunk_kv=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-3)


# ------------------------- decode attention --------------------------- #
@pytest.mark.parametrize("B,S,H,KV,hd,blk", [
    (1, 256, 4, 4, 64, 128),
    (4, 512, 8, 2, 64, 256),
    (2, 1024, 16, 1, 128, 256),
])
def test_decode_attention_sweep(B, S, H, KV, hd, blk):
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, hd))
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd))
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd))
    kv_len = jnp.arange(1, B + 1, dtype=jnp.int32) * (S // (B + 1)) + 1
    got = ops.decode_attention(q, kc, vc, kv_len, block_k=blk)
    want = jnp.stack([
        ref.decode_attention(q[i:i + 1], kc[i:i + 1], vc[i:i + 1],
                             kv_len[i])[0]
        for i in range(B)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(s_mult=st.integers(1, 4), kv=st.sampled_from([1, 2, 4]),
       rep=st.sampled_from([1, 2, 4]))
def test_decode_attention_property(s_mult, kv, rep):
    B, hd = 2, 32
    S = 128 * s_mult
    H = kv * rep
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, hd))
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, S, kv, hd))
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, S, kv, hd))
    got = ops.decode_attention(q, kc, vc, S, block_k=128)
    want = ref.decode_attention(q, kc, vc, jnp.int32(S))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
