"""Cost model: qualitative reproduction of the paper's §V/§IV claims."""
import dataclasses

import pytest

from repro.core import (compare_policies, GiB, hpc_workload_objects,
                        llm_serve_objects, ObjectLevelInterleave,
                        paper_system, plan_step_cost, policy_search,
                        TierPreferred, UniformInterleave)


def _tiers(ldram_gib):
    t = dict(paper_system("A"))
    t["LDRAM"] = dataclasses.replace(t["LDRAM"], capacity_GiB=ldram_gib)
    return t


@pytest.mark.parametrize("wl", ["BT", "LU", "MG", "SP", "FT"])
def test_oli_beats_uniform_sufficient_ldram(wl):
    """OLI observation 1: OLI consistently outperforms uniform
    interleaving (65% average in the paper) with sufficient LDRAM."""
    tiers = _tiers(128)
    objs = hpc_workload_objects(wl)
    costs = compare_policies(
        objs,
        [UniformInterleave(["LDRAM", "CXL"]),
         ObjectLevelInterleave("LDRAM", ["CXL"])],
        tiers)
    uni = costs["uniform_interleave[LDRAM+CXL]"].step_s
    oli = costs["oli[LDRAM+CXL]"].step_s
    assert oli <= uni * 1.001, f"{wl}: OLI {oli} worse than uniform {uni}"


@pytest.mark.parametrize("wl", ["BT", "LU", "MG"])
def test_oli_beats_preferred_insufficient_ldram(wl):
    """OLI observation 2: with insufficient LDRAM (64 GB), OLI beats
    LDRAM-preferred (1.42x average in the paper).  Setup matches §V-B:
    LDRAM (limited) + CXL only — the preferred policy pushes the
    late-allocated latency-sensitive residue onto CXL."""
    tiers = {k: v for k, v in _tiers(64).items()
             if k in ("LDRAM", "CXL")}
    objs = hpc_workload_objects(wl)
    costs = compare_policies(
        objs,
        [TierPreferred("LDRAM"),
         ObjectLevelInterleave("LDRAM", ["CXL"])],
        tiers)
    assert costs["oli[LDRAM+CXL]"].step_s < \
        costs["LDRAM_preferred"].step_s


def test_xsbench_prefers_ldram():
    """§V-B: XSBench (concentrated latency-sensitive set) favors
    LDRAM-preferred over both interleaving flavors."""
    tiers = _tiers(128)
    objs = hpc_workload_objects("XSBench")
    costs = compare_policies(
        objs,
        [TierPreferred("LDRAM"),
         UniformInterleave(["LDRAM", "CXL"])],
        tiers)
    assert costs["LDRAM_preferred"].step_s <= \
        costs["uniform_interleave[LDRAM+CXL]"].step_s


def test_rdram_cxl_close_to_ldram_cxl():
    """HPC observation 1: interleave(RDRAM+CXL) ≈ interleave(LDRAM+CXL)
    (CXL dominates; <9.2% difference in the paper)."""
    tiers = _tiers(768)
    objs = hpc_workload_objects("MG")
    costs = compare_policies(
        objs,
        [UniformInterleave(["LDRAM", "CXL"]),
         UniformInterleave(["RDRAM", "CXL"])],
        tiers)
    a = costs["uniform_interleave[LDRAM+CXL]"].step_s
    b = costs["uniform_interleave[RDRAM+CXL]"].step_s
    assert abs(a - b) / a < 0.15


def test_policy_search_feasible_and_sane():
    """FlexGen-style search places hot objects fast-first under budget."""
    tiers = _tiers(196)
    objs = llm_serve_objects(n_params=65_000_000_000,
                             kv_bytes=120 * GiB, act_bytes=2 * GiB)
    res = policy_search(objs, tiers, fast="LDRAM", grid=4)
    assert res.step_s > 0
    placed = sum(res.plan.tier_bytes.values())
    total = sum(o.nbytes for o in objs)
    assert placed >= 0.98 * total


@pytest.mark.parametrize("wl", ["BT", "LU", "CG", "MG", "XSBench"])
@pytest.mark.parametrize("mk", ["preferred", "uniform", "oli"])
def test_phased_time_at_least_unphased_tier_max(wl, mk):
    """Invariant: phased (per-object-sweep) time can never be below the
    unphased parallel-tier composition — sum of per-object maxima >=
    max of per-tier sums."""
    tiers = _tiers(128)
    objs = hpc_workload_objects(wl)
    pol = {"preferred": TierPreferred("LDRAM"),
           "uniform": UniformInterleave(["LDRAM", "CXL"]),
           "oli": ObjectLevelInterleave("LDRAM", ["CXL"])}[mk]
    c = plan_step_cost(objs, pol.plan(objs, tiers), tiers)
    assert c.phased_s >= max(c.per_tier_time.values()) - 1e-12


def test_policy_search_monotone_in_fast_capacity():
    """Invariant: at fixed traffic, growing the fast tier (more fast
    share available to the search) never increases the optimized step
    time — every placement feasible at the smaller capacity stays
    feasible."""
    objs = llm_serve_objects(n_params=30_000_000_000,
                             kv_bytes=80 * GiB, act_bytes=2 * GiB)
    prev = None
    for cap in (96, 128, 196, 320):
        res = policy_search(objs, _tiers(cap), fast="LDRAM", grid=4)
        if prev is not None:
            assert res.step_s <= prev + 1e-9, (
                f"step time rose from {prev} to {res.step_s} when fast "
                f"capacity grew to {cap} GiB")
        prev = res.step_s


def test_step_cost_bounds():
    tiers = _tiers(768)
    objs = hpc_workload_objects("CG")
    plan = TierPreferred("LDRAM").plan(objs, tiers)
    c = plan_step_cost(objs, plan, tiers, compute_time_s=100.0)
    assert c.step_s >= 100.0         # compute floor
    assert c.bound == "compute"
    c2 = plan_step_cost(objs, plan, tiers, compute_time_s=0.0)
    assert c2.bound == "memory"
