"""Serving subsystem: pool invariants, scheduler ordering, tiering,
and paged-decode consistency against the monolithic decode path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import lm
from repro.serving import (ContinuousBatchingScheduler, FAST_KIND,
                           KVBlockSpec, KVBlockTierer, PagedKVPool,
                           plan_admission, PoolExhausted, Request,
                           RequestState, SchedulerConfig, ServingConfig,
                           ServingEngine)


def _meta_pool(num_blocks=16, block_tokens=4, fast_budget=None, **kw):
    return PagedKVPool(num_blocks, block_tokens,
                       fast_block_budget=fast_budget, **kw)


def _req(rid, plen=6, new=4, arrival=0.0):
    return Request(rid=rid, prompt=np.zeros(plen, np.int32),
                   max_new_tokens=new, arrival_s=arrival)


# ===================================================================== #
# Pool: alloc / free / defrag invariants                                #
# ===================================================================== #
def test_pool_alloc_free_roundtrip():
    pool = _meta_pool(8)
    a = pool.alloc(1, 3)
    b = pool.alloc(2, 2)
    assert len(set(a) | set(b)) == 5          # unique physical blocks
    assert pool.used_block_count() == 5
    assert pool.free_block_count() == 3
    assert [pool.blocks[x].logical_idx for x in a] == [0, 1, 2]
    assert pool.free_seq(1) == 3
    assert pool.used_block_count() == 2
    assert 1 not in pool.table
    # freed blocks are reusable
    c = pool.alloc(3, 5)
    assert len(c) == 5
    with pytest.raises(PoolExhausted):
        pool.alloc(4, 2)


def test_pool_blocks_for_tokens():
    pool = _meta_pool(8, block_tokens=4)
    assert pool.blocks_for_tokens(1) == 1
    assert pool.blocks_for_tokens(4) == 1
    assert pool.blocks_for_tokens(5) == 2


def test_pool_fast_budget_enforced():
    pool = _meta_pool(8, fast_budget=2)
    pool.alloc(1, 4)                          # default slow kind
    bids = pool.table[1]
    assert pool.migrate(bids[0], FAST_KIND)
    assert pool.migrate(bids[1], FAST_KIND)
    assert not pool.migrate(bids[2], FAST_KIND)   # budget full
    assert pool.fast_used() == 2
    assert pool.counters.promoted == 2
    assert pool.migrate(bids[0], "pinned_host")   # demote frees a slot
    assert pool.counters.demoted == 1
    assert pool.migrate(bids[2], FAST_KIND)


def test_pool_per_block_alloc_kind_callable():
    pool = _meta_pool(8, fast_budget=8)
    kinds = iter([FAST_KIND, "pinned_host", FAST_KIND, "pinned_host"])
    pool.alloc(1, 4, kind=lambda: next(kinds))
    got = [pool.blocks[b].kind for b in pool.table[1]]
    assert got == [FAST_KIND, "pinned_host", FAST_KIND, "pinned_host"]


def test_pool_defrag_compacts_and_preserves():
    pool = _meta_pool(12)
    pool.alloc(1, 3)
    pool.alloc(2, 4)
    pool.alloc(3, 2)
    pool.free_seq(2)                          # hole in the id space
    seq1, seq3 = list(pool.table[1]), list(pool.table[3])
    kinds1 = [pool.blocks[b].kind for b in seq1]
    pool.blocks[seq3[0]].touch_count = 7      # payload metadata survives
    moved = pool.defrag()
    assert moved >= 0
    # live blocks occupy the lowest ids, free list is the suffix
    live = sorted(bid for tbl in pool.table.values() for bid in tbl)
    assert live == list(range(5))
    assert sorted(pool._free) == list(range(5, 12))
    # logical order and metadata preserved
    assert [pool.blocks[b].logical_idx for b in pool.table[1]] == [0, 1, 2]
    assert [pool.blocks[b].kind for b in pool.table[1]] == kinds1
    assert pool.blocks[pool.table[3][0]].touch_count == 7
    # allocation still works after compaction
    pool.alloc(4, 7)
    with pytest.raises(PoolExhausted):
        pool.alloc(5, 1)


# ===================================================================== #
# gather_seq / gather_tables edge cases (data mode, both layouts)       #
# ===================================================================== #
def _data_pool(pooled=False, num_blocks=6, bt=4):
    spec = KVBlockSpec(n_units=1, n_attn=2, block_tokens=bt, n_kv=2,
                       head_dim=8, dtype="float32")
    return PagedKVPool(num_blocks, bt, spec=spec, pooled=pooled), spec


def test_gather_seq_requires_data_mode():
    pool = _meta_pool(8)                      # metadata-only: no spec
    pool.alloc(1, 2)
    with pytest.raises(AssertionError, match="data-mode"):
        pool.gather_seq(1, 4)


@pytest.mark.parametrize("pooled", [False, True])
def test_gather_seq_empty_sequence_is_zero_padded(pooled):
    pool, spec = _data_pool(pooled)
    k, v = pool.gather_seq(99, 3)             # unknown seq: no blocks
    assert k.shape == (1, 2, 3 * 4, 2, 8)
    assert float(jnp.abs(k).sum()) == 0.0
    assert float(jnp.abs(v).sum()) == 0.0


@pytest.mark.parametrize("pooled", [False, True])
def test_gather_seq_rejects_pad_shorter_than_live_blocks(pooled):
    pool, spec = _data_pool(pooled)
    pool.alloc(1, 3)
    with pytest.raises(ValueError, match="pad_blocks"):
        pool.gather_seq(1, 2)


@pytest.mark.parametrize("pooled", [False, True])
def test_gather_seq_roundtrips_written_payload(pooled):
    pool, spec = _data_pool(pooled)
    rs = np.random.RandomState(0)
    kv_k = jnp.asarray(rs.randn(1, 2, 6, 2, 8), jnp.float32)
    kv_v = jnp.asarray(rs.randn(1, 2, 6, 2, 8), jnp.float32)
    pool.write_prefill(7, kv_k, kv_v, n_tokens=6)
    k, v = pool.gather_seq(7, 3)              # 2 live blocks + 1 pad
    np.testing.assert_allclose(np.asarray(k[:, :, :6]),
                               np.asarray(kv_k), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v[:, :, :6]),
                               np.asarray(kv_v), rtol=1e-6)
    assert float(jnp.abs(k[:, :, 8:]).sum()) == 0.0   # pad block zero


def test_gather_tables_requires_pooled_layout():
    pool, _ = _data_pool(pooled=False)
    pool.alloc(1, 2)
    with pytest.raises(ValueError, match="pooled"):
        pool.gather_tables([1], 4)


def test_gather_tables_block_ids_and_lens():
    pool, _ = _data_pool(pooled=True)
    pool.alloc(1, 2)
    pool.seq_len[1] = 7
    pool.alloc(2, 1)
    pool.seq_len[2] = 3
    tbl, lens = pool.gather_tables([1, 2, 99], 3)
    assert tbl.shape == (3, 3) and tbl.dtype == np.int32
    assert list(tbl[0, :2]) == list(pool.table[1])
    assert tbl[0, 2] == 0                     # pad slot masked by lens
    assert list(lens) == [7, 3, 0]
    with pytest.raises(ValueError, match="pad_blocks"):
        pool.gather_tables([1], 1)


# ===================================================================== #
# Scheduler: admission + preemption ordering                            #
# ===================================================================== #
def test_scheduler_fifo_admission_capped_by_batch():
    pool = _meta_pool(32)
    sched = ContinuousBatchingScheduler(pool, SchedulerConfig(
        max_batch=2, max_prefill_per_iter=4))
    for i in range(4):
        sched.submit(_req(i))
    admitted = sched.admit()
    assert [r.rid for r in admitted] == [0, 1]     # FIFO, batch-capped
    assert [r.rid for r in sched.waiting] == [2, 3]


def test_scheduler_admission_respects_blocks_and_arrival():
    pool = _meta_pool(4, block_tokens=4)
    sched = ContinuousBatchingScheduler(pool, SchedulerConfig(
        max_batch=4, max_prefill_per_iter=4, admission_margin_blocks=1))
    sched.submit(_req(0, plen=7))      # needs 2 blocks (+1 margin)
    sched.submit(_req(1, plen=7, arrival=5.0))
    admitted = sched.admit(now_s=0.0)
    assert [r.rid for r in admitted] == [0]        # rid1 hasn't arrived
    pool.alloc(0, 2)
    admitted = sched.admit(now_s=10.0)
    assert admitted == []                          # 2 free < need 2+1
    pool.free_seq(0)
    assert [r.rid for r in sched.admit(now_s=10.0)] == [1]


def test_scheduler_preemption_lifo_and_readmission_order():
    pool = _meta_pool(8, block_tokens=4)
    sched = ContinuousBatchingScheduler(pool, SchedulerConfig(
        max_batch=3, max_prefill_per_iter=3))
    for i in range(3):
        sched.submit(_req(i, plen=6))
    admitted = sched.admit()
    assert len(admitted) == 3
    for r in admitted:
        pool.alloc(r.rid, 2)
    sched.submit(_req(3))
    # demand blocks: latest-admitted (rid2) must be evicted first
    victims = sched.preempt_for_blocks(5)
    assert [v.rid for v in victims] == [2, 1]
    assert all(v.state is RequestState.PREEMPTED for v in victims)
    assert pool.free_block_count() >= 5
    # preempted requests sit at the queue FRONT, most recent first,
    # ahead of the never-admitted rid3
    assert [r.rid for r in sched.waiting] == [1, 2, 3]
    assert victims[0].preemptions == 1


def test_scheduler_protected_request_evicted_last():
    pool = _meta_pool(8, block_tokens=4)
    sched = ContinuousBatchingScheduler(pool, SchedulerConfig(
        max_batch=2, max_prefill_per_iter=2))
    for i in range(2):
        sched.submit(_req(i, plen=6))
    admitted = sched.admit()
    for r in admitted:
        pool.alloc(r.rid, 4)
    protect = admitted[1]                  # newest would normally go first
    victims = sched.preempt_for_blocks(4, protect=protect)
    assert [v.rid for v in victims] == [0]
    assert protect.state is RequestState.RUNNING


# ===================================================================== #
# Tiering                                                               #
# ===================================================================== #
def test_tiering_static_never_migrates():
    pool = _meta_pool(8, fast_budget=4)
    pool.alloc(1, 4)
    tierer = KVBlockTierer(pool, "static")
    pool.touch_seq(1, 0)
    assert tierer.step([1], 0) == 0
    assert pool.fast_used() == 0


@pytest.mark.parametrize("policy", ["autonuma", "tiering08", "tpp"])
def test_tiering_promotes_hot_within_budget(policy):
    pool = _meta_pool(12, fast_budget=4)
    pool.alloc(1, 4)
    pool.alloc(2, 4)
    tierer = KVBlockTierer(pool, policy)
    for step in range(6):                   # seq1 hot, seq2 cold
        pool.touch_seq(1, step)
        tierer.step([1], step)
    assert pool.fast_used() <= 4
    assert sum(1 for b in pool.seq_blocks(1) if b.kind == FAST_KIND) > 0
    assert all(b.kind != FAST_KIND for b in pool.seq_blocks(2))
    assert tierer.stats.promoted > 0
    assert tierer.stats.hint_faults > 0


def test_tiering_demotes_cold_on_pressure():
    pool = _meta_pool(12, fast_budget=2)
    pool.alloc(1, 2)
    pool.alloc(2, 2)
    tierer = KVBlockTierer(pool, "autonuma")
    # seq1 becomes hot and takes the whole fast budget
    for step in range(3):
        pool.touch_seq(1, step)
        tierer.step([1], step)
    assert all(b.kind == FAST_KIND for b in pool.seq_blocks(1))
    # now only seq2 is hot: seq1's cold blocks must be demoted
    for step in range(3, 7):
        pool.touch_seq(2, step)
        tierer.step([2], step)
    assert pool.fast_used() <= 2
    assert sum(1 for b in pool.seq_blocks(2) if b.kind == FAST_KIND) > 0
    assert tierer.stats.demoted > 0


# ===================================================================== #
# Admission plan (cost-model sizing)                                    #
# ===================================================================== #
def test_plan_admission_scales_with_capacity():
    cfg = get_smoke_config("llama3-8b")
    small = plan_admission(cfg, 16, 128, device_budget_bytes=2 * 2**20,
                           host_budget_bytes=2 * 2**20)
    big = plan_admission(cfg, 16, 128, device_budget_bytes=2 * 2**20,
                         host_budget_bytes=32 * 2**20)
    assert big.total_blocks > small.total_blocks
    assert big.max_batch >= small.max_batch    # LIO 3
    assert small.fast_blocks <= small.total_blocks


# ===================================================================== #
# Paged decode consistency + end-to-end engine                          #
# ===================================================================== #
@pytest.fixture(scope="module")
def tiny():
    cfg = get_smoke_config("llama3-8b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_paged_decode_matches_monolithic(tiny):
    """Greedy tokens from the paged engine == lm.decode_step chain."""
    cfg, params = tiny
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0,
                              cfg.vocab)
    logits_p, cache = lm.prefill(params, cfg, toks)
    pads = [(0, 0)] * cache["kv_k"].ndim
    pads[3] = (0, 8)
    for k in ("kv_k", "kv_v"):
        cache[k] = jnp.pad(cache[k], pads)
    ref = [int(jnp.argmax(logits_p))]
    tok = jnp.argmax(logits_p, -1)[:, None].astype(jnp.int32)
    for _ in range(4):
        lg, cache = lm.decode_step(params, cfg, cache, tok)
        ref.append(int(jnp.argmax(lg)))
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)

    eng = ServingEngine(cfg, params, ServingConfig(
        block_tokens=8, max_batch=2, max_context=32, policy="tiering08"))
    eng.submit(np.asarray(toks[0]), max_new_tokens=5)
    eng.run()
    assert eng.sched.finished[0].out_tokens == ref


def test_engine_multi_request_trace(tiny):
    cfg, params = tiny
    eng = ServingEngine(cfg, params, ServingConfig(
        block_tokens=8, max_batch=2, max_context=32, policy="tiering08"))
    rs = np.random.RandomState(0)
    for i in range(3):
        eng.submit(rs.randint(0, cfg.vocab, (8,)).astype(np.int32),
                   max_new_tokens=4, arrival_s=0.005 * i)
    rep = eng.run()
    s = rep.summary
    assert s["finished"] == 3.0
    assert s["decode_tokens"] == 12.0
    assert s["throughput_tok_s"] > 0
    assert all(row["new_tokens"] == 4.0 for _, row in rep.per_request)
    assert all(row["decode_tok_s"] > 0 for _, row in rep.per_request)
    # every block returned to the pool
    assert eng.pool.used_block_count() == 0
    assert rep.tiering["promoted"] >= 0


def test_engine_preemption_under_tight_pool(tiny):
    """Pool smaller than the trace working set forces preemption, and
    every request still finishes with the full token count."""
    cfg, params = tiny
    eng = ServingEngine(cfg, params, ServingConfig(
        block_tokens=8, max_batch=3, max_context=24, policy="static",
        num_blocks=5, fast_block_budget=2))
    rs = np.random.RandomState(1)
    for i in range(3):
        eng.submit(rs.randint(0, cfg.vocab, (8,)).astype(np.int32),
                   max_new_tokens=10)
    rep = eng.run()
    assert rep.summary["finished"] == 3.0
    assert all(row["new_tokens"] == 10.0 for _, row in rep.per_request)
    assert rep.summary["preemptions"] > 0
    assert eng.pool.used_block_count() == 0


def test_engine_rejects_hybrid_arch():
    cfg = get_smoke_config("jamba-1.5-large-398b")
    with pytest.raises(ValueError, match="attention-only"):
        ServingEngine(cfg, params=None)


# ===================================================================== #
# Fused tiered-gather decode path                                       #
# ===================================================================== #
def _run_engine(cfg, params, prompts, new_tokens=4, **sv_kw):
    eng = ServingEngine(cfg, params, ServingConfig(
        block_tokens=8, max_batch=3, max_context=32, policy="tiering08",
        **sv_kw))
    for p in prompts:
        eng.submit(p, max_new_tokens=new_tokens)
    eng.run()
    return eng


def test_fused_gather_matches_staged_decode(tiny):
    """fused_gather=True must emit the same greedy tokens as the
    staged gather_seq path — the layouts differ, the math must not."""
    cfg, params = tiny
    rs = np.random.RandomState(2)
    prompts = [rs.randint(0, cfg.vocab, (n,)).astype(np.int32)
               for n in (12, 7, 9)]
    staged = _run_engine(cfg, params, prompts)
    fused = _run_engine(cfg, params, prompts, fused_gather=True)
    assert fused.pool.pooled and not staged.pool.pooled
    for rid in range(3):
        assert (fused.sched.finished[rid].out_tokens
                == staged.sched.finished[rid].out_tokens)


@pytest.fixture(scope="module")
def tiny_moe():
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_fused_gather_moe_matches_staged(tiny_moe):
    cfg, params = tiny_moe
    rs = np.random.RandomState(3)
    prompts = [rs.randint(0, cfg.vocab, (n,)).astype(np.int32)
               for n in (10, 6)]
    staged = _run_engine(cfg, params, prompts)
    fused = _run_engine(cfg, params, prompts, fused_gather=True)
    for rid in range(2):
        assert (fused.sched.finished[rid].out_tokens
                == staged.sched.finished[rid].out_tokens)


def test_fused_gather_moe_expert_telemetry(tiny_moe):
    """The fused path feeds routed expert ids into the ExpertPool:
    heat accumulates, residency stays within budget, and the summary
    surfaces the expert.* counters."""
    cfg, params = tiny_moe
    rs = np.random.RandomState(4)
    prompts = [rs.randint(0, cfg.vocab, (8,)).astype(np.int32)
               for _ in range(2)]
    eng = _run_engine(cfg, params, prompts, new_tokens=6,
                      fused_gather=True, expert_policy="lru",
                      expert_fast_fraction=0.25)
    ep = eng.expert_pool
    assert ep is not None
    # 2 requests x 5 decode iterations (the first output token comes
    # from prefill) x top_k activations x n_moe layers
    n_moe = ep.n_layers
    assert ep.counters.accesses == 2 * 5 * cfg.top_k * n_moe
    assert ep.fast_residents() <= ep.fast_expert_budget
    assert ep.counters.promoted > 0
    s = eng.telemetry_summary()
    assert s["expert.accesses"] == float(ep.counters.accesses)
    assert "expert.fast_hit_ratio" in s


def test_expert_policy_requires_moe_model(tiny):
    cfg, params = tiny
    with pytest.raises(ValueError, match="no MoE"):
        ServingEngine(cfg, params, ServingConfig(
            block_tokens=8, max_batch=2, max_context=32,
            expert_policy="lru"))


# ===================================================================== #
# Contention-aware admission (repro.topology)                           #
# ===================================================================== #
def _narrow_link_topology(bw_GBps=5.0):
    from repro.topology import TopologyGraph
    g = TopologyGraph("pcie", origin="hbm")
    g.add_node("hbm", "chip", tier=FAST_KIND)
    g.add_node("host", "host", tier="pinned_host")
    g.add_link("hbm", "host", 600.0, bw_GBps, "pcie")
    return g


def test_admission_budgets_shared_link():
    """Block capacity alone would admit everything; the KV gathers'
    shared PCIe link must cap the batch instead."""
    from repro.serving.kv_pool import KVBlockSpec
    spec = KVBlockSpec(n_units=2, n_attn=2, block_tokens=4, n_kv=2,
                       head_dim=8)                 # 1 KiB per block
    pool = PagedKVPool(64, 4, spec=spec)
    sched = ContinuousBatchingScheduler(
        pool, SchedulerConfig(max_batch=8, max_prefill_per_iter=8,
                              link_efficiency_floor=0.9,
                              gather_period_s=1e-6),
        topology=_narrow_link_topology(5.0))
    for i in range(6):
        sched.submit(_req(i, plen=6))
    admitted = sched.admit()
    # each request offers ~2 GB/s of gather over a 5 GB/s link: the
    # third would drag everyone under the 90% floor
    assert len(admitted) == 2
    assert sched.link_deferrals == 1
    assert len(sched.waiting) == 4
    # pool capacity was NOT the limit
    assert pool.can_alloc(sched.blocks_needed(sched.waiting[0]) + 1)


def test_admission_link_budget_counts_running_residency():
    """Running requests' slow-resident blocks load the link; requests
    whose blocks were promoted to the fast kind stop loading it."""
    from repro.serving.kv_pool import KVBlockSpec
    spec = KVBlockSpec(n_units=2, n_attn=2, block_tokens=4, n_kv=2,
                       head_dim=8)
    pool = PagedKVPool(64, 4, spec=spec, fast_block_budget=64,
                       default_kind="pinned_host")
    sched = ContinuousBatchingScheduler(
        pool, SchedulerConfig(max_batch=8, max_prefill_per_iter=1,
                              link_efficiency_floor=0.9,
                              gather_period_s=1e-6),
        topology=_narrow_link_topology(5.0))
    for i in range(3):
        sched.submit(_req(i, plen=6))
    first = sched.admit()
    assert len(first) == 1
    pool.alloc(first[0].rid, 2)                  # its KV lands slow
    second = sched.admit()
    assert len(second) == 1
    pool.alloc(second[0].rid, 2)
    assert sched.admit() == []                   # link saturated
    # promote one running request's blocks to the fast kind: its
    # gather leaves the PCIe link, freeing budget for the third
    for bid in pool.table[first[0].rid]:
        assert pool.migrate(bid, FAST_KIND)
    assert len(sched.admit()) == 1


def test_admission_without_topology_unchanged():
    pool = _meta_pool(32)
    sched = ContinuousBatchingScheduler(pool, SchedulerConfig(
        max_batch=8, max_prefill_per_iter=8))
    for i in range(4):
        sched.submit(_req(i))
    assert len(sched.admit()) == 4
    assert sched.link_deferrals == 0


def test_admission_ignores_preexisting_violations_on_disjoint_links():
    """A flow already under the floor (heavy residency on one link)
    must not head-of-line-block a candidate whose gather rides a
    different, healthy link — only the marginal effect counts."""
    from repro.serving.kv_pool import KVBlockSpec
    from repro.topology import TopologyGraph
    g = TopologyGraph("two-links", origin="hbm")
    g.add_node("hbm", "chip", tier=FAST_KIND)
    g.add_node("host1", "host", tier="pinned_host")
    g.add_node("host2", "host", tier="unpinned_host")
    g.add_link("hbm", "host1", 600.0, 5.0, "pcie")    # saturated below
    g.add_link("hbm", "host2", 900.0, 100.0, "pcie")  # plenty free
    spec = KVBlockSpec(n_units=2, n_attn=2, block_tokens=4, n_kv=2,
                       head_dim=8)                    # 1 KiB per block
    pool = PagedKVPool(64, 4, spec=spec, default_kind="unpinned_host")
    sched = ContinuousBatchingScheduler(
        pool, SchedulerConfig(max_batch=8, max_prefill_per_iter=2,
                              link_efficiency_floor=0.9,
                              gather_period_s=1e-6),
        topology=g)
    # two running requests whose 3 blocks each gather over the narrow
    # link: 2 x ~3 GB/s offered over 5 GB/s -> both already < 90%
    for rid in (10, 11):
        r = _req(rid, plen=10)
        r.state = RequestState.RUNNING
        sched.running.append(r)
        pool.alloc(rid, 3, kind="pinned_host")
    sched.submit(_req(0, plen=6))      # gathers over the wide link
    assert [r.rid for r in sched.admit()] == [0]
    assert sched.link_deferrals == 0


def test_admission_candidate_exactly_at_floor_is_admitted():
    """The floor is inclusive: a candidate whose fair share lands
    exactly on ``floor * offered`` is admitted, not deferred."""
    from repro.serving.kv_pool import KVBlockSpec
    spec = KVBlockSpec(n_units=2, n_attn=2, block_tokens=4, n_kv=2,
                       head_dim=8)                 # 1 KiB per block
    pool = PagedKVPool(64, 4, spec=spec)
    # one request = 2 blocks = 2.048 GB/s of gather; the link carries
    # exactly one request, so two equal flows each achieve *exactly*
    # half their offered rate (floats halve exactly) — the boundary
    bw = 2 * spec.nbytes / 1e-6 / 1e9
    sched = ContinuousBatchingScheduler(
        pool, SchedulerConfig(max_batch=8, max_prefill_per_iter=8,
                              link_efficiency_floor=0.5,
                              gather_period_s=1e-6),
        topology=_narrow_link_topology(bw))
    for i in range(3):
        sched.submit(_req(i, plen=6))
    admitted = sched.admit()
    # 1st flows free; 2nd lands exactly at the 50% floor (admitted);
    # 3rd would drop everyone to 1/3 < floor (deferred)
    assert [r.rid for r in admitted] == [0, 1]
    assert sched.link_deferrals == 1


def test_admission_skips_link_budget_for_fast_resident_default():
    """A pool whose default kind IS the fast kind gathers nothing over
    the topology: admission must not synthesize a zero flow."""
    pool = _meta_pool(32, fast_budget=32, default_kind=FAST_KIND)
    sched = ContinuousBatchingScheduler(
        pool, SchedulerConfig(max_batch=8, max_prefill_per_iter=8,
                              link_efficiency_floor=0.9,
                              gather_period_s=1e-6),
        topology=_narrow_link_topology(0.001))     # starved link
    for i in range(4):
        sched.submit(_req(i))
    assert len(sched.admit()) == 4
    assert sched.link_deferrals == 0


# ===================================================================== #
# Violation-predictive admission + preemption (repro.obs.qos)           #
# ===================================================================== #
class _StubPredictor:
    """Predictor double: violation iff total offered exceeds a limit."""

    def __init__(self, limit_GBps):
        self.limit = limit_GBps
        self.excludes = []

    def violations(self, flows, exclude=None):
        self.excludes.append(exclude)
        total = sum(f.offered_GBps for f in flows)
        return {"victim": (total, self.limit)} if total > self.limit \
            else {}

    def admission_ok(self, flows, exclude=None):
        return not self.violations(flows, exclude)


def _qos_sched(limit_GBps, **cfg_kw):
    from repro.serving.kv_pool import KVBlockSpec
    spec = KVBlockSpec(n_units=2, n_attn=2, block_tokens=4, n_kv=2,
                       head_dim=8)                 # 1 KiB per block
    pool = PagedKVPool(64, 4, spec=spec, default_kind="pinned_host",
                       tenant="antagonist")
    pred = _StubPredictor(limit_GBps)
    sched = ContinuousBatchingScheduler(
        pool, SchedulerConfig(max_batch=8, max_prefill_per_iter=8,
                              gather_period_s=1e-6, **cfg_kw),
        topology=_narrow_link_topology(100.0), predictor=pred)
    return sched, pool, pred


def test_qos_admission_defers_on_predicted_violation():
    # each request gathers ~2 GB/s; the stub allows 4.5 GB/s total
    sched, pool, pred = _qos_sched(4.5)
    for i in range(4):
        sched.submit(_req(i, plen=6))
    admitted = sched.admit()
    assert [r.rid for r in admitted] == [0, 1]
    assert sched.qos_deferrals == 1
    # the predictor replaces the floor entirely
    assert sched.link_deferrals == 0
    # own stale blame-book snapshot is excluded (live flows passed in)
    assert set(pred.excludes) == {"antagonist"}


def test_qos_preemption_sheds_slow_holders_until_forecast_clears():
    sched, pool, pred = _qos_sched(10.0)
    for prio, rid in ((1.0, 0), (0.0, 1), (2.0, 2)):
        r = _req(rid, plen=6)
        r.priority = prio
        sched.submit(r)
    admitted = sched.admit()
    assert len(admitted) == 3
    for r in admitted:
        pool.alloc(r.rid, 2)         # slow-resident: 3 x ~2 GB/s live
    # the SLO forecast tightens: only ~2 GB/s of gather is tolerable
    pred.limit = 2.5
    victims = sched.preempt_predicted_violation()
    # lowest priority evicted first, then the next, until it clears
    assert [v.rid for v in victims] == [1, 0]
    assert sched.slo_preemptions == 2
    assert [r.rid for r in sched.running] == [2]
    # evicted requests lose their blocks and rejoin the queue front
    assert pool.used_block_count() == 2
    assert [r.rid for r in sched.waiting] == [0, 1]
    # a second call is a no-op (forecast already clear)
    assert sched.preempt_predicted_violation() == []


def test_qos_preemption_noop_without_slow_holders():
    sched, pool, pred = _qos_sched(10.0)
    sched.submit(_req(0, plen=6))
    (r,) = sched.admit()
    pool.alloc(r.rid, 2, kind=FAST_KIND)   # all fast: no link traffic
    pred.limit = 0.0
    # running flows are empty (nothing slow-resident) -> nothing to shed
    assert sched.preempt_predicted_violation() == []
    assert sched.slo_preemptions == 0
