"""Integration: prefill + decode_step == full forward, for every family.

This is the system's core numerical invariant — the KV/SSM/WKV caches and
position handling must be exact across the prefill/decode boundary.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_smoke_config
from repro.models import lm


def _pad_kv(cache, extra=8):
    out = dict(cache)
    for k in ("kv_k", "kv_v"):
        if k in out:
            pads = [(0, 0)] * out[k].ndim
            pads[3] = (0, extra)
            out[k] = jnp.pad(out[k], pads)
    return out


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_matches_prefill(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    cross = None
    if cfg.n_frontend_tokens:
        cross = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_frontend_tokens, cfg.d_model))

    logits_p, cache = lm.prefill(params, cfg, toks, cross)
    cache = _pad_kv(cache)
    # decode 3 tokens, comparing each against the full-sequence prefill
    seq = toks
    for step in range(3):
        nxt = jnp.argmax(logits_p, -1)[:, None].astype(jnp.int32)
        logits_d, cache = lm.decode_step(params, cfg, cache, nxt)
        seq = jnp.concatenate([seq, nxt], axis=1)
        logits_full, _ = lm.prefill(params, cfg, seq, cross)
        a = np.asarray(logits_d, np.float32)
        b = np.asarray(logits_full, np.float32)
        rel = np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9)
        assert rel < 2e-2, f"{arch} step {step}: rel err {rel}"
        logits_p = logits_d


def test_mamba_chunk_vs_step_recurrence():
    """SSD chunked scan == token-by-token recurrence (oracle check)."""
    from repro.models import modules as M
    dims = M.mamba_dims(32, expand=2, head_dim=16, d_state=8, chunk=8)
    p = M.init_mamba(jax.random.PRNGKey(0), dims)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 19, 32),
                          jnp.float32) * 0.5
    y_full, (cs, ss) = M.mamba_fwd(p, x, dims)
    # token-by-token
    cs2 = jnp.zeros((2, dims.d_conv - 1, dims.d_inner), jnp.bfloat16)
    ss2 = jnp.zeros((2, dims.n_heads, dims.d_state, dims.head_dim),
                    jnp.float32)
    outs = []
    for t in range(19):
        y, (cs2, ss2) = M.mamba_fwd(p, x[:, t:t + 1], dims,
                                    conv_state=cs2, ssm_state=ss2)
        outs.append(y)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full, np.float32),
                               np.asarray(y_step, np.float32),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(ss, np.float32),
                               np.asarray(ss2, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_rwkv_chunk_vs_step_recurrence():
    from repro.models import modules as M
    dims = M.rwkv_dims(32, d_ff=64, head_dim=16, chunk=8)
    p = M.init_rwkv_tmix(jax.random.PRNGKey(0), dims)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 21, 32),
                          jnp.float32) * 0.5
    y_full, (state, shift) = M.rwkv_tmix_fwd(p, x, dims)
    st = None
    sh = None
    outs = []
    for t in range(21):
        y, (st, sh) = M.rwkv_tmix_fwd(p, x[:, t:t + 1], dims,
                                      wkv_state=st, shift_state=sh)
        outs.append(y)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full, np.float32),
                               np.asarray(y_step, np.float32),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(state), np.asarray(st),
                               rtol=2e-2, atol=2e-2)


def test_int8_kv_cache_decode():
    """int8-quantized KV cache: decode within quantization tolerance."""
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config("llama3-8b"),
                              kv_cache_dtype="int8")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    logits_p, cache = lm.prefill(params, cfg, toks)
    out = dict(cache)
    for k in ("kv_k", "kv_v", "kv_k_scale", "kv_v_scale"):
        pads = [(0, 0)] * out[k].ndim
        pads[3] = (0, 8)
        out[k] = jnp.pad(out[k], pads)
    assert out["kv_k"].dtype == jnp.int8
    nxt = jnp.argmax(logits_p, -1)[:, None].astype(jnp.int32)
    logits_d, _ = lm.decode_step(params, cfg, out, nxt)
    logits_full, _ = lm.prefill(params, cfg,
                                jnp.concatenate([toks, nxt], 1))
    a = np.asarray(logits_d, np.float32)
    b = np.asarray(logits_full, np.float32)
    rel = np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9)
    assert rel < 0.1, rel
