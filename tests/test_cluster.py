"""Cluster plane: namespaced ledgers, session router, hierarchical
arbiter split, per-link interference overrides, and the ClusterPlane
end-to-end on the single test device."""
import jax
import numpy as np
import pytest

from repro.cluster import (DEFAULT_REPLICA, ClusterPlane, Namespace,
                           SessionRequest, SessionRouter, is_pattern,
                           replica_meshes, reset_bare_key_warning)
from repro.configs import get_smoke_config
from repro.models import lm
from repro.obs import qos_chains
from repro.pool import ResidencyLedger, TierBudgetArbiter
from repro.serving import (ClusterOptions, ConfigError, ServingConfig,
                           TieringOptions)
from repro.serving.config import validate_args
from repro.topology import TopologyGraph, multi_host_pod

MiB = 2**20


# ===================================================================== #
# Namespace: round-trip, short form, globs                              #
# ===================================================================== #
def test_namespace_roundtrip_all_forms():
    for s in ("a", "host0/serving", "host1/t/kv"):
        ns = Namespace.parse(s)
        assert Namespace.parse(str(ns)) == ns
        assert str(ns) == s
    # canonical long form always carries the replica
    assert Namespace.parse("a").key == "default/a"
    assert Namespace.parse("host0/serving/kv").key == "host0/serving/kv"


def test_namespace_short_form_preserves_legacy_keys():
    # the API-compat contract: pre-cluster tenant names render unchanged
    assert str(Namespace(tenant="serving")) == "serving"
    assert str(Namespace(replica=DEFAULT_REPLICA, tenant="a")) == "a"
    assert str(Namespace(replica="host1", tenant="a")) == "host1/a"


def test_namespace_component_validation():
    with pytest.raises(ValueError):
        Namespace(tenant="a/b")
    with pytest.raises(ValueError):
        Namespace.parse("a/b/c/d")
    with pytest.raises(ValueError):
        Namespace(tenant="a").matches("a/b/c/d")


def test_namespace_glob_matching():
    ns = Namespace(replica="host1", tenant="serving", obj="kv3")
    assert ns.matches("host1/*")
    assert ns.matches("*/serving")
    assert ns.matches("host?/serving/kv*")
    assert not ns.matches("host0/*")
    # bare pattern addresses the default replica, mirroring of()
    assert not ns.matches("serving")
    assert Namespace(tenant="serving").matches("serving")
    assert is_pattern("host*/x") and not is_pattern("host0/x")


def test_namespace_ordering_groups_replicas():
    keys = [Namespace(replica="h1", tenant="b"),
            Namespace(replica="h0", tenant="z"),
            Namespace(replica="h0", tenant="a")]
    ordered = [str(ns) for ns in sorted(keys)]
    assert ordered == ["h0/a", "h0/z", "h1/b"]


def test_namespace_derivation_helpers():
    ns = Namespace.parse("h0/t")
    assert ns.with_obj("kv").obj == "kv"
    assert ns.with_obj("kv").tenant_key() == ns
    assert ns.in_replica("h1").key == "h1/t"


# ===================================================================== #
# Bare-string shim: warn once per process                               #
# ===================================================================== #
def test_bare_key_shim_warns_once():
    reset_bare_key_warning()
    with pytest.warns(DeprecationWarning, match="bare tenant key"):
        assert Namespace.of("legacy") == Namespace(tenant="legacy")
    # second bare key is silent — once per process, not per call
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert Namespace.of("other").key == "default/other"
        # namespaced keys and glob patterns never warn
        reset_bare_key_warning()
        assert Namespace.of("h0/t").replica == "h0"
        assert Namespace.of(Namespace(tenant="x")).tenant == "x"
        assert Namespace.of("*").tenant == "*"
    reset_bare_key_warning()


# ===================================================================== #
# Ledger: per-replica namespaces sum exactly to the global view         #
# ===================================================================== #
def _two_replica_ledger():
    led = ResidencyLedger()
    for t in ("h0/serving", "h1/serving", "h1/batch"):
        led.register_tenant(t)
    led.register("h0/serving", "kv0", {"FAST": 4 * MiB, "CXL": 1 * MiB})
    led.register("h1/serving", "kv1", {"FAST": 2 * MiB})
    led.register("h1/batch", "kv2", {"FAST": 3 * MiB, "CXL": 5 * MiB})
    return led


def test_ledger_namespace_aggregation_is_conserved():
    led = _two_replica_ledger()
    total = led.aggregate("*/*")
    by_host = [led.aggregate("h0/*"), led.aggregate("h1/*")]
    for tier in total:
        assert total[tier] == sum(a.get(tier, 0) for a in by_host)
    assert led.bytes_on("FAST", "h0/*") == 4 * MiB
    assert led.bytes_on("FAST", "h1/*") == 5 * MiB
    assert led.bytes_on("FAST", "*/*") == 9 * MiB
    assert led.bytes_on("CXL", "*/serving") == 1 * MiB


def test_ledger_accepts_namespace_and_legacy_keys():
    led = ResidencyLedger()
    led.register_tenant(Namespace(replica="h0", tenant="t"))
    led.register(Namespace.parse("h0/t"), "kv", {"FAST": MiB})
    assert led.tenant_bytes("h0/t") == MiB
    # a pre-cluster bare key lands in the default replica
    reset_bare_key_warning()
    with pytest.warns(DeprecationWarning):
        led.register_tenant("old")
    led.register("default/old", "kv", {"FAST": MiB})
    assert led.bytes_on("FAST", "default/*") == MiB
    assert led.bytes_on("FAST", "*/*") == 2 * MiB
    reset_bare_key_warning()


# ===================================================================== #
# SessionRouter: policies, degenerate cases, pending reservations       #
# ===================================================================== #
def _req(sid, kv=None):
    return SessionRequest(session_id=sid, prompt_tokens=8, new_tokens=8,
                          kv_bytes_hint=kv)


def test_router_rejects_unknown_policy_and_empty_registry():
    with pytest.raises(ConfigError, match="unknown router policy"):
        SessionRouter("best-effort")
    r = SessionRouter("round-robin")
    with pytest.raises(ConfigError, match="no registered replicas"):
        r.route(_req("s0"))


def test_router_single_replica_fast_path():
    r = SessionRouter("headroom-distance")
    r.register("only", distance_ns=5.0, headroom_fn=lambda: 0)
    assert [r.route(_req(f"s{i}")) for i in range(3)] == ["only"] * 3
    assert r.routed_counts() == {"only": 3}


def test_router_zero_headroom_degrades_to_least_loaded():
    r = SessionRouter("headroom-distance")
    load = {"near": 4, "far": 1}
    for name, d in (("near", 1.0), ("far", 9.0)):
        r.register(name, distance_ns=d, headroom_fn=lambda: 0,
                   load_fn=lambda n=name: load[n])
    # both full: the lighter replica wins despite being farther
    assert r.route(_req("s0", kv=MiB)) == "far"


def test_router_headroom_dominates_distance():
    r = SessionRouter("headroom-distance")
    r.register("near", distance_ns=1.0, headroom_fn=lambda: 2 * MiB)
    r.register("far", distance_ns=9.0, headroom_fn=lambda: 10 * MiB)
    # only far can hold the whole session fast
    assert r.route(_req("s0", kv=4 * MiB)) == "far"
    # comparable headroom: distance breaks the tie
    r2 = SessionRouter("headroom-distance")
    r2.register("far", distance_ns=9.0, headroom_fn=lambda: 8 * MiB)
    r2.register("near", distance_ns=1.0, headroom_fn=lambda: 8 * MiB)
    assert r2.route(_req("s1", kv=MiB)) == "near"


def test_router_pending_reservations_spread_batches():
    """Without live pool feedback, in-flight kv reservations must keep
    a batch of identical submissions off a single replica."""
    r = SessionRouter("headroom-distance")
    for name in ("a", "b"):
        r.register(name, distance_ns=1.0, headroom_fn=lambda: 8 * MiB)
    picks = [r.route(_req(f"s{i}", kv=3 * MiB)) for i in range(4)]
    assert set(picks) == {"a", "b"}
    assert picks.count("a") == picks.count("b") == 2
    r.drain_pending()
    assert all(v.pending_bytes == 0 for v in r._views.values())


def test_router_baseline_policies():
    rr = SessionRouter("round-robin")
    rnd = SessionRouter("random", seed=7)
    ll = SessionRouter("least-loaded")
    load = {"a": 3, "b": 0}
    for router in (rr, rnd, ll):
        for name in ("a", "b"):
            router.register(name, distance_ns=1.0,
                            load_fn=lambda n=name: load[n])
    assert [rr.route(_req(f"s{i}")) for i in range(4)] == \
        ["a", "b", "a", "b"]
    assert set(rnd.route(_req(f"s{i}")) for i in range(8)) == {"a", "b"}
    assert ll.route(_req("s0")) == "b"


# ===================================================================== #
# Hierarchical arbiter: replica groups first, tenants within            #
# ===================================================================== #
def test_arbiter_split_respects_replica_capacity():
    led = _two_replica_ledger()
    cap = {"h0": 2 * MiB, "h1": 3 * MiB}
    arb = TierBudgetArbiter(led, "FAST",
                            capacity_bytes=sum(cap.values()),
                            replica_capacity=cap)
    grant = arb.split(arb.demands())
    # no trace attached -> whole residency is demand; h0/serving wants
    # 5 MiB but its host only has 2 MiB of physical fast tier
    by_replica = {}
    for tenant, g in grant.items():
        by_replica.setdefault(Namespace.of(tenant).replica, 0)
        by_replica[Namespace.of(tenant).replica] += g
    assert by_replica["h0"] <= cap["h0"]
    assert by_replica["h1"] <= cap["h1"]
    assert by_replica["h0"] == 2 * MiB          # capped, not starved
    assert by_replica["h1"] == 3 * MiB


@pytest.mark.filterwarnings("ignore::DeprecationWarning")
def test_arbiter_single_replica_degenerates_to_flat_split():
    led = ResidencyLedger()
    for t in ("a", "b"):
        led.register_tenant(t)
        led.register(t, "kv", {"FAST": 4 * MiB})
    flat = TierBudgetArbiter(led, "FAST", capacity_bytes=4 * MiB)
    grouped = TierBudgetArbiter(led, "FAST", capacity_bytes=4 * MiB,
                                replica_capacity={"default": 4 * MiB})
    assert flat.split(flat.demands()) == grouped.split(grouped.demands())


# ===================================================================== #
# InterferenceMatrix.with_link_scales: one physical link, not its kind  #
# ===================================================================== #
def _two_cxl_link_graph():
    g = TopologyGraph("two-cxl")
    g.add_node("s0")
    g.add_node("cxl0", kind="cxl")
    g.add_node("cxl1", kind="cxl")
    g.add_link("s0", "cxl0", 150.0, 38.4, kind="cxl")
    g.add_link("s0", "cxl1", 150.0, 38.4, kind="cxl")
    return g


def test_link_scales_override_one_link_only():
    g = _two_cxl_link_graph()
    m = g.interference.with_link_scales("s0-cxl0",
                                        {("read", "write"): 2.0})
    base = g.interference.weight("cxl", "read", "write")
    hot = m.weight("cxl", "read", "write", link=("s0", "cxl0"))
    cold = m.weight("cxl", "read", "write", link=("s0", "cxl1"))
    assert hot == pytest.approx(2.0 * base)
    assert cold == pytest.approx(base)          # same kind, other link
    # link order is normalized: (b, a) prices like (a, b)
    assert m.weight("cxl", "read", "write",
                    link=("cxl0", "s0")) == pytest.approx(hot)


def test_link_scales_take_precedence_over_pair_scales():
    m = TopologyGraph("g").interference \
        .with_pair_scales({("cxl", "read", "write"): 3.0}) \
        .with_link_scales(("s0", "cxl0"), {("read", "write"): 1.5})
    kind_level = m.weight("cxl", "read", "write")
    link_level = m.weight("cxl", "read", "write", link=("s0", "cxl0"))
    base = TopologyGraph("g").interference.weight("cxl", "read", "write")
    assert kind_level == pytest.approx(3.0 * base)
    assert link_level == pytest.approx(1.5 * base)   # link wins
    with pytest.raises(ValueError, match="not 'a-b'"):
        m.with_link_scales("nodash", {("read", "write"): 2.0})


def test_link_scales_survive_graph_rebuilt():
    g = _two_cxl_link_graph()
    g.interference = g.interference.with_link_scales(
        "s0-cxl0", {("read", "write"): 2.0})
    g2 = g.rebuilt(link_overrides={(("cxl1", "s0")): (150.0, 20.0)})
    before = g.interference.weight("cxl", "read", "write",
                                   link=("s0", "cxl0"))
    after = g2.interference.weight("cxl", "read", "write",
                                   link=("s0", "cxl0"))
    assert after == pytest.approx(before)


# ===================================================================== #
# ClusterPlane end-to-end (single test device: replicas share it)       #
# ===================================================================== #
@pytest.fixture(scope="module")
def tiny():
    cfg = get_smoke_config("llama3-8b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _plane(cfg, params, **kw):
    kw.setdefault("serving", ServingConfig(
        block_tokens=8, max_batch=2, max_context=32, policy="tiering08"))
    return ClusterPlane(cfg, params, n_replicas=2, **kw)


def test_replica_meshes_cover_all_devices():
    meshes = replica_meshes(2)
    assert len(meshes) == 2
    # on one test device both replicas share it; with more devices the
    # meshes must be disjoint
    devs = [tuple(d.id for d in m.devices.flat) for m in meshes]
    if len(jax.devices()) >= 2:
        assert not set(devs[0]) & set(devs[1])


def test_plane_routes_runs_and_conserves_namespaces(tiny):
    cfg, params = tiny
    plane = _plane(cfg, params)
    rs = np.random.RandomState(0)
    rids = [plane.submit(rs.randint(0, cfg.vocab, (8,)).astype(np.int32),
                         4, arrival_s=0.005 * i) for i in range(4)]
    # submissions spread across replicas via pending reservations
    assert set(r.split(":")[0] for r in rids) == set(plane.replicas)
    rep = plane.run()
    assert rep.summary["finished"] == 4.0
    assert rep.summary["replicas"] == 2.0
    assert sum(rep.routed.values()) == 4
    assert rep.aggregate_throughput() > 0
    # the acceptance invariant: per-replica ledger bytes sum exactly
    # to the global aggregate, across every tier in play
    for tier in plane.ledger.aggregate("*/*"):
        per = {h: plane.ledger.bytes_on(tier, f"{h}/*")
               for h in plane.replicas}
        assert sum(per.values()) == plane.ledger.bytes_on(tier, "*/*")
    cons = plane.namespace_conservation()
    assert sum(v for h, v in cons.items() if h != "total") == \
        cons["total"]


def test_plane_replica_tenants_are_namespaced(tiny):
    cfg, params = tiny
    plane = _plane(cfg, params)
    names = {str(rep.ns) for rep in plane.replicas.values()}
    assert names == {"host0/serving", "host1/serving"}
    # each replica engine registered its pool under its namespace in
    # the one shared ledger
    tenants = {str(ns) for ns in plane.ledger.tenants}
    assert names <= tenants


def test_plane_publish_exports_per_replica_gauges(tiny):
    cfg, params = tiny
    plane = _plane(cfg, params)
    n = plane.publish()
    assert n > 0
    names = plane.registry.names()
    for host in plane.replicas:
        for g in ("fast_headroom_bytes", "active_sessions",
                  "routed_sessions", "distance_ns"):
            assert f"cluster.{host}.{g}" in names
    # host0 sits next to the front-end; host1 pays the ICI hop
    d0 = plane.registry.gauge("cluster.host0.distance_ns").value
    d1 = plane.registry.gauge("cluster.host1.distance_ns").value
    assert d0 < d1


def test_merged_trace_keeps_per_replica_qos_chains(tiny):
    """qos_chains pairs a violation with the blame event that follows
    it in sequence, so the merge must keep each replica's event order
    intact rather than interleaving by timestamp."""
    cfg, params = tiny
    plane = _plane(cfg, params)
    for i, (host, rep) in enumerate(plane.replicas.items()):
        tr = rep.engine.tracer
        tr.event("slo.violation", cat="slo", tid="serving",
                 metric="decode_latency", host=host)
        tr.event("qos.blame", cat="qos", tid="serving",
                 antagonist=f"noisy{i}", link="ici", host=host)
    chains = qos_chains(plane.merged_trace())
    assert len(chains) == 2
    for c in chains:
        assert c["blame"] is not None
        # blame joined to its own replica's violation, never a sibling's
        assert c["blame"].args["host"] == c["violation"].args["host"]
        assert c["blame"].tid.split("/")[0] == \
            c["violation"].tid.split("/")[0]


def test_plane_rejects_undersized_testbed(tiny):
    cfg, params = tiny
    with pytest.raises(ValueError, match="hosts for"):
        ClusterPlane(cfg, params, n_replicas=4,
                     testbed=multi_host_pod(2))


def test_plane_arbiter_splits_under_physical_caps(tiny):
    cfg, params = tiny
    plane = _plane(cfg, params)
    grant = plane.arbiter.split(plane.arbiter.demands())
    per_replica = {}
    for tenant, g in grant.items():
        r = Namespace.of(tenant).replica
        per_replica[r] = per_replica.get(r, 0) + g
    for host, cap in plane.replica_fast_bytes.items():
        assert per_replica.get(host, 0) <= cap


# ===================================================================== #
# Config sections: two-way sync, from_args, centralized validation      #
# ===================================================================== #
def test_config_flat_kwargs_populate_sections():
    sc = ServingConfig(adaptive=True, expert_policy="lru", qos=False)
    assert sc.tiering.adaptive is True
    assert sc.experts.policy == "lru"
    assert sc.qos_options.enabled is False
    assert sc.cluster is None                  # no legacy flat kwargs


def test_config_section_wins_over_flat_kwargs():
    sc = ServingConfig(policy="tiering08",
                       tiering=TieringOptions(policy="static",
                                              num_blocks=7))
    assert sc.policy == "static"               # section overwrote flat
    assert sc.num_blocks == 7


def test_cluster_options_validate_eagerly():
    with pytest.raises(ConfigError, match="replicas must be >= 1"):
        ClusterOptions(replicas=0)
    with pytest.raises(ConfigError, match="unknown router policy"):
        ClusterOptions(router="fastest")


def _args(**kw):
    import argparse
    ns = argparse.Namespace(scheduler="continuous", tenant=None)
    for k, v in kw.items():
        setattr(ns, k, v)
    return ns


def test_from_args_builds_cluster_options():
    sc = ServingConfig.from_args(_args(replicas=2, router="round-robin"))
    assert sc.cluster is not None
    assert sc.cluster.replicas == 2
    assert sc.cluster.router == "round-robin"
    assert ServingConfig.from_args(_args()).cluster is None


def test_validate_args_cross_field_rules():
    with pytest.raises(ConfigError, match="--predictive requires"):
        validate_args(_args(predictive=True))
    with pytest.raises(ConfigError, match="requires --adaptive"):
        validate_args(_args(calibrate=True))
    with pytest.raises(ConfigError, match="--scheduler continuous"):
        validate_args(_args(scheduler="static", replicas=2))
    with pytest.raises(ConfigError, match="not yet supported"):
        validate_args(_args(replicas=2, fused_gather=True))
    with pytest.raises(ConfigError, match="not yet supported"):
        validate_args(_args(replicas=2, expert_policy="lru"))
    with pytest.raises(ConfigError, match="unknown --router"):
        validate_args(_args(router="fastest"))
    # the happy paths raise nothing
    validate_args(_args(replicas=2, router="headroom-distance"))
    validate_args(_args(adaptive=True, predictive=True, calibrate=True))
