"""Sharding rules: divisibility fitting, cache regimes, batch fallbacks."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config
from repro.launch.mesh import make_mesh
from repro.models import lm, shardings as sh


@pytest.fixture(scope="module")
def mesh11():
    return make_mesh((1, 1), ("data", "model"))


def _specs_for(arch, mesh):
    cfg = get_smoke_config(arch)
    shapes = jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    return cfg, shapes, sh.param_pspecs(shapes, mesh)


@pytest.mark.parametrize("arch", ["llama3-8b", "qwen3-moe-30b-a3b",
                                  "jamba-1.5-large-398b", "rwkv6-7b",
                                  "whisper-large-v3"])
def test_specs_always_divide(arch, mesh11):
    """Every assigned axis must divide its dim (here trivially, but the
    rule engine is exercised end-to-end on every family)."""
    cfg, shapes, specs = _specs_for(arch, mesh11)
    sizes = dict(zip(mesh11.axis_names, mesh11.devices.shape))

    def check(path, leaf, spec):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 9):
            if ax is not None:
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = 1
                for a in axes:
                    n *= sizes[a]
                assert dim % n == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(check, shapes, specs)


def test_nondividing_dim_replicated():
    """25 heads over 16-way TP (gpt2-style) must fall back to replicate."""
    mesh = make_mesh((1, 1), ("data", "model"))
    spec = sh._fit((25, 64), ("__fsdp__", "__tp__"), mesh,
                   "data", "model")
    # sizes are 1 so everything divides — test the logic with fake mesh:
    mesh2 = make_mesh((1,), ("model",))
    spec2 = sh._fit((25, 64), (None, "__tp__"), mesh2, "data", "model")
    assert spec2 == P(None, "model")  # 64 % 1 == 0
    # emulate 16-way by direct check of the rule helper
    import types
    fake = types.SimpleNamespace(axis_names=("data", "model"),
                                 devices=types.SimpleNamespace(
                                     shape=(2, 16)))
    got = sh._fit((25, 64), ("__tp__", "__fsdp__"), fake, "data", "model")
    assert got[0] is None          # 25 % 16 != 0 -> replicated
    assert got[1] == "data"        # 64 % 2 == 0


def test_batch_pspec_fallbacks():
    import types
    fake = types.SimpleNamespace(axis_names=("pod", "data", "model"),
                                 devices=types.SimpleNamespace(
                                     shape=(2, 16, 16)))
    assert sh.batch_pspec(256, fake, ("pod", "data")) == \
        P(("pod", "data"))
    assert sh.batch_pspec(2, fake, ("pod", "data")) == P("pod")
    assert sh.batch_pspec(1, fake, ("pod", "data")) == P(None)


def test_cache_pspecs_regimes():
    import types
    fake = types.SimpleNamespace(axis_names=("data", "model"),
                                 devices=types.SimpleNamespace(
                                     shape=(16, 16)))
    shapes = {
        "index": jax.ShapeDtypeStruct((), jnp.int32),
        "kv_k": jax.ShapeDtypeStruct((4, 1, 128, 36864, 8, 128),
                                     jnp.bfloat16),
    }
    # batch 128 divisible by dp 16 -> batch-sharded; kv=8 not /16 -> seq
    specs = sh.cache_pspecs(shapes, fake, 128, ("data",))
    assert specs["kv_k"] == P(None, None, ("data",), "model", None, None)
    # batch 1 -> seq sharded over (data, model)
    specs = sh.cache_pspecs(shapes, fake, 1, ("data",))
    assert specs["kv_k"][3] == ("data", "model")


def test_serve_params_tp_only():
    """Inference cells drop FSDP (TP-resident weights, §Perf O5)."""
    import jax
    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_mesh
    from repro.models import lm, shardings as sh
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_smoke_config("llama3-8b")
    shapes = jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    train_specs = sh.param_pspecs(shapes, mesh, fsdp="data")
    serve_specs = sh.param_pspecs(shapes, mesh, fsdp=None)
    # serve specs must never reference the data axis
    for s in jax.tree.leaves(serve_specs,
                             is_leaf=lambda x: hasattr(x, "index")):
        assert "data" not in [a for a in s if a], s
    # train specs do (at least somewhere)
    uses_data = any("data" in [a for a in s if a]
                    for s in jax.tree.leaves(
                        train_specs, is_leaf=lambda x: hasattr(x, "index")))
    assert uses_data
