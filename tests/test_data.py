"""Data pipeline: determinism, sharding partition, O(1) resume."""
import numpy as np
from _hyp import given, settings, st

from repro.data.pipeline import batch_for_step, DataConfig, DataIterator

CFG = DataConfig(vocab=1000, seq_len=16, global_batch=8, seed=42)


def test_deterministic():
    a = batch_for_step(CFG, 5)
    b = batch_for_step(CFG, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_steps_differ():
    a = batch_for_step(CFG, 5)
    b = batch_for_step(CFG, 6)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_labels_are_shifted_stream():
    b = batch_for_step(CFG, 0)
    assert b["tokens"].shape == (8, 16)
    assert b["labels"].shape == (8, 16)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 1000), dp=st.sampled_from([1, 2, 4, 8]))
def test_shard_rows_disjoint_and_seeded(step, dp):
    """Different ranks produce different data; shard sizes partition the
    global batch (stateless index map — any worker can recompute)."""
    shards = [batch_for_step(CFG, step, r, dp) for r in range(dp)]
    per = CFG.global_batch // dp
    for s in shards:
        assert s["tokens"].shape == (per, CFG.seq_len)
    if dp > 1:
        assert not np.array_equal(shards[0]["tokens"],
                                  shards[1]["tokens"])


def test_vocab_bounds():
    b = batch_for_step(CFG, 3)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < CFG.vocab


def test_iterator_resume():
    it = DataIterator(CFG, start_step=0)
    next(it)
    next(it)
    state = it.state()
    b3 = next(it)
    it2 = DataIterator(CFG)
    it2.restore(state)
    b3r = next(it2)
    np.testing.assert_array_equal(b3["tokens"], b3r["tokens"])
