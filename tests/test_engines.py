"""End-to-end engine tests: ZeRO-Offload train + FlexGen serve (tiny)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import tpu_v5e_tiers
from repro.data.pipeline import batch_for_step, DataConfig
from repro.models import lm
from repro.offload.serve_engine import (FlexGenEngine, max_batch_for_capacity,
                                        search_placement, ServeConfig)
from repro.offload.train_engine import OffloadConfig, ZeroOffloadEngine


@pytest.fixture(scope="module")
def tiny():
    cfg = get_smoke_config("stablelm-1.6b")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_zero_offload_trains(tiny):
    cfg, params = tiny
    eng = ZeroOffloadEngine(cfg, params, OffloadConfig(
        opt_state_shares=[("pinned_host", 1.0)]))
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    losses = []
    for step in range(3):
        b = batch_for_step(dc, step)
        t = eng.train_step({"tokens": jnp.asarray(b["tokens"]),
                            "labels": jnp.asarray(b["labels"])})
        assert np.isfinite(t.loss)
        assert t.optimizer_s > 0 and t.fwd_bwd_s > 0
        losses.append(t.loss)
    # optimizer states really live on the host tier
    host_bytes = eng.opt_state_bytes_on("pinned_host")
    assert host_bytes > 0
    assert eng.opt_state_bytes_on("device") == 0
    # training makes progress on the synthetic stream
    assert losses[-1] < losses[0] + 0.5


def test_zero_offload_interleave_all(tiny):
    """The paper's 'interleave all' policy: state split across kinds."""
    cfg, params = tiny
    eng = ZeroOffloadEngine(cfg, params, OffloadConfig(
        opt_state_shares=[("device", 0.34), ("pinned_host", 0.33),
                          ("unpinned_host", 0.33)]))
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2)
    b = batch_for_step(dc, 0)
    t = eng.train_step({"tokens": jnp.asarray(b["tokens"]),
                        "labels": jnp.asarray(b["labels"])})
    assert np.isfinite(t.loss)
    assert eng.opt_state_bytes_on("device") > 0
    assert eng.opt_state_bytes_on("pinned_host") > 0


def test_flexgen_serves(tiny):
    cfg, params = tiny
    eng = FlexGenEngine(cfg, params, ServeConfig(
        max_new_tokens=4, prompt_len=8,
        weight_shares=[("device", 0.5), ("pinned_host", 0.5)],
        kv_shares=[("device", 1.0)]))
    prompts = np.random.randint(0, cfg.vocab, (2, 8), dtype=np.int32)
    stats = eng.run(prompts)
    assert stats.batch == 2
    assert stats.prefill_s > 0 and stats.decode_s > 0
    assert stats.decode_tok_s > 0


def test_flexgen_kv_on_host(tiny):
    """KV cache resident on the host tier between decode steps."""
    cfg, params = tiny
    eng = FlexGenEngine(cfg, params, ServeConfig(
        max_new_tokens=3, prompt_len=8,
        weight_shares=[("device", 1.0)],
        kv_shares=[("device", 0.5), ("pinned_host", 0.5)]))
    prompts = np.random.randint(0, cfg.vocab, (2, 8), dtype=np.int32)
    stats = eng.run(prompts)
    assert np.isfinite(stats.decode_tok_s)


def test_policy_search_integration(tiny):
    cfg, _ = tiny
    res = search_placement(cfg, batch=4, seq=128, tier_set=tpu_v5e_tiers(),
                           fast="HBM")
    assert res.step_s > 0


def test_batch_scales_with_capacity(tiny):
    """LIO 3: more capacity -> larger feasible batch."""
    cfg, _ = tiny
    small = max_batch_for_capacity(cfg, 1024, 10 * 2**30)
    big = max_batch_for_capacity(cfg, 1024, 40 * 2**30)
    assert big > small >= 0
