"""TieredArray: block placement over memory kinds, gather/update."""
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import (available_memory_kinds, gather_pytree, place_pytree,
                        TieredArray)


def test_roundtrip_contiguous():
    x = jnp.arange(1024.0).reshape(64, 16)
    ta = TieredArray.place(x, [("device", 0.5), ("pinned_host", 0.5)])
    np.testing.assert_array_equal(np.asarray(ta.gather()), np.asarray(x))
    assert set(ta.kinds) == {"device", "pinned_host"}
    assert abs(ta.fast_fraction() - 0.5) < 0.05


def test_roundtrip_block_interleaved():
    x = jnp.arange(4096.0).reshape(256, 16)
    ta = TieredArray.place(x, [("device", 0.25), ("pinned_host", 0.75)],
                           block_rows=16)
    np.testing.assert_array_equal(np.asarray(ta.gather()), np.asarray(x))
    assert abs(ta.fast_fraction() - 0.25) < 0.1
    assert len(ta.blocks) == 16


def test_update_preserves_placement():
    x = jnp.ones((32, 8))
    ta = TieredArray.place(x, [("device", 0.5), ("unpinned_host", 0.5)])
    ta2 = ta.update(x * 3)
    assert ta2.kinds == ta.kinds
    np.testing.assert_array_equal(np.asarray(ta2.gather()),
                                  np.asarray(x * 3))


def test_prefetch_stream_order():
    x = jnp.arange(128.0).reshape(16, 8)
    ta = TieredArray.place(x, [("device", 0.5), ("pinned_host", 0.5)],
                           block_rows=4)
    got = jnp.concatenate(list(ta.prefetch_blocks()), axis=0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


def test_pytree_placement():
    tree = {"a": jnp.ones((16, 4)), "b": jnp.zeros((8,))}
    placed = place_pytree(tree, lambda n, l: [("pinned_host", 1.0)])
    out = gather_pytree(placed)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.ones((16, 4)))
    assert placed["a"].bytes_on("pinned_host") == placed["a"].nbytes


def test_memory_kinds_available():
    kinds = available_memory_kinds()
    assert "device" in kinds
    assert "pinned_host" in kinds  # the host tier must exist for offload


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(1, 64), cols=st.integers(1, 8),
       frac=st.floats(0.05, 0.95),
       block=st.one_of(st.none(), st.integers(1, 16)))
def test_roundtrip_property(rows, cols, frac, block):
    x = jnp.arange(float(rows * cols)).reshape(rows, cols)
    ta = TieredArray.place(
        x, [("device", frac), ("pinned_host", 1.0 - frac)],
        block_rows=block)
    np.testing.assert_array_equal(np.asarray(ta.gather()), np.asarray(x))
    total_rows = sum(b.shape[0] for b in ta.blocks)
    assert total_rows == rows
