"""repro.topology: graph queries, contention, distance-aware planning."""
import dataclasses

import pytest

from repro.core import (DataObject, distance_weighted_policy, GiB,
                        PlacementPlan, plan_step_cost, UniformInterleave)
from repro.telemetry import AccessTrace, AdaptiveReplanner
from repro.topology import (build_topology, Flow, TopologyGraph,
                            two_socket_system)

G = GiB


# ---------------------------------------------------------------------- #
# graph path queries                                                      #
# ---------------------------------------------------------------------- #
def test_path_queries_on_vendor_a():
    g = build_topology("vendor-a").graph
    assert g.hop_latency_ns("socket0", "cxl0") == pytest.approx(153.0)
    assert g.hop_latency_ns("socket0", "numa1") == pytest.approx(87.0)
    assert g.hop_latency_ns("socket0", "socket0") == 0.0
    # bottleneck bandwidth is the min link along the path
    assert g.path_bw_GBps("numa0", "cxl0") == pytest.approx(38.4)
    assert g.bottleneck("numa0", "cxl0").kind == "cxl"
    # tier-level views resolve through tier_nodes
    assert g.tier_latency_ns("CXL") == pytest.approx(153.0)
    assert g.tier_links("LDRAM")[0].kind == "local"
    assert g.tier_path("LDRAM", "CXL")[-1].kind == "cxl"


def test_effective_tiers_reproduce_paper_figure2():
    tb = build_topology("vendor-a")
    eff = tb.effective_tiers()
    lat = {t: v.unloaded_latency_ns + v.hop_latency_ns
           for t, v in eff.items()}
    assert lat["LDRAM"] == pytest.approx(118)
    assert lat["RDRAM"] == pytest.approx(205)      # +87 ns UPI hop
    assert lat["CXL"] == pytest.approx(271)        # +153 ns CXL link
    # remote DRAM bandwidth is capped by the cross-socket link
    assert eff["RDRAM"].peak_bw_GBps < eff["LDRAM"].peak_bw_GBps
    # the saturation knee survives the bandwidth cap
    assert eff["RDRAM"].saturation_streams == pytest.approx(
        tb.tiers["RDRAM"].saturation_streams)


def test_far_socket_pays_the_extra_hop():
    near = build_topology("vendor-a").effective_tiers()
    far = build_topology("far-socket").effective_tiers()
    assert far["CXL"].hop_latency_ns == pytest.approx(87 + 153)
    assert (far["CXL"].unloaded_latency_ns + far["CXL"].hop_latency_ns
            > near["CXL"].unloaded_latency_ns
            + near["CXL"].hop_latency_ns)
    # LDRAM is unaffected by where the card sits
    assert far["LDRAM"] == near["LDRAM"]


def test_unknown_topology_and_bad_graph_usage_raise():
    with pytest.raises(ValueError, match="unknown topology"):
        build_topology("vendor-z")
    g = TopologyGraph()
    g.add_node("a")
    with pytest.raises(ValueError):
        g.add_node("a")
    with pytest.raises(ValueError):
        g.add_link("a", "missing", 1.0, 1.0)
    g.add_node("b")
    with pytest.raises(ValueError):
        g.path("a", "b")          # disconnected


# ---------------------------------------------------------------------- #
# shared-link contention                                                  #
# ---------------------------------------------------------------------- #
def test_contention_fair_shares_the_bottleneck_link():
    g = build_topology("far-socket").graph   # UPI: 230 GB/s
    f1 = Flow("socket0", "numa1", 200.0)
    f2 = Flow("socket0", "cxl0", 100.0)      # also crosses UPI
    solo = g.contended_flows([f2])[0]
    r1, r2 = g.contended_flows([f1, f2])
    # 300 GB/s offered over a 230 GB/s link: proportional fair share
    # cuts the RDRAM flow below its solo rate
    assert r1.achieved_GBps == pytest.approx(230 * 200 / 300)
    # the CXL flow stays pinned at its own card link...
    assert r2.achieved_GBps <= solo.achieved_GBps
    assert r2.bottleneck == ("cxl0", "socket1")
    # ...but M/M/1 queueing on the shared UPI hop inflates its latency
    assert r2.latency_ns > solo.latency_ns


def test_disjoint_flows_do_not_interfere():
    g = build_topology("vendor-a").graph
    f1 = Flow("socket0", "numa0", 100.0)
    f2 = Flow("socket1", "numa1", 100.0)
    r1, r2 = g.contended_flows([f1, f2])
    assert r1.achieved_GBps == pytest.approx(100.0)
    assert r2.achieved_GBps == pytest.approx(100.0)


# ---------------------------------------------------------------------- #
# class-weighted (asymmetric) interference                                #
# ---------------------------------------------------------------------- #
def test_interference_matrix_asymmetry_and_kind_scaling():
    from repro.topology import InterferenceMatrix
    m = InterferenceMatrix()
    # same-class pairs always price at 1.0 (symmetric back-compat)
    assert m.weight("upi", "read", "read") == 1.0
    assert m.weight("cxl", "write", "write") == 1.0
    # writer-on-reader hits far harder than reader-on-writer
    assert m.weight("upi", "read", "write") == pytest.approx(1.6)
    assert m.weight("upi", "write", "read") == pytest.approx(0.85)
    # CXL controllers amplify the asymmetry; local links damp it
    assert m.weight("cxl", "read", "write") > m.weight(
        "upi", "read", "write") > m.weight("local", "read", "write")
    # calibration pair scales multiply on top, floored at 0.05
    scaled = m.with_pair_scales({("upi", "read", "write"): 1.5})
    assert scaled.weight("upi", "read", "write") == pytest.approx(2.4)
    assert scaled.weight("upi", "write", "read") == pytest.approx(0.85)
    floored = m.with_pair_scales({("upi", "write", "read"): 1e-9})
    assert floored.weight("upi", "write", "read") == 0.05


def test_contention_write_class_degrades_reader_asymmetrically():
    g = build_topology("far-socket").graph   # UPI: 230 GB/s
    reader = Flow("socket0", "numa1", 100.0, cls="read", tenant="v")
    # 100 GB/s of co-located readers: total 200 < 230, no sharing
    r_read, _ = g.contended_flows(
        [reader, Flow("socket0", "numa1", 100.0, cls="read")])
    assert r_read.achieved_GBps == pytest.approx(100.0)
    # the same offered load as writers weighs 1.6x on the reader's
    # queue (260 > 230): the reader is squeezed...
    r_vic, r_agg = g.contended_flows(
        [reader, Flow("socket0", "numa1", 100.0, cls="write")])
    assert r_vic.achieved_GBps == pytest.approx(230 * 100 / 260)
    assert r_vic.raw_rho == pytest.approx(260 / 230)
    # ...while the writer's own view (100 + 0.85*100 = 185 < 230)
    # stays healthy — asymmetry, not fair share
    assert r_agg.achieved_GBps == pytest.approx(100.0)
    assert r_agg.raw_rho < 1.0
    # and the reader's loaded latency exceeds the all-reader case
    assert r_vic.latency_ns > r_read.latency_ns


def test_all_read_flows_reproduce_symmetric_fair_share():
    """Legacy call sites (no cls) must price exactly as before the
    interference matrix existed."""
    g = build_topology("far-socket").graph
    flows = [Flow("socket0", "numa1", 200.0),
             Flow("socket0", "cxl0", 100.0)]
    r1, r2 = g.contended_flows(flows)
    assert r1.achieved_GBps == pytest.approx(230 * 200 / 300)
    assert r2.bottleneck == ("cxl0", "socket1")
    # the new surfacing fields report the (pre-existing) latency clamp
    assert r1.clamped and r1.raw_rho == pytest.approx(300 / 230)


def test_link_saturation_counted_and_traced():
    from repro.obs import TraceRecorder
    g = build_topology("far-socket").graph
    tracer = TraceRecorder(clock=lambda: 0.0)
    flows = [Flow("socket0", "numa1", 150.0, cls="read"),
             Flow("socket0", "numa1", 150.0, cls="write")]
    res = g.contended_flows(flows, tracer=tracer)
    # reader rho = (150 + 1.6*150)/230 > 0.95: clamp engages
    assert res[0].clamped and res[0].raw_rho > 0.95
    assert g.link_saturations[("socket0", "socket1")] == 1
    evs = tracer.filter(name="link.saturated")
    assert len(evs) == 1                     # once per link per call
    assert evs[0].args["link"] == "socket0-socket1"
    assert evs[0].args["kind"] == "upi"
    assert evs[0].args["raw_rho"] > 0.95
    # a second call bumps the counter again
    g.contended_flows(flows)
    assert g.link_saturations[("socket0", "socket1")] == 2
    # an uncontended call records nothing
    g.contended_flows([Flow("socket0", "numa0", 10.0)])
    assert len(g.link_saturations) == 1


def test_link_loads_attribute_per_tenant_and_class():
    g = build_topology("far-socket").graph
    loads = g.link_loads([
        Flow("socket0", "numa1", 60.0, cls="read", tenant="a"),
        Flow("socket0", "numa1", 40.0, cls="write", tenant="a"),
        Flow("socket0", "cxl0", 30.0, cls="read", tenant="b"),
    ])
    upi = loads[("socket0", "socket1")]
    assert upi[("a", "read")] == pytest.approx(60.0)
    assert upi[("a", "write")] == pytest.approx(40.0)
    assert upi[("b", "read")] == pytest.approx(30.0)   # cxl path crosses UPI
    assert loads[("cxl0", "socket1")] == {("b", "read"): pytest.approx(30.0)}


def test_rebuilt_graph_carries_interference_matrix():
    from repro.topology import InterferenceMatrix
    g = build_topology("far-socket").graph
    g.interference = InterferenceMatrix().with_pair_scales(
        {("upi", "read", "write"): 2.0})
    rg = g.rebuilt({("socket0", "socket1"): (87.0, 115.0)})
    assert rg.interference.weight("upi", "read", "write") == \
        pytest.approx(3.2)
    assert rg.links[("socket0", "socket1")].bw_GBps == 115.0


# ---------------------------------------------------------------------- #
# distance-aware costing (acceptance criteria)                            #
# ---------------------------------------------------------------------- #
def _cxl_resident_cost(name: str) -> float:
    tb = build_topology(name)
    objs = [DataObject("table", 64 * G, read_bytes_per_step=64 * G,
                       random_fraction=0.6)]
    plan = PlacementPlan({"table": [("CXL", 1.0)]}, "pinned", {})
    return plan_step_cost(objs, plan, tb.tiers,
                          topology=tb.graph).step_s


def test_far_socket_cxl_strictly_slower_in_step_time():
    assert _cxl_resident_cost("far-socket") \
        > _cxl_resident_cost("vendor-a")


def test_shared_hop_serializes_interleaved_traffic():
    """An object interleaved across RDRAM + CXL: with the card on the
    far socket both shares squeeze through one UPI link, so the phase
    is gated by the link's *summed* traffic; near-socket keeps the
    paths disjoint and the slowest share gates instead."""
    objs = [DataObject("field", 64 * G, read_bytes_per_step=128 * G)]
    plan = PlacementPlan({"field": [("RDRAM", 0.88), ("CXL", 0.12)]},
                         "pinned", {})
    costs = {}
    for name in ("vendor-a", "far-socket"):
        tb = build_topology(name)
        costs[name] = plan_step_cost(objs, plan, tb.tiers,
                                     topology=tb.graph)
    far, near = costs["far-socket"], costs["vendor-a"]
    assert far.step_s > near.step_s
    # the UPI link is charged with BOTH shares' bytes in the far config
    upi_far = far.link_time["socket0--socket1"]
    assert upi_far > near.link_time["socket0--socket1"]
    assert upi_far == pytest.approx(128 * G / (230.0 * 1e9))
    # and it is the binding resource: slower than either tier share
    assert upi_far > max(far.per_tier_time.values())


def test_distance_weighted_interleave_beats_uniform_at_equal_capacity():
    tb = build_topology("vendor-a")
    tiers = {k: v for k, v in tb.tiers.items() if k != "NVMe"}
    tiers["LDRAM"] = dataclasses.replace(tiers["LDRAM"],
                                         capacity_GiB=64)
    objs = [DataObject("field", 192 * G,
                       read_bytes_per_step=2 * 192 * G)]
    w = tb.graph.tier_weights(tiers)
    assert w["LDRAM"] > w["RDRAM"] > w["CXL"] > 0
    assert sum(w.values()) == pytest.approx(1.0)
    assert "NVMe" not in w
    uni = UniformInterleave(["LDRAM", "RDRAM", "CXL"])
    wtd = distance_weighted_policy(tb.graph, tiers)
    cost = {p.name: plan_step_cost(objs, p.plan(objs, tiers), tiers,
                                   topology=tb.graph).step_s
            for p in (uni, wtd)}
    assert cost[wtd.name] <= cost[uni.name]
    # weighted plan respects the fast-tier capacity cap
    shares = dict(wtd.plan(objs, tiers).shares["field"])
    assert shares["LDRAM"] * 192 * G <= 64 * G * 1.001


# ---------------------------------------------------------------------- #
# replanner orders tiers by measured distance                             #
# ---------------------------------------------------------------------- #
def test_replanner_tier_order_follows_origin_distance():
    from conftest import dual_cxl_machine

    g, tiers = dual_cxl_machine()
    rp0 = AdaptiveReplanner(AccessTrace(), tiers, "DRAM0",
                            topology=g, origin="socket0")
    assert rp0.tier_order == ["DRAM0", "DRAM1", "CXL0", "CXL1"]
    assert rp0.default_tier == "CXL1"     # new objects land farthest
    rp1 = AdaptiveReplanner(AccessTrace(), tiers, "DRAM1",
                            topology=g, origin="socket1")
    assert rp1.tier_order == ["DRAM1", "DRAM0", "CXL1", "CXL0"]
    # the distance view is folded into the replanner's tier set
    assert rp0.tiers["CXL1"].hop_latency_ns == pytest.approx(240.0)
    assert rp1.tiers["CXL1"].hop_latency_ns == pytest.approx(153.0)


def test_alias_tier_reuses_a_node_under_a_new_name():
    g = build_topology("tpu-pod").graph
    g.alias_tier("HBM", "device")
    g.alias_tier("HOST", "pinned_host")
    assert g.node_of("device") == g.node_of("HBM")
    assert g.tier_latency_ns("pinned_host") \
        == g.tier_latency_ns("HOST")
    with pytest.raises(KeyError):
        g.alias_tier("nope", "x")


def test_two_socket_builder_places_card_behind_requested_socket():
    far = two_socket_system("A", cxl_socket=1)
    assert far.graph.tier_links("CXL")[0].kind == "upi"
    near = two_socket_system("A", cxl_socket=0)
    assert near.graph.tier_links("CXL")[0].kind == "cxl"
