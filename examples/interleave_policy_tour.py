"""Tour of the paper's placement policies on the HPC dwarfs (Figs 13-15),
plus the topology-derived distance-weighted interleave mode.

Exits non-zero if a policy comparison regresses (the checks at the
bottom encode the relationships the paper's figures establish), so the
tour doubles as a guard in CI-ish runs:

    PYTHONPATH=src python examples/interleave_policy_tour.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.core import (compare_policies,  # noqa: E402
                        distance_weighted_policy, hpc_workload_objects,
                        ObjectLevelInterleave, paper_system,
                        TierPreferred, UniformInterleave)
from repro.topology import build_topology  # noqa: E402

WORKLOADS = ("BT", "LU", "CG", "MG", "SP", "FT", "XSBench")
TOL = 1.001


def main() -> int:
    testbed = build_topology("vendor-a")
    regressions = []
    for cap, tag in ((128, "sufficient"), (64, "insufficient")):
        tiers = {k: v for k, v in paper_system("A").items()
                 if k in ("LDRAM", "CXL")}
        tiers["LDRAM"] = dataclasses.replace(tiers["LDRAM"],
                                             capacity_GiB=cap)
        weighted = distance_weighted_policy(
            testbed.graph, tiers, tier_set=["LDRAM", "CXL"],
            name="distance_weighted")
        print(f"\n=== LDRAM {cap} GB ({tag}) + CXL, system A ===")
        print(f"{'workload':10s} {'preferred':>10s} {'uniform':>10s} "
              f"{'weighted':>10s} {'OLI':>10s}  best")
        for wl in WORKLOADS:
            objs = hpc_workload_objects(wl)
            costs = compare_policies(
                objs,
                [TierPreferred("LDRAM"),
                 UniformInterleave(["LDRAM", "CXL"]),
                 weighted,
                 ObjectLevelInterleave("LDRAM", ["CXL"])],
                tiers)
            p = costs["LDRAM_preferred"].step_s
            u = costs["uniform_interleave[LDRAM+CXL]"].step_s
            w = costs["distance_weighted"].step_s
            o = costs["oli[LDRAM+CXL]"].step_s
            best = min((p, "preferred"), (u, "uniform"),
                       (w, "weighted"), (o, "OLI"))[1]
            print(f"{wl:10s} {p:9.2f}s {u:9.2f}s {w:9.2f}s {o:9.2f}s"
                  f"  {best}")

            # -- policy-comparison invariants (paper Figs 13-15) -------
            if cap == 128:
                # with sufficient fast memory, blind uniform interleave
                # never wins: bandwidth-aware shares (weighted) and
                # object selection (OLI) both dominate it
                if w > u * TOL:
                    regressions.append(
                        f"{tag}/{wl}: distance-weighted {w:.2f}s > "
                        f"uniform {u:.2f}s")
                if o > u * TOL:
                    regressions.append(
                        f"{tag}/{wl}: OLI {o:.2f}s > uniform {u:.2f}s")
            else:
                # with insufficient fast memory, fast-preferred is the
                # fragile policy: some interleaving variant must match
                # or beat it on every workload
                if min(u, w, o) > p * TOL:
                    regressions.append(
                        f"{tag}/{wl}: best interleave "
                        f"{min(u, w, o):.2f}s > preferred {p:.2f}s")

    if regressions:
        print("\nPOLICY-COMPARISON REGRESSIONS:", file=sys.stderr)
        for r in regressions:
            print(f"  {r}", file=sys.stderr)
        return 1
    print("\nall policy-comparison invariants hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
