"""Tour of the paper's placement policies on the HPC dwarfs (Figs 13-15).

    PYTHONPATH=src python examples/interleave_policy_tour.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.core import (ObjectLevelInterleave, TierPreferred,  # noqa: E402
                        UniformInterleave, compare_policies,
                        hpc_workload_objects, paper_system)


def main():
    for cap, tag in ((128, "sufficient"), (64, "insufficient")):
        tiers = {k: v for k, v in paper_system("A").items()
                 if k in ("LDRAM", "CXL")}
        tiers["LDRAM"] = dataclasses.replace(tiers["LDRAM"],
                                             capacity_GiB=cap)
        print(f"\n=== LDRAM {cap} GB ({tag}) + CXL, system A ===")
        print(f"{'workload':10s} {'preferred':>10s} {'uniform':>10s} "
              f"{'OLI':>10s}  best")
        for wl in ("BT", "LU", "CG", "MG", "SP", "FT", "XSBench"):
            objs = hpc_workload_objects(wl)
            costs = compare_policies(
                objs,
                [TierPreferred("LDRAM"),
                 UniformInterleave(["LDRAM", "CXL"]),
                 ObjectLevelInterleave("LDRAM", ["CXL"])],
                tiers)
            p = costs["LDRAM_preferred"].step_s
            u = costs["uniform_interleave[LDRAM+CXL]"].step_s
            o = costs["oli[LDRAM+CXL]"].step_s
            best = min((p, "preferred"), (u, "uniform"), (o, "OLI"))[1]
            print(f"{wl:10s} {p:9.2f}s {u:9.2f}s {o:9.2f}s  {best}")


if __name__ == "__main__":
    main()
