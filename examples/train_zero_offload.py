"""End-to-end driver: ZeRO-Offload training (~100M model, few hundred
steps), optimizer state on the HOST tier — the paper's Sec. IV-A use case
with real memory-kind placement, checkpoint/restart included.

    PYTHONPATH=src python examples/train_zero_offload.py \
        --steps 300 --policy ldram+cxl
"""
import argparse
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.checkpoint import store                      # noqa: E402
from repro.configs.base import LayerSpec, ModelConfig   # noqa: E402
from repro.data.pipeline import DataConfig, DataIterator  # noqa: E402
from repro.models import lm                              # noqa: E402
from repro.offload.train_engine import (OffloadConfig,  # noqa: E402
                                        ZeroOffloadEngine)

POLICIES = {
    "ldram_only": [("device", 1.0)],
    "ldram+cxl": [("device", 0.5), ("unpinned_host", 0.5)],
    "ldram+rdram": [("device", 0.5), ("pinned_host", 0.5)],
    "interleave_all": [("device", 0.34), ("pinned_host", 0.33),
                       ("unpinned_host", 0.33)],
    "host_only": [("pinned_host", 1.0)],
}

# ~100M-parameter GPT-style model
CFG = ModelConfig(
    name="gpt-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv=12, d_ff=3072, vocab=32000, head_dim=64,
    pattern=(LayerSpec(kind="attn"),), norm="ln", act="gelu",
    pos_emb="learned", max_pos=1024, tie_embeddings=True, remat=False,
    attn_chunk=256, loss_chunk=128)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--policy", default="host_only",
                    choices=list(POLICIES))
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    print(f"~{CFG.param_count()/1e6:.0f}M params; opt-state policy: "
          f"{args.policy}")
    params = lm.init_params(jax.random.PRNGKey(0), CFG)
    eng = ZeroOffloadEngine(CFG, params, OffloadConfig(
        opt_state_shares=POLICIES[args.policy]))

    dc = DataConfig(vocab=CFG.vocab, seq_len=args.seq,
                    global_batch=args.batch)
    it = DataIterator(dc)
    start = 0
    if store.latest_step(args.ckpt_dir) is not None:
        state, meta = store.restore(args.ckpt_dir, eng.params)
        eng.params = state
        start = meta["step"]
        it.restore({"step": start})
        print(f"restored at step {start}")

    t_hist = []
    for i in range(start, args.steps):
        b = next(it)
        t = eng.train_step({"tokens": jnp.asarray(b["tokens"]),
                            "labels": jnp.asarray(b["labels"])})
        t_hist.append(t)
        if i % 20 == 0:
            print(f"step {i:4d} loss={t.loss:.4f} total={t.total_s*1e3:6.1f}ms "
                  f"[fwd/bwd {t.fwd_bwd_s*1e3:6.1f} | grad→host "
                  f"{t.grad_xfer_s*1e3:5.1f} | adam(host) "
                  f"{t.optimizer_s*1e3:6.1f} | params→dev "
                  f"{t.param_xfer_s*1e3:5.1f}]")
        if args.ckpt_every and i and i % args.ckpt_every == 0:
            store.save(args.ckpt_dir, i, eng.params,
                       metadata={"step": i})
    host = eng.opt_state_bytes_on("pinned_host") \
        + eng.opt_state_bytes_on("unpinned_host")
    print(f"\nopt state on host tiers: {host/2**20:.0f} MiB; "
          f"mean step {sum(x.total_s for x in t_hist)/len(t_hist)*1e3:.1f} ms")


if __name__ == "__main__":
    main()
