"""Quickstart: train a small LM for a few steps with the public API.

    PYTHONPATH=src python examples/quickstart.py [--steps 20]
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.configs import get_smoke_config              # noqa: E402
from repro.data.pipeline import DataConfig, DataIterator  # noqa: E402
from repro.launch.steps import make_train_step          # noqa: E402
from repro.models import lm                              # noqa: E402
from repro.optim import AdamConfig, init_state          # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    print(f"arch={args.arch} (reduced: {cfg.n_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab})")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    acfg = AdamConfig(lr=3e-3)
    opt = init_state(params, acfg)
    step = jax.jit(make_train_step(cfg, acfg))

    dc = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
    it = DataIterator(dc)
    for i in range(args.steps):
        b = next(it)
        t0 = time.perf_counter()
        params, opt, loss = step(params, opt,
                                 {"tokens": jnp.asarray(b["tokens"]),
                                  "labels": jnp.asarray(b["labels"])})
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:3d} loss={float(loss):.4f} "
                  f"({(time.perf_counter()-t0)*1e3:.0f} ms)")
    print("done — loss should be falling on the synthetic stream.")


if __name__ == "__main__":
    main()
