"""Batched serving with tier-resident weights/KV (paper Sec. IV-B).

    PYTHONPATH=src python examples/serve_flexgen.py --batch 8
"""
import argparse
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_smoke_config              # noqa: E402
from repro.core import tpu_v5e_tiers                    # noqa: E402
from repro.models import lm                              # noqa: E402
from repro.offload.serve_engine import (FlexGenEngine,  # noqa: E402
                                        search_placement, ServeConfig)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-65b-serve")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)

    # cost-model placement search (the paper's LP search)
    res = search_placement(cfg, args.batch, args.prompt_len
                           + args.new_tokens, tpu_v5e_tiers(), fast="HBM")
    print("placement search:",
          {k: {t: round(f, 2) for t, f in v.items()}
           for k, v in res.fractions.items()})

    eng = FlexGenEngine(cfg, params, ServeConfig(
        max_new_tokens=args.new_tokens, prompt_len=args.prompt_len,
        weight_shares=[("device", 0.7), ("pinned_host", 0.3)],
        kv_shares=[("device", 1.0)]))
    prompts = np.random.RandomState(0).randint(
        0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
    st = eng.run(prompts)
    print(f"batch={st.batch} prefill={st.prefill_s*1e3:.1f}ms "
          f"decode={st.decode_tok_s:.1f} tok/s "
          f"({st.new_tokens} tokens/seq)")


if __name__ == "__main__":
    main()
