from .train_engine import ZeroOffloadEngine, OffloadConfig, StepTiming
from .serve_engine import (FlexGenEngine, ServeConfig, ServeStats,
                           search_placement, max_batch_for_capacity)
