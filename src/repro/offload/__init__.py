from .serve_engine import (FlexGenEngine, max_batch_for_capacity,
                           search_placement, ServeConfig, ServeStats)
from .train_engine import OffloadConfig, StepTiming, ZeroOffloadEngine

__all__ = [
    "FlexGenEngine", "max_batch_for_capacity", "OffloadConfig",
    "search_placement", "ServeConfig", "ServeStats", "StepTiming",
    "ZeroOffloadEngine",
]
