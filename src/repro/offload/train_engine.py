"""ZeRO-Offload training engine (paper Sec. IV-A, TPU-native).

Reproduces the paper's tensor-offloading training loop with real host
placement:

  * fp32 master params + Adam moments live on the HOST tier, placed by a
    configurable policy (the paper's interleaving study: LDRAM-only /
    +CXL / +RDRAM / interleave-all map to placement shares across
    memory kinds via TieredArray);
  * each step: device computes loss+grads (jitted, sharded); gradient
    buckets stream device->host (overlapped, double-buffered); the fused
    Adam kernel updates master/m/v host-side; updated params stream back
    host->device as bf16.
  * step-time decomposition mirrors Fig. 9: {fwd_bwd, grad_xfer,
    optimizer, param_xfer} — the benchmark reads these.

The paper's headline findings fall out of the cost model + this engine:
the optimizer is the tier-bandwidth-sensitive phase; the transfers ride
the accelerator<->host interconnect and do NOT benefit from extra
slow-tier bandwidth (LLM training observation 1).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.tiered_array import gather_pytree, place_pytree, TieredArray
from ..kernels import ops as kops
from ..launch import steps as steps_mod
from ..optim import adam


@dataclasses.dataclass
class OffloadConfig:
    # fraction shares of opt-state bytes per memory kind — the paper's
    # interleaving policies expressed directly:
    #   LDRAM only      -> [("device", 1.0)]
    #   LDRAM + CXL     -> [("device", .5), ("unpinned_host", .5)]
    #   interleave all  -> thirds
    opt_state_shares: Sequence[Tuple[str, float]] = (("pinned_host", 1.0),)
    bucket_mb: int = 64            # gradient bucket size for overlap
    use_fused_kernel: bool = True
    adam: adam.AdamConfig = dataclasses.field(default_factory=adam.AdamConfig)


def emit_step_traffic(telemetry, param_bytes: int) -> None:
    """Record one train step's per-phase traffic (the Fig. 9 phases).

    The single source of the ZeRO-Offload traffic model: params read
    twice on fwd/bwd, grads streamed device->host, fp32 master+m+v
    (6x the bf16 param bytes) read and rewritten by the optimizer,
    updated params streamed back.  Used by the engine and by the train
    CLI's telemetry sidecar so both observe identical traffic.
    """
    pb = param_bytes
    telemetry.observe("params_bf16", 2 * pb, 0, 0.0, phase="fwd_bwd")
    telemetry.observe("grads_bf16", pb, pb, 0.0, phase="grad_xfer")
    telemetry.observe("opt_state_fp32", 6 * pb, 6 * pb, 0.0,
                      phase="optimizer")
    telemetry.observe("params_bf16", 0, pb, 0.0, phase="param_xfer")
    telemetry.advance_epoch()


@dataclasses.dataclass
class StepTiming:
    fwd_bwd_s: float
    grad_xfer_s: float
    optimizer_s: float
    param_xfer_s: float
    loss: float

    @property
    def total_s(self) -> float:
        return (self.fwd_bwd_s + self.grad_xfer_s + self.optimizer_s
                + self.param_xfer_s)


class ZeroOffloadEngine:
    """Single-host engine exercising real host-tier placement.

    ``telemetry`` (an AccessTrace or AccessSampler) receives one event
    per Fig.-9 phase per step — params read on fwd/bwd, grads written on
    transfer, opt state read+written by the optimizer, params written
    back — so the adaptive replanner sees the same phase structure the
    timing decomposition reports.
    """

    def __init__(self, cfg: ModelConfig, params: Any,
                 off: Optional[OffloadConfig] = None,
                 telemetry=None):
        self.cfg = cfg
        self.off = off or OffloadConfig()
        self.params = params
        self.telemetry = telemetry
        self.grad_step = jax.jit(steps_mod.make_grad_step(cfg))
        # host-resident fp32 state as TieredArrays with the policy shares
        shares = list(self.off.opt_state_shares)
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        self.master = place_pytree(
            jax.tree.map(lambda p: p.astype(jnp.float32), params),
            lambda name, leaf: shares)
        self.m = place_pytree(jax.tree.map(f32, params),
                              lambda name, leaf: shares)
        self.v = place_pytree(jax.tree.map(f32, params),
                              lambda name, leaf: shares)
        self.step_count = 0

    # ------------------------------------------------------------------ #
    def _param_bytes(self) -> int:
        return sum(p.nbytes for p in jax.tree.leaves(self.params))

    # ------------------------------------------------------------------ #
    def train_step(self, batch: Dict[str, jax.Array]) -> StepTiming:
        o = self.off.adam
        t0 = time.perf_counter()
        loss, grads = self.grad_step(self.params, batch)
        jax.block_until_ready(loss)
        t1 = time.perf_counter()

        # gradient "transfer": materialize grads host-side bucket by
        # bucket (double-buffered device_put pipeline via TieredArray)
        host = [("pinned_host", 1.0)]
        grads_host = place_pytree(grads, lambda n, l: host)
        jax.block_until_ready(jax.tree.leaves(
            gather_pytree(jax.tree.map(lambda t: t.blocks[0], grads_host,
                                       is_leaf=lambda x: isinstance(
                                           x, TieredArray)))))
        t2 = time.perf_counter()

        # host-side fused Adam over each leaf (the paper's CPU optimizer)
        self.step_count += 1
        b1c = 1.0 - o.b1 ** self.step_count
        b2c = 1.0 - o.b2 ** self.step_count
        new_params = []
        flat_p, tdef = jax.tree.flatten(self.params)
        fm = tdef.flatten_up_to(self.master)
        fmm = tdef.flatten_up_to(self.m)
        fv = tdef.flatten_up_to(self.v)
        fg = tdef.flatten_up_to(grads_host)
        out_m, out_mm, out_v = [], [], []
        for p, ma, mm, vv, gg in zip(flat_p, fm, fmm, fv, fg):
            mag = ma.gather()
            mmg = mm.gather()
            vvg = vv.gather()
            ggg = gg.gather()
            if self.off.use_fused_kernel:
                nm, m2, v2 = kops.fused_adam(
                    mag, mmg, vvg, ggg, lr=o.lr, b1=o.b1, b2=o.b2,
                    eps=o.eps, wd=o.weight_decay, b1c=b1c, b2c=b2c)
            else:
                from ..kernels import ref as kref
                nm, m2, v2 = kref.fused_adam(
                    mag, mmg, vvg, ggg, lr=o.lr, b1=o.b1, b2=o.b2,
                    eps=o.eps, wd=o.weight_decay, b1c=b1c, b2c=b2c)
            out_m.append(ma.update(nm))
            out_mm.append(mm.update(m2))
            out_v.append(vv.update(v2))
            new_params.append(nm.astype(p.dtype))
        jax.block_until_ready(new_params)
        t3 = time.perf_counter()

        self.master = jax.tree.unflatten(tdef, out_m)
        self.m = jax.tree.unflatten(tdef, out_mm)
        self.v = jax.tree.unflatten(tdef, out_v)
        # param transfer host->device (bf16)
        self.params = jax.tree.unflatten(tdef, [
            jax.device_put(p) for p in new_params])
        jax.block_until_ready(jax.tree.leaves(self.params))
        t4 = time.perf_counter()

        if self.telemetry is not None:
            emit_step_traffic(self.telemetry, self._param_bytes())

        return StepTiming(t1 - t0, t2 - t1, t3 - t2, t4 - t3,
                          float(loss))

    def opt_state_bytes_on(self, kind: str) -> int:
        total = 0
        for t in (self.master, self.m, self.v):
            for leaf in jax.tree.leaves(
                    t, is_leaf=lambda x: isinstance(x, TieredArray)):
                total += leaf.bytes_on(kind)
        return total
