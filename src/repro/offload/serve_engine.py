"""FlexGen-style serving engine (paper Sec. IV-B, TPU-native).

Reproduces the paper's inference use case with real tier placement:

  * weights / KV-cache / activations are placed across {device,
    pinned_host, unpinned_host} by a policy searched with the cost model
    (core.costmodel.policy_search — the paper's LP search);
  * prefill runs on device; decode streams tier-resident KV blocks
    through the decode-attention path;
  * batch size is chosen to fill the capacity budget (LIO 3: "CXL
    increases capacity -> larger batch -> throughput").

The engine reports prefill/decode throughput separately (Fig. 11's split:
prefill is latency-sensitive, decode bandwidth-sensitive).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core import costmodel, objects as obj_mod, tiers as tiers_mod
from ..core.tiered_array import gather_pytree, place_pytree
from ..launch import steps as steps_mod
from ..serving.kv_pool import TieredKVCache


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    prompt_len: int = 64
    # tier capacity budget in bytes for {device HBM-analogue, host}
    device_budget: Optional[int] = None
    weight_shares: Sequence[Tuple[str, float]] = (("device", 1.0),)
    kv_shares: Sequence[Tuple[str, float]] = (("device", 1.0),)


@dataclasses.dataclass
class ServeStats:
    batch: int
    prefill_s: float
    decode_s: float
    new_tokens: int

    @property
    def prefill_tok_s(self) -> float:
        return self.batch * 1.0 / max(self.prefill_s, 1e-9)

    @property
    def decode_tok_s(self) -> float:
        return self.batch * self.new_tokens / max(self.decode_s, 1e-9)


def search_placement(cfg: ModelConfig, batch: int, seq: int,
                     tier_set: Mapping[str, tiers_mod.MemoryTier],
                     fast: str = "HBM") -> costmodel.SearchResult:
    """FlexGen's policy search over our cost model."""
    n_params = cfg.param_count()
    kv_bytes = (cfg.n_layers * 2 * batch * seq * cfg.n_kv
                * cfg.head_dim * 2)
    act_bytes = batch * cfg.d_model * 4 * cfg.n_layers
    objs = obj_mod.llm_serve_objects(n_params, kv_bytes, act_bytes)
    return costmodel.policy_search(objs, tier_set, fast=fast, grid=10)


class FlexGenEngine:
    """Batched prefill+decode with tier-resident weights/KV.

    ``telemetry`` (an AccessTrace or AccessSampler) receives per-phase
    traffic: one write-heavy prefill epoch, then one epoch per decode
    step (weights + KV streamed, one token's KV written) — the Fig. 11
    latency/bandwidth split as an observable signal.
    """

    def __init__(self, cfg: ModelConfig, params: Any,
                 serve: Optional[ServeConfig] = None,
                 telemetry=None, ledger=None, tenant: str = "flexgen"):
        self.cfg = cfg
        self.serve_cfg = serve or ServeConfig()
        self.telemetry = telemetry
        # KV residency is accounted in the (possibly shared) ledger
        # under this engine's tenant namespace
        self.ledger = ledger
        self.tenant = tenant
        self.kv_home: Optional[TieredKVCache] = None
        sc = self.serve_cfg
        # place weights per policy (block-interleaved TieredArrays)
        self.params_tiered = place_pytree(
            params, lambda n, l: list(sc.weight_shares), block_rows=None)
        self.prefill_step = jax.jit(steps_mod.make_prefill_step(cfg))
        self.decode_step = jax.jit(steps_mod.make_serve_step(cfg))

    def _materialize_params(self):
        return gather_pytree(self.params_tiered)

    def run(self, prompts: np.ndarray,
            frames: Optional[np.ndarray] = None) -> ServeStats:
        """prompts: (B, prompt_len) int32."""
        sc = self.serve_cfg
        B, P = prompts.shape
        params = self._materialize_params()
        batch = {"tokens": jnp.asarray(prompts)}
        if frames is not None:
            batch["frames"] = jnp.asarray(frames)

        t0 = time.perf_counter()
        logits, cache = self.prefill_step(params, batch)
        jax.block_until_ready(logits)
        t1 = time.perf_counter()

        w_bytes = sum(p.nbytes for p in jax.tree.leaves(params))
        kv_bytes = sum(cache[k].nbytes for k in ("kv_k", "kv_v")
                       if k in cache)
        if self.telemetry is not None:
            self.telemetry.observe("weights", read_bytes=w_bytes,
                                   phase="prefill")
            self.telemetry.observe("kv_cache", write_bytes=kv_bytes,
                                   phase="prefill")
            self.telemetry.advance_epoch()

        # pad KV buffers for decode; tier residency between steps is
        # delegated to the serving subsystem's KV manager (stash on the
        # configured shares, restore to device per decode step)
        pad_to = P + sc.max_new_tokens
        for k in ("kv_k", "kv_v"):
            if k in cache:
                pads = [(0, 0)] * cache[k].ndim
                pads[3] = (0, pad_to - P)
                cache[k] = jnp.pad(cache[k], pads)
        kv_home = TieredKVCache(sc.kv_shares, ledger=self.ledger,
                                tenant=self.tenant)
        self.kv_home = kv_home
        kv_home.stash(cache)

        kv_step_bytes = sum(cache[k].nbytes for k in ("kv_k", "kv_v")
                            if k in cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens = [tok]
        t2 = time.perf_counter()
        for i in range(sc.max_new_tokens - 1):
            cache = kv_home.restore(cache)
            logits, cache = self.decode_step(params, cache, tok)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            out_tokens.append(tok)
            kv_home.update(cache)
            if self.telemetry is not None:
                self.telemetry.observe("weights", read_bytes=w_bytes,
                                       phase="decode")
                self.telemetry.observe(
                    "kv_cache", read_bytes=kv_step_bytes,
                    write_bytes=max(kv_step_bytes // max(pad_to, 1), 1),
                    phase="decode")
                self.telemetry.advance_epoch()
        jax.block_until_ready(tok)
        t3 = time.perf_counter()
        return ServeStats(B, t1 - t0, t3 - t2, sc.max_new_tokens)


def max_batch_for_capacity(cfg: ModelConfig, seq: int,
                           capacity_bytes: int) -> int:
    """LIO 3: batch scales with memory capacity (weights + KV + acts)."""
    w = 2 * cfg.param_count()
    per_seq_kv = cfg.n_layers * 2 * seq * cfg.n_kv * cfg.head_dim * 2
    per_seq_act = cfg.d_model * 4 * cfg.n_layers
    avail = capacity_bytes - w
    if avail <= 0:
        return 0
    return max(int(avail // (per_seq_kv + per_seq_act)), 0)
