"""Deterministic synthetic token pipeline — stateless, shardable, resumable.

Fault-tolerance posture (DESIGN.md §4): the pipeline is a pure function
``step -> batch``; there is NO loader state to checkpoint or lose.  Any
worker (or replacement worker after a failure) recomputes its shard of any
step independently, which also makes elastic re-scaling trivial: the
(step, dp_rank, dp_size) triple fully determines the data.

The synthetic stream is a mixture of Zipf-distributed unigrams with
shifting n-gram structure so losses are non-trivial and reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


def _fold(seed: int, *xs: int) -> np.random.Generator:
    s = np.uint64(seed)
    for x in xs:
        s = np.uint64((int(s) * 6364136223846793005 + int(x) + 1) % 2**64)
    return np.random.default_rng(int(s))


def batch_for_step(cfg: DataConfig, step: int,
                   dp_rank: int = 0, dp_size: int = 1
                   ) -> Dict[str, np.ndarray]:
    """The (dp_rank)-th shard of global step `step`."""
    assert cfg.global_batch % dp_size == 0
    per = cfg.global_batch // dp_size
    rng = _fold(cfg.seed, step, dp_rank)
    # Zipf unigrams clipped to vocab, plus a step-dependent periodic motif
    # so the stream has learnable structure.
    z = rng.zipf(cfg.zipf_a, size=(per, cfg.seq_len + 1))
    toks = (z % (cfg.vocab - 2)) + 1
    motif = (np.arange(cfg.seq_len + 1)[None, :] * (1 + step % 7)
             + dp_rank) % 97
    mask = rng.random((per, cfg.seq_len + 1)) < 0.15
    toks = np.where(mask, (motif % (cfg.vocab - 2)) + 1, toks)
    toks = toks.astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def global_batch_for_step(cfg: DataConfig, step: int
                          ) -> Dict[str, np.ndarray]:
    return batch_for_step(cfg, step, 0, 1)


class DataIterator:
    """Step-indexed iterator with O(1) resume (just set .step)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 dp_rank: int = 0, dp_size: int = 1):
        self.cfg = cfg
        self.step = start_step
        self.dp_rank = dp_rank
        self.dp_size = dp_size

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = batch_for_step(self.cfg, self.step, self.dp_rank, self.dp_size)
        self.step += 1
        return b

    def state(self) -> Dict[str, int]:
        return {"step": self.step}

    def restore(self, state: Dict[str, int]) -> None:
        self.step = int(state["step"])
