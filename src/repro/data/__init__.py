from .pipeline import DataConfig, DataIterator, batch_for_step, \
    global_batch_for_step
