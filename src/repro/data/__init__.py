from .pipeline import (batch_for_step, DataConfig, DataIterator,
                       global_batch_for_step)

__all__ = [
    "batch_for_step", "DataConfig", "DataIterator",
    "global_batch_for_step",
]
