"""ServingEngine: continuous batching over the paged, tiered KV pool.

The decode path is rebuilt around the block table instead of the
monolithic cache ``lm.decode_step`` uses: each iteration the running
requests' blocks are gathered from their tiers (async device_put, the
TieredArray discipline), the new token's K/V is scattered at each
sequence's own length, and attention runs through the Pallas
``kernels.decode_attention`` kernel — whose per-sequence ``kv_len``
masking is exactly what ragged continuous batches need.  Per-sequence
positions feed RoPE/learned embeddings, so sequences of different
lengths decode in one batch (the thing the one-shot FlexGenEngine
cannot do).

Supported configs: attention-only patterns (optionally MoE) with
rope/learned/none positions and bf16 KV — the serving family of the
paper's Sec. IV-B study.  Hybrid SSM/RWKV decode stays on the one-shot
engine.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..configs.base import ModelConfig
from ..core.migration import MigrationExecutor
from ..core.tiers import GiB, MemoryTier, tpu_v5e_tiers
from ..kernels import ops
from ..launch import steps as steps_mod
from ..models import modules as M
from . import config as config_mod
from ..telemetry import (AccessSampler, AccessTrace, AdaptiveReplanner,
                         PhaseDetector, ReplanConfig, SamplerConfig)
from .kv_pool import FAST_KIND, PagedKVPool, spec_from_config
from .metrics import ServingMetrics
from .scheduler import (ContinuousBatchingScheduler, plan_admission, Request,
                        RequestState, SchedulerConfig)
from .tiering import KVBlockTierer


def check_paged_support(cfg: ModelConfig) -> None:
    """Raise if the config can't run on the paged decode path."""
    for spec in cfg.pattern:
        if spec.kind != "attn" or spec.cross_attn:
            raise ValueError(
                f"{cfg.name}: paged serving supports attention-only "
                f"patterns (got {spec.kind}"
                f"{'+cross' if spec.cross_attn else ''}); use the "
                f"one-shot FlexGenEngine for hybrid architectures")
    if cfg.encoder_layers:
        raise ValueError(f"{cfg.name}: encoder-decoder serving is not "
                         "paged; use FlexGenEngine")
    if cfg.kv_cache_dtype != "bf16":
        raise ValueError(f"{cfg.name}: paged pool stores bf16 KV "
                         f"(got {cfg.kv_cache_dtype})")
    if cfg.pos_emb not in ("rope", "learned", "none"):
        raise ValueError(f"{cfg.name}: unsupported pos_emb "
                         f"{cfg.pos_emb!r} for paged decode")


# ---------------------------------------------------------------------- #
# Paged decode step (jitted once per engine; B and S_pad are static).     #
# ---------------------------------------------------------------------- #
def _paged_unit_fwd(cfg: ModelConfig, up, x, kv_k, kv_v, lengths,
                    block_k: int):
    """One repeating unit over the gathered block table.

    x: (B, 1, D); kv_k/kv_v: (n_attn, B, S_pad, KV, hd); lengths: (B,).
    Returns (x, new_k, new_v) with new_k/new_v (n_attn, B, KV, hd).
    """
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    barange = jnp.arange(B)
    new_ks, new_vs = [], []
    i_attn = 0
    for li, spec in enumerate(cfg.pattern):
        lp = up["layers"][li]
        h = M.apply_norm(cfg.norm, lp["norm1"], x)
        ap = lp["attn"]
        q = h @ ap["wq"]
        k = h @ ap["wk"]
        v = h @ ap["wv"]
        if "bq" in ap:
            q = q + ap["bq"]
        if "bk" in ap:
            k = k + ap["bk"]
            v = v + ap["bv"]
        q = q.reshape(B, 1, H, hd)
        k = k.reshape(B, 1, KV, hd)
        v = v.reshape(B, 1, KV, hd)
        if cfg.pos_emb == "rope":
            pos = lengths[:, None]                     # per-seq positions
            q = M.apply_rope(q, pos, cfg.rope_theta, cfg.rotary_pct)
            k = M.apply_rope(k, pos, cfg.rope_theta, cfg.rotary_pct)
        ck, cv = kv_k[i_attn], kv_v[i_attn]            # (B, S_pad, KV, hd)
        k_tok = k[:, 0].astype(ck.dtype)
        v_tok = v[:, 0].astype(cv.dtype)
        ck = ck.at[barange, lengths].set(k_tok)
        cv = cv.at[barange, lengths].set(v_tok)
        att = ops.decode_attention(q[:, 0], ck, cv, lengths + 1,
                                   block_k=block_k)    # (B, H, hd)
        x = x + (att.reshape(B, 1, H * hd) @ ap["wo"])

        h = M.apply_norm(cfg.norm, lp["norm2"], x)
        if spec.moe:
            out, _ = M.moe_fwd(lp["moe"], h, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor,
                               n_groups=cfg.moe_groups, act=cfg.act)
        else:
            out = M.mlp_fwd(lp["mlp"], h, cfg.act)
        x = x + out
        new_ks.append(k_tok)
        new_vs.append(v_tok)
        i_attn += 1
    return x, jnp.stack(new_ks), jnp.stack(new_vs)


def _paged_decode(cfg: ModelConfig, block_k: int, params, tokens,
                  kv_k, kv_v, lengths):
    """tokens (B, 1) int32; kv_k/kv_v (U, n_attn, B, S_pad, KV, hd);
    lengths (B,) — tokens already cached per sequence.

    Returns (logits (B, V), new_k, new_v (U, n_attn, B, KV, hd))."""
    x = params["embed"][tokens[:, 0]].astype(jnp.bfloat16)[:, None]
    if cfg.pos_emb == "learned":
        x = x + params["pos_emb"][lengths].astype(x.dtype)[:, None]

    def body(carry, xs):
        up, kk, vv = xs
        h, nk, nv = _paged_unit_fwd(cfg, up, carry, kk, vv, lengths,
                                    block_k)
        return h, (nk, nv)

    x, (new_k, new_v) = lax.scan(body, x, (params["units"], kv_k, kv_v))
    x = M.apply_norm(cfg.norm, params["final_norm"], x)
    W = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = (x[:, 0] @ W.T).astype(jnp.float32)
    return logits, new_k, new_v


def _fused_unit_fwd(cfg: ModelConfig, up, x, k_pool, v_pool, block_tbl,
                    lengths, block_tokens: int):
    """One repeating unit on the fused tiered-gather path.

    x: (B, 1, D); k_pool/v_pool: (n_attn, num_blocks, bt, KV, hd) — the
    pool's *resident* layout, not a per-sequence staging copy; block_tbl
    (B, nb) int32 names each sequence's blocks in pool order.  Attention
    reads blocks straight from the pool via the scalar-prefetched table
    (kernels.tiered_gather) and folds the step's K/V in-kernel, so the
    gather+scatter the unfused path pays per iteration never happens.
    MoE layers run the fused expert FFN indexed by routed expert ids;
    the ids are returned (n_moe, B, K) so the ExpertPool can account
    per-expert heat.
    """
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    new_ks, new_vs, routed = [], [], []
    i_attn = 0
    for li, spec in enumerate(cfg.pattern):
        lp = up["layers"][li]
        h = M.apply_norm(cfg.norm, lp["norm1"], x)
        ap = lp["attn"]
        q = h @ ap["wq"]
        k = h @ ap["wk"]
        v = h @ ap["wv"]
        if "bq" in ap:
            q = q + ap["bq"]
        if "bk" in ap:
            k = k + ap["bk"]
            v = v + ap["bv"]
        q = q.reshape(B, 1, H, hd)
        k = k.reshape(B, 1, KV, hd)
        v = v.reshape(B, 1, KV, hd)
        if cfg.pos_emb == "rope":
            pos = lengths[:, None]
            q = M.apply_rope(q, pos, cfg.rope_theta, cfg.rotary_pct)
            k = M.apply_rope(k, pos, cfg.rope_theta, cfg.rotary_pct)
        k_tok = k[:, 0].astype(k_pool.dtype)
        v_tok = v[:, 0].astype(v_pool.dtype)
        att = ops.paged_decode_attention(
            q[:, 0], k_pool[i_attn], v_pool[i_attn], block_tbl,
            lengths, k_tok, v_tok, block_tokens=block_tokens)
        x = x + (att.reshape(B, 1, H * hd) @ ap["wo"])

        h = M.apply_norm(cfg.norm, lp["norm2"], x)
        if spec.moe:
            mp = lp["moe"]
            # token-choice top-k, weights renormalized over the chosen
            # experts — the moe_fwd routing, sans capacity/drop (decode
            # batches are far under capacity at serving scale)
            logits = h[:, 0].astype(jnp.float32) @ mp["router"]
            topw, topi = lax.top_k(jax.nn.softmax(logits, axis=-1),
                                   cfg.top_k)
            topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
            topi = topi.astype(jnp.int32)
            out = ops.fused_expert_ffn(h[:, 0], mp["w_gate"],
                                       mp["w_up"], mp["w_down"],
                                       topi, topw)[:, None]
            routed.append(topi)
        else:
            out = M.mlp_fwd(lp["mlp"], h, cfg.act)
        x = x + out
        new_ks.append(k_tok)
        new_vs.append(v_tok)
        i_attn += 1
    ids = (jnp.stack(routed) if routed
           else jnp.zeros((0, B, max(cfg.top_k, 1)), jnp.int32))
    return x, jnp.stack(new_ks), jnp.stack(new_vs), ids


def _fused_paged_decode(cfg: ModelConfig, block_tokens: int, params,
                        tokens, k_store, v_store, block_tbl, lengths):
    """tokens (B, 1) int32; k_store/v_store (U, n_attn, num_blocks, bt,
    KV, hd) — the pooled layout itself; block_tbl (B, nb) int32;
    lengths (B,).

    Returns (logits (B, V), new_k, new_v (U, n_attn, B, KV, hd),
    routed expert ids (U, n_moe, B, K))."""
    x = params["embed"][tokens[:, 0]].astype(jnp.bfloat16)[:, None]
    if cfg.pos_emb == "learned":
        x = x + params["pos_emb"][lengths].astype(x.dtype)[:, None]

    def body(carry, xs):
        up, kp, vp = xs
        h, nk, nv, ids = _fused_unit_fwd(cfg, up, carry, kp, vp,
                                         block_tbl, lengths,
                                         block_tokens)
        return h, (nk, nv, ids)

    x, (new_k, new_v, routed) = lax.scan(
        body, x, (params["units"], k_store, v_store))
    x = M.apply_norm(cfg.norm, params["final_norm"], x)
    W = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = (x[:, 0] @ W.T).astype(jnp.float32)
    return logits, new_k, new_v, routed


# ---------------------------------------------------------------------- #
# Engine                                                                 #
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class ServingConfig:
    block_tokens: int = 16
    max_batch: int = 4
    max_context: int = 128            # prompt + generated cap per request
    policy: str = "tiering08"         # static | autonuma | tiering08 | tpp
    num_blocks: Optional[int] = None  # default: max_batch * blocks/seq
    fast_block_budget: Optional[int] = None   # default: half the pool
    slow_kind: str = "pinned_host"
    max_prefill_per_iter: int = 2
    migrate_every: int = 1
    # optional cost-model sizing: overrides num_blocks/fast budget/batch
    device_budget_bytes: Optional[int] = None
    host_budget_bytes: Optional[int] = None
    # telemetry + adaptive object-level re-interleaving (repro.telemetry):
    # sample_rate 1.0 = full instrumentation (smoke-scale traffic);
    # lower it toward PEBS-like rates for production-sized pools.
    adaptive: bool = False
    replan_every: int = 8   # iterations between replans (<= 0 disables)
    sample_rate: float = 1.0
    # predictive control plane (requires adaptive): plans are keyed by
    # the PhaseDetector's recurrence *signatures*, and when the
    # detector predicts a different phase next epoch the proven plan
    # cached for it is pre-staged (promotion-dominant deltas only) so
    # a recurring burst's first iteration runs on its placement
    predictive: bool = False
    # named repro.topology testbed: the scheduler budgets the shared
    # links KV gathers cross (contention-aware admission), and with
    # --adaptive the replanner prices the pool's memory kinds over that
    # machine's hop topology (path latency, bottleneck bandwidth,
    # shared-link move serialization)
    topology: Optional[str] = None
    # tenant namespace in the residency ledger (multi-tenant pools:
    # several engines/trainers sharing one ledger must use distinct
    # tenant names so the arbiter can split the fast tier among them)
    tenant: str = "serving"
    # observability plane (repro.obs): ring bound on the control-plane
    # trace, and optional p95 SLO thresholds (seconds) for TTFT and
    # inter-token decode latency — violations are counted live by the
    # rolling-window SLOMonitor and surfaced in the report
    trace_max_events: int = 65536
    slo_p95_ttft_s: Optional[float] = None
    slo_p95_decode_s: Optional[float] = None
    slo_p99_decode_s: Optional[float] = None
    # extreme-tail decode SLO (p99.9) and the rolling SLO window size;
    # p99.9 targets use a quantile-aware warmup (>= 1/(1-q) samples)
    # so violation_rate() is never judged off a handful of samples
    slo_p999_decode_s: Optional[float] = None
    slo_window: int = 512
    # fused tiered-gather decode: the pool keeps the pooled (stacked)
    # KV layout and attention reads blocks straight from it through a
    # scalar-prefetched block-index table (kernels.tiered_gather),
    # folding the new token in-kernel — the per-iteration gather_seq
    # staging copy and cache scatter disappear.  MoE layers run the
    # fused expert FFN indexed by routed ids (requires silu experts).
    fused_gather: bool = False
    # MoE expert tier residency (serving.expert_pool): experts become
    # tiered objects with routing-driven heat.  "lru" promotes by
    # recency (the expert-cache baseline); "predictive" additionally
    # prefetches the predicted next phase's hot experts.  Uses its own
    # residency namespace so KV arbitration grants are not diluted.
    expert_policy: Optional[str] = None
    expert_fast_fraction: float = 0.25   # share of experts fast-resident
    # interference-class QoS plane (requires topology + a decode SLO):
    # this tenant's gather flows are published tagged with their
    # interference class into a BlameLedger (tail excursions get joined
    # to their bottleneck link + noisy neighbor), and admission +
    # preemption switch from the flat link_efficiency_floor to a
    # ViolationPredictor pricing each candidate against every
    # registered tenant's predicted p99 (audited as ``qos.violation``)
    qos: bool = False
    # interference class this engine's KV gathers present (read for
    # decode-dominant serving; a prefill-heavy tenant may be write)
    qos_class: str = "read"
    # self-calibrating cost model (requires adaptive): fit the pool's
    # slow-tier bandwidth from a real transfer probe at startup and
    # keep correcting the planning tiers online from audit residuals,
    # so replan verdicts and migration pricing run on measured numbers
    calibrate: bool = False
    # ------------------------------------------------------------------
    # nested sections (serving.config): the grouped view of the flat
    # fields above.  Pass a section to configure by concern; pass the
    # flat kwargs and __post_init__ populates the sections — both
    # surfaces stay coherent either way.  ``cluster`` is new with the
    # multi-host plane and has no flat mirror.
    tiering: Optional["config_mod.TieringOptions"] = None
    qos_options: Optional["config_mod.QoSOptions"] = None
    experts: Optional["config_mod.ExpertOptions"] = None
    cluster: Optional["config_mod.ClusterOptions"] = None

    def __post_init__(self):
        config_mod.sync_sections(self)

    @classmethod
    def from_args(cls, args) -> "ServingConfig":
        """Build from a serve-CLI-shaped namespace, running every
        cross-field validation (``config.validate_args``) first.
        Raises :class:`~repro.serving.config.ConfigError` on any
        violated constraint — the CLI maps that to ``parser.error``.
        """
        config_mod.validate_args(args)
        get = lambda name, default=None: getattr(args, name, default)  # noqa: E731
        replicas = int(get("replicas", 1) or 1)
        cluster = None
        if replicas > 1 or get("router") is not None:
            cluster = config_mod.ClusterOptions(
                replicas=replicas,
                router=get("router") or "headroom-distance",
                shard_model=bool(get("shard_model", True)))
        return cls(
            block_tokens=get("block_tokens", 16),
            max_batch=get("batch", 4),
            max_context=(get("prompt_len", 32) + get("new_tokens", 16)
                         + get("block_tokens", 16)),
            policy=get("policy", "tiering08"),
            num_blocks=get("num_blocks"),
            fast_block_budget=get("fast_blocks"),
            adaptive=bool(get("adaptive")),
            replan_every=get("replan_every", 8),
            sample_rate=get("sample_rate", 1.0),
            predictive=bool(get("predictive")),
            calibrate=bool(get("calibrate")),
            topology=get("topology"),
            tenant=get("tenant") or "serving",
            slo_p95_ttft_s=get("slo_p95_ttft"),
            slo_p95_decode_s=get("slo_p95_decode"),
            slo_p99_decode_s=get("slo_p99_decode"),
            slo_p999_decode_s=get("slo_p999_decode"),
            slo_window=get("slo_window", 512),
            qos=bool(get("qos")),
            fused_gather=bool(get("fused_gather")),
            expert_policy=get("expert_policy"),
            expert_fast_fraction=get("expert_fast_frac", 0.25),
            cluster=cluster)


@dataclasses.dataclass
class ServingReport:
    summary: Dict[str, float]
    per_request: List[Tuple[int, Dict[str, float]]]
    tiering: Dict[str, int]
    policy: str
    telemetry: Dict[str, float] = dataclasses.field(default_factory=dict)
    slo: Dict[str, object] = dataclasses.field(default_factory=dict)


def kind_tiers(pool: PagedKVPool,
               fast_base: Optional[MemoryTier] = None,
               slow_base: Optional[MemoryTier] = None
               ) -> Dict[str, MemoryTier]:
    """MemoryTier descriptors for the pool's memory kinds, with
    capacities set from the pool's block budgets — what the adaptive
    replanner plans against.  ``fast_base``/``slow_base`` override the
    TPU defaults (e.g. a topology testbed's device-local tiers, whose
    hop latency the graph supplies)."""
    base = tpu_v5e_tiers()
    bn = pool.block_nbytes()
    if fast_base is None:
        fast_base = base["HBM"]
    if slow_base is None:
        slow_base = (base["HOST"] if pool.slow_kind == "pinned_host"
                     else base["HOST_UNPINNED"])
    fast = dataclasses.replace(
        fast_base, name=FAST_KIND,
        capacity_GiB=max(pool.fast_block_budget, 1) * bn / GiB)
    slow = dataclasses.replace(
        slow_base, name=pool.slow_kind, kind="host",
        capacity_GiB=max(pool.num_blocks, 1) * bn / GiB)
    return {FAST_KIND: fast, pool.slow_kind: slow}


class ServingEngine:
    """Continuous-batching serving over a tier-resident paged KV pool."""

    def __init__(self, cfg: ModelConfig, params,
                 serving: Optional[ServingConfig] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 ledger=None, pool_sharding=None):
        check_paged_support(cfg)
        self.cfg = cfg
        self.sv = sv = serving or ServingConfig()
        self.clock = clock
        self.params = params
        bt = sv.block_tokens
        self.max_seq_blocks = max(1, math.ceil(sv.max_context / bt))
        if sv.device_budget_bytes is not None:
            plan = plan_admission(
                cfg, bt, sv.max_context, sv.device_budget_bytes,
                sv.host_budget_bytes or 0, max_batch_cap=sv.max_batch)
            num_blocks, fast_budget = plan.total_blocks, plan.fast_blocks
            max_batch = plan.max_batch
        else:
            num_blocks = sv.num_blocks or sv.max_batch * self.max_seq_blocks
            fast_budget = (sv.fast_block_budget
                           if sv.fast_block_budget is not None
                           else max(1, num_blocks // 2))
            max_batch = sv.max_batch
        self.max_batch = max_batch
        if sv.fused_gather and any(
                s.moe for s in cfg.pattern) and cfg.act != "silu":
            raise ValueError(f"{cfg.name}: fused MoE decode needs silu "
                             "(gated) experts")
        spec = spec_from_config(cfg, bt)
        static = sv.policy in ("static", "none", "no_balance")
        # all tier occupancy flows through the (possibly shared)
        # residency ledger under this engine's tenant namespace; the
        # fused decode path needs the pooled layout it indexes into
        self.pool = PagedKVPool(
            num_blocks, bt, spec=spec, fast_block_budget=fast_budget,
            slow_kind=sv.slow_kind, default_kind=sv.slow_kind,
            ledger=ledger, tenant=sv.tenant, pooled=sv.fused_gather,
            sharding_fn=pool_sharding)
        self.ledger = self.pool.ledger
        self._static_split = static
        self.tierer = KVBlockTierer(self.pool, sv.policy)
        topo = None
        tb = None
        if sv.topology:
            from ..topology import build_topology
            tb = build_topology(sv.topology)
            topo = tb.graph
            # the pool's memory kinds ride the testbed's fast node
            # and its capacity-expander (CXL-class) node
            topo.alias_tier(tb.fast, FAST_KIND)
            topo.alias_tier(tb.capacity_tier, self.pool.slow_kind)
        self.topo = topo
        # observability plane: one tracer + registry + SLO monitor per
        # engine, all on the engine's virtual timebase (_now), created
        # before the components they instrument
        self._t0 = 0.0
        self._virtual_skew = 0.0
        self._step = 0
        from ..obs import (LagRatioMonitor, MetricsRegistry,
                           PredictionLedger, SLOMonitor, SLOTarget,
                           TraceRecorder)
        self.tracer = TraceRecorder(clock=self._now,
                                    max_events=sv.trace_max_events)
        self.registry = MetricsRegistry()
        # prediction audit plane: every control-plane forecast (step
        # costs, demand grants, phase predictions, move times) joins
        # its realized outcome here — always on, near-zero cost
        self.audit = PredictionLedger(registry=self.registry,
                                      tracer=self.tracer)
        slo_targets = []
        if sv.slo_p95_ttft_s is not None:
            slo_targets.append(SLOTarget("ttft", 0.95, sv.slo_p95_ttft_s))
        if sv.slo_p95_decode_s is not None:
            slo_targets.append(
                SLOTarget("decode_latency", 0.95, sv.slo_p95_decode_s))
        if sv.slo_p99_decode_s is not None:
            slo_targets.append(
                SLOTarget("decode_latency", 0.99, sv.slo_p99_decode_s))
        if sv.slo_p999_decode_s is not None:
            slo_targets.append(
                SLOTarget("decode_latency", 0.999, sv.slo_p999_decode_s))
        self.slo = SLOMonitor(slo_targets, clock=self._now,
                              registry=self.registry, tracer=self.tracer,
                              window=sv.slo_window)
        self.lag = LagRatioMonitor()
        self._lag_tokens = 0          # decode tokens at last epoch close
        self._lag_time = 0.0          # _now() at last epoch close
        # interference-class QoS plane: blame attribution + predictive
        # admission, both priced on the topology's class-aware
        # contention model
        self.blame = None
        self.predictor = None
        self._qos_last_key: Optional[int] = None
        if sv.qos:
            if topo is None:
                raise ValueError("qos requires a topology (the blame "
                                 "plane attributes violations to links)")
            decode_slo = sv.slo_p99_decode_s or sv.slo_p95_decode_s
            if decode_slo is None:
                raise ValueError("qos requires a decode SLO "
                                 "(slo_p99_decode_s or slo_p95_decode_s)")
            from ..obs import BlameLedger, ViolationPredictor
            self.blame = BlameLedger(topo, registry=self.registry,
                                     tracer=self.tracer, clock=self._now)
            self.predictor = ViolationPredictor(topo, blame=self.blame,
                                                audit=self.audit)
            self.predictor.set_target(sv.tenant, decode_slo)
            # every decode-latency excursion gets joined to its
            # bottleneck link + antagonist at firing time
            self.slo.add_violation_hook(
                lambda t, v, now: self.blame.on_violation(
                    sv.tenant, t.key, v, t.threshold_s, now=now)
                if t.metric == "decode_latency" else None)
        self.sched = ContinuousBatchingScheduler(
            self.pool, SchedulerConfig(
                max_batch=max_batch,
                max_prefill_per_iter=sv.max_prefill_per_iter,
                flow_class=sv.qos_class),
            topology=topo, tracer=self.tracer,
            predictor=self.predictor)
        self.metrics = ServingMetrics(registry=self.registry,
                                      slo=self.slo)
        # telemetry: the pool emits access events through a sampling
        # front-end; phase detection + (optionally) adaptive replanning
        # consume the shared trace, which also registers as this
        # tenant's namespace in the ledger (the arbiter reads it there)
        self.trace = AccessTrace()
        self.sampler = AccessSampler(
            self.trace, SamplerConfig(sample_rate=sv.sample_rate))
        self.pool.attach_telemetry(self.sampler)
        self.ledger.attach_trace(sv.tenant, self.trace)
        self.phases = PhaseDetector(self.trace)
        self.replanner: Optional[AdaptiveReplanner] = None
        if sv.predictive and not sv.adaptive:
            raise ValueError("predictive serving requires adaptive=True "
                             "(prediction pre-stages the replanner's "
                             "phase-cached plans)")
        if sv.calibrate and not sv.adaptive:
            raise ValueError("calibrate requires adaptive=True (the "
                             "corrections feed the replanner's cost "
                             "model)")
        self.calibrator = None
        if sv.adaptive:
            if tb is not None:
                tiers = kind_tiers(self.pool,
                                   fast_base=tb.tiers[tb.fast],
                                   slow_base=tb.tiers[tb.capacity_tier])
            else:
                tiers = kind_tiers(self.pool)
            if sv.calibrate:
                from ..obs import (CostModelCalibrator,
                                   measure_transfer_probes)
                self.calibrator = CostModelCalibrator(tiers, graph=topo)
                # startup fit: one real device->host transfer probe for
                # the pool's slow kind (the tier names ARE jax memory
                # kinds, so probes map directly); the fast (device)
                # tier keeps the builder numbers
                self.calibrator.fit_probes(measure_transfer_probes(
                    kinds=(self.pool.slow_kind,), n_mb=16, iters=2))
            executor = MigrationExecutor(tiers,
                                         move_fn=self._move_seq_blocks,
                                         topology=topo)
            self.replanner = AdaptiveReplanner(
                self.trace, tiers, FAST_KIND,
                cfg=ReplanConfig(replan_every=max(sv.replan_every, 1),
                                 window_epochs=max(sv.replan_every, 1)),
                executor=executor,
                default_tier=self.pool.slow_kind,
                topology=topo,
                ledger=self.ledger, tenant=sv.tenant,
                tracer=self.tracer, audit=self.audit,
                calibrator=self.calibrator)
            self.replanner.executor.tracer = self.tracer
            self.replanner.executor.audit = self.audit
            self.replanner.executor.calibrator = self.calibrator
            self.replanner.executor.recalibrate()
        # predictive engines run the full control plane in-engine: a
        # predictive TierBudgetArbiter rebalances this tenant's
        # fast-tier grant each replan epoch (capacity = the configured
        # fast-block budget, so single-tenant grants can never exceed
        # what the pool was sized for), and replan deltas defer to a
        # MoveScheduler round so the trace shows the scheduled batch
        self.arbiter = None
        self.movesched = None
        if sv.predictive:
            from ..pool import MoveScheduler, TierBudgetArbiter
            self.arbiter = TierBudgetArbiter(
                self.ledger, FAST_KIND,
                capacity_bytes=fast_budget * self.pool.block_nbytes(),
                objective="fair_share", predictive=True,
                tracer=self.tracer, audit=self.audit)
            self.movesched = MoveScheduler(
                self.replanner.executor, self.ledger, tracer=self.tracer)
            self.movesched.audit = self.audit
            self.movesched.calibrator = self.calibrator
            self.replanner.move_scheduler = self.movesched
        # MoE expert tier residency: every (layer, expert) weight block
        # becomes a tiered object with routing-driven heat, sharing the
        # cross-tenant move scheduler when one exists but keeping its
        # own residency namespace (so the KV arbiter's fair-share grant
        # is not split against expert bytes)
        self.expert_pool = None
        self._moe_per_unit = sum(1 for s in cfg.pattern if s.moe)
        if sv.expert_policy:
            from .expert_pool import (expert_nbytes_from_config,
                                      ExpertPool, moe_layers_from_config)
            n_moe = moe_layers_from_config(cfg)
            if n_moe == 0:
                raise ValueError(f"{cfg.name}: expert_policy set but "
                                 "the model has no MoE layers")
            total = n_moe * cfg.n_experts
            budget = max(1, int(round(total * sv.expert_fast_fraction)))
            self.expert_pool = ExpertPool(
                n_moe, cfg.n_experts, expert_nbytes_from_config(cfg),
                fast_expert_budget=budget, policy=sv.expert_policy,
                tenant=f"{sv.tenant}.experts", slow_kind=sv.slow_kind,
                movesched=self.movesched, tracer=self.tracer)
        self._prefill = jax.jit(steps_mod.make_prefill_step(cfg))
        self._decode = jax.jit(functools.partial(_paged_decode, cfg, bt))
        self._decode_fused = (
            jax.jit(functools.partial(_fused_paged_decode, cfg, bt))
            if sv.fused_gather else None)
        self._next_rid = 0

    # ------------------------------------------------------------------ #
    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               arrival_s: float = 0.0, priority: float = 0.0) -> int:
        """Queue one request; returns its request id.  ``priority``
        orders budget preemption (lowest evicted first)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        max_new = min(max_new_tokens,
                      self.sv.max_context - prompt.shape[0])
        if max_new <= 0:
            raise ValueError(
                f"prompt of {prompt.shape[0]} tokens leaves no room "
                f"under max_context={self.sv.max_context}")
        need = self.pool.blocks_for_tokens(prompt.shape[0] + 1)
        margin = self.sched.cfg.admission_margin_blocks
        if need + margin > self.pool.num_blocks:
            raise ValueError(
                f"prompt needs {need} blocks (+{margin} margin) but the "
                f"pool only has {self.pool.num_blocks}")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt, max_new_tokens=max_new,
                      arrival_s=arrival_s, priority=priority)
        self.sched.submit(req)
        self.metrics.on_submit(rid, arrival_s, prompt.shape[0])
        return rid

    def submit_trace(self, prompts: Sequence[np.ndarray],
                     max_new_tokens: int,
                     arrivals: Optional[Sequence[float]] = None
                     ) -> List[int]:
        arrivals = arrivals or [0.0] * len(prompts)
        return [self.submit(p, max_new_tokens, a)
                for p, a in sorted(zip(prompts, arrivals),
                                   key=lambda pa: pa[1])]

    # ------------------------------------------------------------------ #
    def _alloc_kind(self) -> Optional[str]:
        """Per-block allocation kind (passed as a callable to the pool).

        Static policy: a fixed split — fast at the budget's share of the
        pool, interleaved per block, never migrated (the one-shot
        engine's kv_shares, online).  Dynamic policies: first-touch in
        the slow tier; promotion earns fast residency from observed
        heat.
        """
        pool = self.pool
        if self._static_split:
            target = pool.fast_block_budget / max(pool.num_blocks, 1)
            if pool.fast_used() < pool.fast_block_budget and \
                    pool.fast_used() < target * (pool.used_block_count()
                                                 + 1):
                return FAST_KIND
        return None           # pool default (slow kind)

    def _do_prefill(self, req: Request, now: float) -> None:
        toks = req.prefill_tokens()[None]          # (1, L)
        L = toks.shape[1]
        need = self.pool.blocks_for_tokens(L + 1)
        if not self.pool.can_alloc(need):
            for v in self.sched.preempt_for_blocks(need, protect=req):
                self.metrics.on_preempt(v.rid, now)
        if req.state is not RequestState.RUNNING:
            return                     # pool too tight: preempted itself
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(toks)})
        self.pool.write_prefill(req.rid, cache["kv_k"][:, :, 0],
                                cache["kv_v"][:, :, 0], L,
                                kind=self._alloc_kind)
        self.metrics.on_admit(req.rid, now)
        tok = int(np.asarray(jnp.argmax(logits[0])))
        req.out_tokens.append(tok)
        self.metrics.on_token(req.rid, self._now())
        if req.done:
            self.sched.finish(req)
            self.metrics.on_finish(req.rid, self._now(), req.preemptions)

    def _ensure_tail_blocks(self) -> None:
        """Every running request needs a block for its next KV write."""
        for req in list(self.sched.running):
            if req.state is not RequestState.RUNNING:
                continue               # evicted by an earlier iteration
            n = self.pool.seq_len[req.rid]
            if n % self.pool.block_tokens != 0:
                continue
            if n // self.pool.block_tokens < len(
                    self.pool.table[req.rid]):
                continue
            if not self.pool.can_alloc(1):
                for v in self.sched.preempt_for_blocks(1, protect=req):
                    self.metrics.on_preempt(v.rid, self._now())
            if req.state is not RequestState.RUNNING:
                continue               # preempted itself
            self.pool.alloc(req.rid, 1, kind=self._alloc_kind)

    def _fused_decode_batch(self, batch):
        """Fused tiered-gather decode: no per-sequence staging copy —
        the jitted step reads the pooled stores through each sequence's
        block-index table.  Routed expert ids feed per-expert heat."""
        B = self.max_batch
        tbl, _ = self.pool.gather_tables([r.rid for r in batch],
                                         self.max_seq_blocks)
        toks = [req.out_tokens[-1] for req in batch]
        lens = [self.pool.seq_len[req.rid] for req in batch]
        n_pad = B - len(batch)
        if n_pad:                      # fixed batch shape: one compile
            tbl = np.concatenate(
                [tbl, np.zeros((n_pad, tbl.shape[1]), np.int32)])
            toks.extend([0] * n_pad)
            lens.extend([0] * n_pad)
        tokens = jnp.asarray(toks, jnp.int32)[:, None]
        lengths = jnp.asarray(lens, jnp.int32)
        logits, new_k, new_v, routed = self._decode_fused(
            self.params, tokens, self.pool.k_store, self.pool.v_store,
            jnp.asarray(tbl), lengths)
        if self.expert_pool is not None and routed.shape[1]:
            ids = np.asarray(routed)       # (U, n_moe, B, K)
            for u in range(ids.shape[0]):
                for m in range(ids.shape[1]):
                    gl = u * self._moe_per_unit + m
                    for i in range(len(batch)):
                        self.expert_pool.record_routing(
                            gl, ids[u, m, i], self._step)
        return logits, new_k, new_v

    def _decode_iteration(self, now: float) -> None:
        batch = list(self.sched.running)
        if not batch:
            return
        B = self.max_batch
        if self._decode_fused is not None:
            logits, new_k, new_v = self._fused_decode_batch(batch)
        else:
            kv_ks, kv_vs, toks, lens = [], [], [], []
            for req in batch:
                k, v = self.pool.gather_seq(req.rid, self.max_seq_blocks)
                kv_ks.append(k)
                kv_vs.append(v)
                toks.append(req.out_tokens[-1])
                lens.append(self.pool.seq_len[req.rid])
            n_pad = B - len(batch)
            if n_pad:                  # fixed batch shape: one compile
                z = jnp.zeros_like(kv_ks[0])
                kv_ks.extend([z] * n_pad)
                kv_vs.extend([z] * n_pad)
                toks.extend([0] * n_pad)
                lens.extend([0] * n_pad)
            kv_k = jnp.stack(kv_ks, axis=2)  # (U, n_attn, B, S_pad, ...)
            kv_v = jnp.stack(kv_vs, axis=2)
            tokens = jnp.asarray(toks, jnp.int32)[:, None]
            lengths = jnp.asarray(lens, jnp.int32)
            logits, new_k, new_v = self._decode(self.params, tokens,
                                                kv_k, kv_v, lengths)
        next_toks = np.asarray(jnp.argmax(logits, axis=-1))
        new_k = np.asarray(new_k)          # (U, n_attn, B, KV, hd)
        new_v = np.asarray(new_v)
        now_tok = self._now()
        for i, req in enumerate(batch):
            self.pool.append_token(req.rid, jnp.asarray(new_k[:, :, i]),
                                   jnp.asarray(new_v[:, :, i]))
            self.pool.touch_seq(req.rid, self._step)
            req.out_tokens.append(int(next_toks[i]))
            self.metrics.on_token(req.rid, now_tok)
            if req.done:
                self.sched.finish(req)
                self.metrics.on_finish(req.rid, now_tok, req.preemptions)

    # ------------------------------------------------------------------ #
    def _move_seq_blocks(self, obj: str, src: str, dst: str,
                         nbytes: int) -> int:
        """MigrationExecutor move_fn: realize an object-level byte move
        as pool-block migrations.  Returns bytes actually moved (the
        fast-block budget may deny promotions)."""
        if not obj.startswith("seq"):
            return 0
        try:
            sid = int(obj[3:])
        except ValueError:
            return 0
        bn = self.pool.block_nbytes()
        want = int(round(nbytes / max(bn, 1)))
        moved = 0
        for b in self.pool.seq_blocks(sid):
            if moved >= want:
                break
            if b.kind == src and self.pool.migrate(b.bid, dst):
                moved += 1
        return moved * bn

    def _replan_step(self) -> None:
        """One telemetry epoch: close the bucket, track phases, and (in
        adaptive mode) attempt an object-level replan over live
        sequences.  Predictive mode keys the plan cache by recurrence
        signature and pre-stages the proven plan of a predicted
        next-epoch phase during the current one's slack."""
        self.sampler.advance_epoch()
        self.phases.update()
        # live lag monitor: one (phase, tokens, time) sample per epoch
        now = self._now()
        self.lag.observe_epoch(str(self.phases.label),
                               self.metrics.decode_tokens
                               - self._lag_tokens,
                               now - self._lag_time)
        self._lag_tokens = self.metrics.decode_tokens
        self._lag_time = now
        self.tracer.event("phase.update", cat="phase",
                          epoch=self._step, label=str(self.phases.label),
                          shifts=len(self.phases.shifts))
        if self.expert_pool is not None:
            # close the expert heat epoch and run promote/demote (and,
            # under the predictive policy, next-phase prefetch)
            self.expert_pool.step(self._step)
        if self.blame is not None:
            # keep this tenant's class-tagged offered flows current in
            # the shared blame book *before* the SLO check, so a firing
            # violation attributes against fresh loads
            self.blame.publish_flows(self.sv.tenant,
                                     self.sched._running_flows(),
                                     now=now)
            if self.expert_pool is not None:
                # expert-gather traffic rides the same tier link as KV
                # gathers; publish it class-tagged under the expert
                # namespace so blame can split demand reads from
                # optional prefetch bytes
                self.blame.publish_flows(
                    self.expert_pool.tenant,
                    self.expert_pool.gather_flows(self.topo), now=now)
        if self.slo.targets and self._step % 16 == 0:
            self.slo.check()
            if self.predictor is not None:
                self._qos_audit_step()
        if (self.replanner is None or self.sv.replan_every <= 0
                or self._step == 0
                or self._step % self.sv.replan_every != 0):
            return
        if self.arbiter is not None:
            self.arbiter.rebalance(epoch=self._step)
        if self.calibrator is not None:
            # refresh the replanner's planning view from whatever online
            # scale corrections the audit loop accumulated this epoch
            self.replanner.recalibrate()
        bn = self.pool.block_nbytes()
        nbytes = {f"seq{sid}": len(tbl) * bn
                  for sid, tbl in self.pool.table.items() if tbl}
        if not nbytes:
            return
        try:
            if self.sv.predictive and self.phases.signature is not None:
                cur = self.phases.expected_signature(1)
                nxt = self.phases.expected_signature(2)
                if nxt is not None and nxt != cur:
                    d = self.replanner.prefetch_phase(self._step, nbytes,
                                                      nxt)
                    if d is not None:
                        return
                self.replanner.maybe_replan(self._step, nbytes,
                                            force=True, phase=cur)
                return
            # phase-conditioned plan cache: recurring detector labels
            # (prefill-heavy vs decode-heavy mixes) reuse their plan
            self.replanner.maybe_replan(self._step, nbytes, force=True,
                                        phase=self.phases.label)
        finally:
            # deferred applies must land this epoch: flush the move
            # round so the realized residency is adopted before the
            # next iteration reads the ledger
            if self.movesched is not None and self.movesched.has_pending:
                self.movesched.flush(epoch=self._step)

    def _qos_audit_step(self) -> None:
        """One predict/realize audit cycle for the ``qos.violation``
        model: join the previous check's tail forecast with the window
        p99 measured now, refresh the online baseline, and file the
        forecast for the next check from the live flow set."""
        sv = self.sv
        q = 0.99 if sv.slo_p99_decode_s is not None else 0.95
        observed = self.slo.quantile("decode_latency", q)
        if observed is None:
            return
        if self._qos_last_key is not None:
            self.predictor.realize(self._qos_last_key, sv.tenant,
                                   observed)
            self._qos_last_key = None
        self.predictor.observe_p99(sv.tenant, observed)
        pred = self.predictor.file_prediction(
            self._step, sv.tenant,
            extra_flows=self.sched._running_flows(),
            exclude=sv.tenant, epoch=self._step)
        if pred is not None:
            self._qos_last_key = self._step

    def telemetry_summary(self) -> Dict[str, float]:
        out: Dict[str, float] = {
            "trace_events": float(self.trace.total_events),
            "profiling_samples": float(self.sampler.samples),
            "profiling_overhead_s": self.sampler.overhead_s,
            "phase_shifts": float(len(self.phases.shifts)),
            "link_deferrals": float(self.sched.link_deferrals),
            "budget_preemptions": float(self.sched.budget_preemptions),
            "qos_deferrals": float(self.sched.qos_deferrals),
            "slo_preemptions": float(self.sched.slo_preemptions),
            "ledger_migrated_bytes": float(
                self.ledger.counters.migrated_bytes),
        }
        if self.replanner is not None:
            out.update(self.replanner.summary())
        if self.expert_pool is not None:
            out.update(self.expert_pool.summary())
        if self.movesched is not None:
            for k, v in self.movesched.summary().items():
                out[f"movesched.{k}"] = v
        if self.arbiter is not None:
            out["arbiter_rebalances"] = float(len(self.arbiter.decisions))
            out["arbiter_predicted_grants"] = float(
                self.arbiter.predicted_grants)
        lag = self.lag.ratio()
        if lag is not None:
            out["live_burst_entry_ratio"] = float(lag)
        out["trace_recorded_events"] = float(len(self.tracer))
        out["trace_dropped_events"] = float(self.tracer.dropped)
        if self.blame is not None:
            out.update(self.blame.summary())
        out.update(self.audit.summary())
        if self.calibrator is not None:
            out.update(self.calibrator.summary())
        return out

    def audit_report(self) -> Dict[str, object]:
        """Structured prediction-audit artifact (the ``--audit-out``
        payload): per-model residual stats plus, when calibration is
        on, the fitted/online correction state."""
        out: Dict[str, object] = {"audit": self.audit.report()}
        if self.calibrator is not None:
            out["calibration"] = self.calibrator.summary()
        return out

    # ------------------------------------------------------------------ #
    def _now(self) -> float:
        """Trace time: wall clock since run() start plus the virtual
        fast-forward over idle arrival gaps.  Every metrics timestamp
        uses this base so TTFT/latency stay comparable to the synthetic
        ``arrival_s`` values."""
        return self.clock() - self._t0 + self._virtual_skew

    def run(self, max_iterations: int = 10_000) -> ServingReport:
        """Drive the trace to completion; returns the serving report."""
        self._t0 = self.clock()
        self._virtual_skew = 0.0
        while self.sched.active and self._step < max_iterations:
            now = self._now()
            # an arbiter may have shrunk this tenant's fast budget in
            # the shared ledger since the last iteration: enforce it
            # before admitting new work (freed blocks re-admit victims)
            for v in self.sched.preempt_over_budget():
                self.metrics.on_preempt(v.rid, now)
            # predictive QoS: back off while any registered tenant's
            # predicted tail exceeds its target under our live flows
            for v in self.sched.preempt_predicted_violation():
                self.metrics.on_preempt(v.rid, now)
            admitted = self.sched.admit(now_s=now)
            if not admitted and not self.sched.running:
                # idle: fast-forward the arrival clock (synthetic traces)
                pending = [r.arrival_s for r in self.sched.waiting]
                skip = max(min(pending) - now, 0.0) if pending else 0.0
                if skip <= 0.0:
                    raise RuntimeError(
                        "scheduler stalled: waiting requests cannot be "
                        "admitted into an empty pool (pool too small)")
                self._virtual_skew += skip
                continue
            for req in admitted:
                self._do_prefill(req, now)
            self._ensure_tail_blocks()
            self._decode_iteration(now)
            if self.sv.migrate_every and \
                    self._step % self.sv.migrate_every == 0:
                self.tierer.step(
                    [r.rid for r in self.sched.running], self._step)
            self._replan_step()
            self.metrics.on_iteration(
                self._step, self.pool.used_block_count(),
                self.pool.fast_used(), len(self.sched.running),
                len(self.sched.waiting))
            self._step += 1
        tstats = self.tierer.stats.as_dict()
        # adaptive replan moves also migrate pool blocks; surface them in
        # the tiering counters the report exposes
        tstats["migrated_bytes"] = self.pool.counters.migrated_bytes
        if self.slo.targets:
            self.slo.check()           # final window evaluation
        summary = self.metrics.summary(tstats)
        telemetry = self.telemetry_summary()
        # publish the run's aggregates into the central registry so a
        # --metrics-out export carries engine + ledger + control-plane
        # state alongside the streaming histograms
        self.registry.set_gauges(summary, prefix="serving.summary")
        self.registry.set_gauges(telemetry, prefix="serving.telemetry")
        self.ledger.publish(self.registry)
        self.registry.set_gauges(self.audit.summary())
        if self.calibrator is not None:
            self.calibrator.publish(self.registry)
        slo = self.slo.summary()
        if self.blame is not None:
            slo["blame"] = self.blame.blame_report()
        return ServingReport(
            summary=summary,
            per_request=self.metrics.per_request_rows(),
            tiering=tstats, policy=self.tierer.policy_name,
            telemetry=telemetry, slo=slo)
