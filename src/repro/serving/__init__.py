"""repro.serving: tier-aware continuous-batching serving subsystem.

The paper's LLM use case (Sec. IV-B) made online: a paged KV block
pool whose blocks live on memory tiers (kv_pool), §VI tiering runtimes
promoting hot blocks under a capacity budget (tiering), a
continuous-batching scheduler with admission control and
preemption-by-recompute (scheduler), a paged decode engine over the
Pallas decode-attention kernel (engine), and request/pool/migration
metrics (metrics), and MoE expert weights as tiered objects with
routing-driven heat and predictive prefetch (expert_pool).
"""
from .config import (ClusterOptions, ConfigError, ExpertOptions,
                     QoSOptions, ROUTER_POLICIES, TieringOptions)
from .engine import (check_paged_support, kind_tiers, ServingConfig,
                     ServingEngine, ServingReport)
from .expert_pool import ExpertCounters, ExpertPool
from .kv_pool import (FAST_KIND, KVBlock, KVBlockSpec, PagedKVPool,
                      PoolExhausted, spec_from_config, TieredKVCache)
from .metrics import percentile, PoolSample, RequestMetrics, ServingMetrics
from .scheduler import (AdmissionPlan, ContinuousBatchingScheduler,
                        plan_admission, Request, RequestState,
                        SchedulerConfig)
from .tiering import (KVBlockTierer, make_tiering_policy, POLICIES,
                      TieringStats)

__all__ = [
    "FAST_KIND", "KVBlock", "KVBlockSpec", "PagedKVPool", "PoolExhausted",
    "TieredKVCache", "spec_from_config",
    "KVBlockTierer", "POLICIES", "TieringStats", "make_tiering_policy",
    "AdmissionPlan", "ContinuousBatchingScheduler", "Request",
    "RequestState", "SchedulerConfig", "plan_admission",
    "PoolSample", "RequestMetrics", "ServingMetrics", "percentile",
    "ServingConfig", "ServingEngine", "ServingReport",
    "check_paged_support", "kind_tiers",
    "ExpertCounters", "ExpertPool",
    "ClusterOptions", "ConfigError", "ExpertOptions", "QoSOptions",
    "ROUTER_POLICIES", "TieringOptions",
]
