"""Online KV-block tiering: core.migration policies driving the pool.

The paper's §VI runtimes (AutoNUMA / Tiering-0.8 / TPP) decide page
promotion from observed hint faults; "Dissecting CXL Memory Performance
at Scale" makes the same point for serving — placement must follow
observed access heat.  Here the *policy classes from core.migration are
reused verbatim*: each scheduler iteration is one epoch, a decode read
of a slow-tier block is a hint fault, and the chosen policy's
``promote_set`` picks which touched slow blocks to promote.  Capacity
pressure on the fast tier is resolved the way MigrationSim does —
demote the coldest fast blocks first — except the demotions act on the
*real* pool (jax.device_put between memory kinds), not a simulation.

``policy="static"`` (NoBalance) is the baseline: whatever split the
allocator chose stays put, exactly the statically-split KV shares the
one-shot engine uses.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from ..core import migration as mig
from .kv_pool import FAST_KIND, KVBlock, PagedKVPool

POLICIES = ("static", "autonuma", "tiering08", "tpp")


def make_tiering_policy(name: str) -> mig.MigrationPolicy:
    name = name.lower()
    if name in ("static", "none", "no_balance"):
        return mig.NoBalance()
    if name == "autonuma":
        return mig.AutoNUMA()
    if name == "tiering08":
        return mig.Tiering08()
    if name == "tpp":
        return mig.TPP()
    raise ValueError(f"unknown tiering policy {name!r}; "
                     f"choose from {POLICIES}")


@dataclasses.dataclass
class TieringStats:
    epochs: int = 0
    hint_faults: int = 0
    promoted: int = 0
    demoted: int = 0
    migrated_bytes: int = 0
    denied_promotions: int = 0   # fast tier full, no cold victim

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class KVBlockTierer:
    """Promotion/demotion loop over a PagedKVPool.

    One ``step`` per scheduler iteration: the pool's heat counters are
    mirrored into core.migration ``Block`` shadows (the policies operate
    on that dataclass), the policy nominates promotions among touched
    slow-tier blocks, and capacity pressure demotes the coldest
    fast-tier blocks of *non-running* sequences first.
    """

    def __init__(self, pool: PagedKVPool, policy: str = "tiering08",
                 slow_kind: Optional[str] = None):
        self.pool = pool
        self.policy = make_tiering_policy(policy)
        self.policy_name = self.policy.name
        self.slow_kind = slow_kind or pool.slow_kind
        self.stats = TieringStats()
        self._mig_stats = mig.MigrationStats()
        # shadow core.migration blocks, keyed by pool block id
        self._shadow: Dict[int, mig.Block] = {}

    # ------------------------------------------------------------------ #
    def _shadow_of(self, b: KVBlock) -> mig.Block:
        s = self._shadow.get(b.bid)
        if s is None or s.obj != f"seq{b.seq_id}":
            s = mig.Block(obj=f"seq{b.seq_id}", idx=b.bid,
                          nbytes=self.pool.block_nbytes(), tier=b.kind)
            self._shadow[b.bid] = s
        s.tier = b.kind
        s.last_touch_epoch = b.last_touch_step
        s.touch_count = b.touch_count
        return s

    def _demote_for(self, need_blocks: int, epoch: int,
                    protect: Sequence[int]) -> int:
        """Demote the coldest fast blocks until ``need_blocks`` fit.

        ``protect`` holds block ids that must not be demoted this epoch
        (the promotion candidates themselves).  Returns #demoted.
        """
        pool = self.pool
        headroom = pool.fast_block_budget - pool.fast_used()
        if headroom >= need_blocks:
            return 0
        protect_set = set(protect)
        victims = mig.coldest_first(
            [b for b in pool.blocks
             if not b.free and b.kind == FAST_KIND
             and b.bid not in protect_set],
            last_touch=lambda b: b.last_touch_step,
            touches=lambda b: b.touch_count)
        demoted = 0
        for v in victims:
            if headroom + demoted >= need_blocks:
                break
            if pool.migrate(v.bid, self.slow_kind):
                demoted += 1
        return demoted

    # ------------------------------------------------------------------ #
    def step(self, touched_seq_ids: Sequence[int], epoch: int) -> int:
        """Run one tiering epoch; returns #blocks promoted.

        ``touched_seq_ids``: sequences whose blocks decode read this
        iteration (the pool's heat counters were already bumped by
        ``touch_seq``).
        """
        pool = self.pool
        self.stats.epochs += 1
        if isinstance(self.policy, mig.NoBalance):
            return 0

        # hint faults: touched blocks resident on a slow kind
        touched_slow: List[mig.Block] = []
        candidates: Dict[int, KVBlock] = {}
        for sid in touched_seq_ids:
            for b in pool.seq_blocks(sid):
                if b.kind != FAST_KIND:
                    touched_slow.append(self._shadow_of(b))
                    candidates[b.bid] = b
        faults_before = self._mig_stats.hint_faults
        promote = self.policy.promote_set(touched_slow, epoch,
                                          self._mig_stats)
        self.stats.hint_faults += self._mig_stats.hint_faults - faults_before

        promoted = 0
        if promote:
            want = [s.idx for s in promote]
            self._demote_for(len(want), epoch, protect=want)
            for bid in want:
                if pool.fast_used() >= pool.fast_block_budget:
                    self.stats.denied_promotions += len(want) - promoted
                    break
                if pool.migrate(bid, FAST_KIND):
                    promoted += 1
        self.stats.promoted = pool.counters.promoted
        self.stats.demoted = pool.counters.demoted
        self.stats.migrated_bytes = pool.counters.migrated_bytes
        return promoted

    # ------------------------------------------------------------------ #
    def profiling_overhead_s(self) -> float:
        """Per-fault CPU cost, as core.migration charges it (PMO 2)."""
        return self.stats.hint_faults * self.policy.fault_cost_s
