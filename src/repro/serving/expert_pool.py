"""ExpertPool: MoE expert weights as first-class tiered objects.

The MoE configs' expert stores dwarf the KV cache (qwen3-moe-30b keeps
128 experts x 48 layers of FFN weight), yet decode activates only
``top_k`` experts per token — exactly the working-set shape the paper's
tiering study rewards: a small hot set earning fast residency while the
cold majority lives on the CXL-class capacity tier.  This module gives
every (layer, expert) weight block the same citizenship KV blocks have:

  * residency is recorded in the shared ``ResidencyLedger`` under the
    pool's tenant namespace, promotions gated by ``can_place`` against
    the arbitrated fast-tier budget;
  * routing decisions feed per-expert heat into an ``AccessTrace``
    (one read event per activation, sized at the expert's weight
    bytes), so phase detection sees expert traffic the same way it
    sees KV traffic;
  * promote/demote deltas flow through the cross-tenant
    ``MoveScheduler`` when one is attached (coalesced, priority-ordered
    and fluid-scheduled with everyone else's moves), falling back to
    direct ledger moves otherwise;
  * the ``predictive`` policy reuses the PR 5 phase machinery: a
    per-recurrence-signature expert-heat table (the expert-level
    ``PhaseDemandTable``) learns which experts each recurring routing
    phase activates, and when the ``PhaseDetector`` predicts a
    *different* signature for the next epoch, that phase's hot experts
    are promoted during the current epoch's slack — so a recurring
    routing burst's first tokens find their experts already fast.

Prefetch efficacy is first-class telemetry: ``prefetch_promotes``
counts experts promoted ahead of a predicted phase, ``prefetch_hits``
how many were then actually routed to while still fast — their ratio
is the bench's ``moe.prefetch_hit_ratio`` headline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

from ..core.migration import BlockMove, PlacementDelta
from ..telemetry import AccessTrace, PhaseDetector
from .kv_pool import FAST_KIND

ExpertKey = Tuple[int, int]            # (global moe-layer index, expert)


@dataclasses.dataclass
class ExpertCounters:
    accesses: int = 0          # expert activations observed
    fast_hits: int = 0         # activation found the expert fast-resident
    promoted: int = 0
    demoted: int = 0
    prefetch_promotes: int = 0  # promotions issued for a predicted phase
    prefetch_hits: int = 0      # prefetched experts routed to while fast


class ExpertPool:
    """Tier residency + heat + predictive prefetch for MoE experts.

    ``n_layers`` is the number of MoE layers (global, across units);
    ``fast_expert_budget`` how many experts may be fast-resident at
    once; ``policy`` is ``"lru"`` (recency earns fast residency — the
    expert-cache baseline) or ``"predictive"`` (recency plus
    next-phase prefetch from the signature heat table).
    """

    def __init__(self, n_layers: int, n_experts: int, expert_nbytes: int,
                 *, fast_expert_budget: int, policy: str = "lru",
                 ledger=None, tenant: str = "experts",
                 slow_kind: str = "pinned_host",
                 movesched=None, move_priority: Optional[float] = None,
                 tracer=None, heat_alpha: float = 0.5,
                 max_signatures: int = 32):
        if policy not in ("lru", "predictive"):
            raise ValueError(f"unknown expert policy {policy!r}")
        if n_layers <= 0 or n_experts <= 0:
            raise ValueError("n_layers and n_experts must be positive")
        if expert_nbytes <= 0:
            raise ValueError("expert_nbytes must be positive")
        self.n_layers = n_layers
        self.n_experts = n_experts
        self.expert_nbytes = int(expert_nbytes)
        self.policy = policy
        self.slow_kind = slow_kind
        self.tenant = tenant
        self.movesched = movesched
        self.move_priority = move_priority
        self.tracer = tracer
        from ..pool.ledger import ResidencyLedger
        self.ledger = ledger if ledger is not None else ResidencyLedger()
        self.ledger.register_tenant(tenant)
        self.fast_expert_budget = max(int(fast_expert_budget), 1)
        self.ledger.set_budget(tenant, FAST_KIND,
                               self.fast_expert_budget
                               * self.expert_nbytes)
        # every expert starts on the capacity tier
        self.kinds: Dict[ExpertKey, str] = {}
        for l in range(n_layers):
            for e in range(n_experts):
                key = (l, e)
                self.kinds[key] = slow_kind
                self.ledger.record_alloc(tenant, self._obj(key),
                                         slow_kind, self.expert_nbytes)
        # heat: activation recency/frequency + the expert-level access
        # trace the phase detector watches
        self.trace = AccessTrace()
        self.phases = PhaseDetector(self.trace)
        self.last_step: Dict[ExpertKey, int] = {}
        self.touch_count: Dict[ExpertKey, int] = {}
        self.counters = ExpertCounters()
        self._epoch_counts: Dict[ExpertKey, int] = {}
        self._epoch_slow_bytes = 0
        self._last_slow_bytes = 0          # last closed epoch's misses
        self._last_prefetch_bytes = 0
        # signature -> {expert: EMA activation share} (the expert-level
        # PhaseDemandTable), TTL/size-bounded like the arbiter's
        self.heat_alpha = float(heat_alpha)
        self.max_signatures = int(max_signatures)
        self._sig_heat: Dict[Hashable, Dict[ExpertKey, float]] = {}
        self._sig_seen: Dict[Hashable, int] = {}
        self._prefetched: set = set()      # promoted-ahead, not yet hit

    # ------------------------------------------------------------------ #
    @staticmethod
    def _obj(key: ExpertKey) -> str:
        return f"expert.L{key[0]}.E{key[1]}"

    def kind_of(self, layer: int, expert: int) -> str:
        return self.kinds[(layer, expert)]

    def fast_residents(self) -> int:
        return sum(1 for k in self.kinds.values() if k == FAST_KIND)

    def fast_hit_ratio(self) -> Optional[float]:
        if self.counters.accesses == 0:
            return None
        return self.counters.fast_hits / self.counters.accesses

    def prefetch_hit_ratio(self) -> Optional[float]:
        if self.counters.prefetch_promotes == 0:
            return None
        return (self.counters.prefetch_hits
                / self.counters.prefetch_promotes)

    # ------------------------------------------------------------------ #
    # heat (routing decisions)                                           #
    # ------------------------------------------------------------------ #
    def record_routing(self, layer: int, expert_ids: Sequence[int],
                       step: int) -> None:
        """Account one decode step's routed experts for one MoE layer.

        Each activation reads the expert's weight block once; slow-
        resident activations are the misses the tier link pays for.
        """
        c = self.counters
        for e in expert_ids:
            key = (int(layer), int(e))
            kind = self.kinds[key]
            c.accesses += 1
            if kind == FAST_KIND:
                c.fast_hits += 1
                if key in self._prefetched:
                    c.prefetch_hits += 1
                    self._prefetched.discard(key)
            else:
                self._epoch_slow_bytes += self.expert_nbytes
            self.last_step[key] = step
            self.touch_count[key] = self.touch_count.get(key, 0) + 1
            self._epoch_counts[key] = self._epoch_counts.get(key, 0) + 1
            self.trace.observe(self._obj(key),
                               read_bytes=self.expert_nbytes,
                               phase="decode")

    # ------------------------------------------------------------------ #
    # per-epoch policy step                                              #
    # ------------------------------------------------------------------ #
    def _observe_signature_heat(self, counts: Dict[ExpertKey, int],
                                epoch: int) -> None:
        sig = self.phases.signature
        if sig is None or not counts:
            return
        total = float(sum(counts.values()))
        heat = self._sig_heat.setdefault(sig, {})
        a = self.heat_alpha
        shares = {k: n / total for k, n in counts.items()}
        for k in set(heat) | set(shares):
            heat[k] = heat.get(k, 0.0) + a * (shares.get(k, 0.0)
                                              - heat.get(k, 0.0))
            if heat[k] < 1e-6:
                del heat[k]
        self._sig_seen[sig] = epoch
        if len(self._sig_heat) > self.max_signatures:
            stale = sorted(self._sig_seen, key=self._sig_seen.get)
            for s in stale[: len(self._sig_heat)
                           - self.max_signatures]:
                self._sig_heat.pop(s, None)
                self._sig_seen.pop(s, None)

    def _lru_ranking(self) -> List[ExpertKey]:
        """Every expert ever touched, most recently active first."""
        return sorted(self.last_step,
                      key=lambda k: (-self.last_step[k], k))

    def _predicted_hot(self, epoch: int) -> List[ExpertKey]:
        """Hot experts of the *predicted next* phase (empty when the
        prediction is 'more of the same' or the phase is unknown)."""
        sig = self.phases.signature
        nxt = self.phases.expected_signature(1)
        if nxt is None or nxt == sig:
            return []
        heat = self._sig_heat.get(nxt)
        if not heat:
            return []
        return sorted(heat, key=lambda k: (-heat[k], k))

    def step(self, epoch: int) -> None:
        """Close the epoch: fold heat into the signature table, pick the
        desired fast set, and run the promote/demote delta through the
        move scheduler."""
        counts = self._epoch_counts
        self._epoch_counts = {}
        self._last_slow_bytes = self._epoch_slow_bytes
        self._epoch_slow_bytes = 0
        self.trace.advance_epoch()
        self.phases.update()
        self._observe_signature_heat(counts, epoch)

        budget = self.fast_expert_budget
        prefetch_keys: List[ExpertKey] = []
        if self.policy == "predictive":
            predicted = self._predicted_hot(epoch)
            # the predicted phase's experts take the front of the fast
            # set; present-epoch recency fills whatever is left
            desired = list(predicted[:budget])
            taken = set(desired)
            for k in self._lru_ranking():
                if len(desired) >= budget:
                    break
                if k not in taken:
                    desired.append(k)
                    taken.add(k)
            prefetch_keys = [k for k in predicted[:budget]
                             if self.kinds[k] != FAST_KIND]
        else:
            desired = self._lru_ranking()[:budget]
        desired_set = set(desired)

        fast = [k for k, kind in self.kinds.items() if kind == FAST_KIND]
        to_promote = [k for k in desired if k not in set(fast)]
        # demote only to make room: coldest fast residents outside the
        # desired set go first
        overflow = len(fast) + len(to_promote) - budget
        to_demote: List[ExpertKey] = []
        if overflow > 0:
            evictable = sorted(
                (k for k in fast if k not in desired_set),
                key=lambda k: (self.last_step.get(k, -1), k))
            to_demote = evictable[:overflow]

        moves = [BlockMove(self._obj(k), FAST_KIND, self.slow_kind,
                           self.expert_nbytes) for k in to_demote]
        moves += [BlockMove(self._obj(k), self.slow_kind, FAST_KIND,
                            self.expert_nbytes) for k in to_promote]
        if moves:
            self._pending_prefetch = set(prefetch_keys)
            delta = PlacementDelta(moves)
            if self.movesched is not None:
                self.movesched.submit(self.tenant, delta,
                                      move_fn=self._apply_move,
                                      priority=self.move_priority)
                self.movesched.flush(epoch=epoch)
            else:
                for m in delta.moves:
                    self._apply_move(m.obj, m.src, m.dst, m.nbytes)
        n_prefetched = sum(1 for k in prefetch_keys
                           if self.kinds[k] == FAST_KIND)
        self.counters.prefetch_promotes += n_prefetched
        self._prefetched.update(k for k in prefetch_keys
                                if self.kinds[k] == FAST_KIND)
        self._last_prefetch_bytes = n_prefetched * self.expert_nbytes
        if self.tracer is not None and (to_promote or to_demote):
            self.tracer.event(
                "expert.rebalance", cat="expert", epoch=epoch,
                promoted=len(to_promote), demoted=len(to_demote),
                prefetched=n_prefetched,
                fast_residents=self.fast_residents())

    def _parse(self, obj: str) -> Optional[ExpertKey]:
        try:
            l, e = obj.split(".")[1:3]
            return (int(l[1:]), int(e[1:]))
        except (ValueError, IndexError):
            return None

    def _apply_move(self, obj: str, src: str, dst: str,
                    nbytes: int) -> int:
        """MoveScheduler move_fn: one expert's ledger-gated tier move."""
        key = self._parse(obj)
        if key is None or self.kinds.get(key) != src:
            return 0
        if dst == FAST_KIND and not self.ledger.can_place(
                self.tenant, FAST_KIND, nbytes):
            return 0
        self.ledger.record_move(self.tenant, obj, src, dst, nbytes)
        self.kinds[key] = dst
        if dst == FAST_KIND:
            self.counters.promoted += 1
        else:
            self.counters.demoted += 1
            self._prefetched.discard(key)   # unused prefetch = a miss
        return nbytes

    # ------------------------------------------------------------------ #
    # QoS flow publication                                               #
    # ------------------------------------------------------------------ #
    def gather_flows(self, topology, period_s: float = 0.05,
                     cls: str = "read") -> List:
        """Class-tagged expert-gather flows for the contention plane.

        One ``cls`` flow for the last epoch's slow-resident expert
        reads (decode stalls on these), plus a ``prefetch`` flow for
        promoted-ahead bytes — so the blame ledger can tell a victim's
        demand reads from this tenant's optional prefetch traffic.
        """
        if topology is None:
            return []
        from ..topology import Flow
        src = topology.node_of(self.slow_kind)
        dst = topology.node_of(FAST_KIND)
        if src is None or dst is None or src == dst:
            return []
        flows = []
        if self._last_slow_bytes > 0:
            flows.append(Flow(src, dst,
                              self._last_slow_bytes / period_s / 1e9,
                              cls=cls, tenant=self.tenant))
        if self._last_prefetch_bytes > 0:
            flows.append(Flow(src, dst,
                              self._last_prefetch_bytes / period_s / 1e9,
                              cls="prefetch", tenant=self.tenant))
        return flows

    # ------------------------------------------------------------------ #
    def summary(self) -> Dict[str, float]:
        c = self.counters
        out = {
            "expert.accesses": float(c.accesses),
            "expert.fast_hits": float(c.fast_hits),
            "expert.promoted": float(c.promoted),
            "expert.demoted": float(c.demoted),
            "expert.prefetch_promotes": float(c.prefetch_promotes),
            "expert.prefetch_hits": float(c.prefetch_hits),
            "expert.fast_residents": float(self.fast_residents()),
        }
        r = self.fast_hit_ratio()
        if r is not None:
            out["expert.fast_hit_ratio"] = r
        r = self.prefetch_hit_ratio()
        if r is not None:
            out["expert.prefetch_hit_ratio"] = r
        return out


def expert_nbytes_from_config(cfg) -> int:
    """Weight bytes of ONE expert's FFN block (gate+up+down, bf16)."""
    mats = 3 if cfg.act == "silu" else 2
    return mats * cfg.d_model * cfg.d_ff * 2


def moe_layers_from_config(cfg) -> int:
    """Global count of MoE layers (units x per-unit MoE specs)."""
    per_unit = sum(1 for s in cfg.pattern if s.moe)
    return cfg.n_units * per_unit
