"""Nested ServingConfig sections + the one place cross-field rules live.

``ServingConfig`` accreted ~25 flat flags across five PRs (tiering,
telemetry, SLO/QoS, MoE experts, calibration); this module groups them
into sections so call sites read by concern:

  * :class:`TieringOptions`  — pool sizing, tiering policy, adaptive
    replanning, calibration, topology;
  * :class:`QoSOptions`      — SLO targets, the interference-class QoS
    plane, the flow class;
  * :class:`ExpertOptions`   — MoE expert residency + the fused
    tiered-gather decode path;
  * :class:`ClusterOptions`  — the multi-host plane: replica count,
    session-router policy, model sharding.

The flat ``ServingConfig`` fields remain valid kwargs: its
``__post_init__`` migrates in both directions (a section passed in
wins over the flat defaults; flat kwargs populate the sections), so
nothing written against the old surface breaks.

``validate_args`` centralizes every cross-field constraint the serve
CLI used to enforce through scattered ``parser.error`` calls
(``--qos`` requires a topology and a decode SLO, ``--predictive``
requires ``--adaptive``, ...), raising :class:`ConfigError` —
``ServingConfig.from_args`` is the one builder both the CLI and
programmatic callers go through.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ClusterOptions", "ConfigError", "ExpertOptions",
           "QoSOptions", "ROUTER_POLICIES", "TieringOptions",
           "validate_args"]

ROUTER_POLICIES = ("headroom-distance", "round-robin", "random",
                   "least-loaded")


class ConfigError(ValueError):
    """A cross-field serving-configuration constraint was violated."""


@dataclasses.dataclass
class TieringOptions:
    """Pool sizing, tiering policy, and the adaptive control plane."""

    policy: str = "tiering08"
    num_blocks: Optional[int] = None
    fast_block_budget: Optional[int] = None
    slow_kind: str = "pinned_host"
    migrate_every: int = 1
    device_budget_bytes: Optional[int] = None
    host_budget_bytes: Optional[int] = None
    adaptive: bool = False
    replan_every: int = 8
    sample_rate: float = 1.0
    predictive: bool = False
    calibrate: bool = False
    topology: Optional[str] = None


@dataclasses.dataclass
class QoSOptions:
    """SLO targets + the interference-class QoS plane."""

    enabled: bool = False          # the old flat ``qos`` switch
    cls: str = "read"              # interference class of KV gathers
    slo_p95_ttft_s: Optional[float] = None
    slo_p95_decode_s: Optional[float] = None
    slo_p99_decode_s: Optional[float] = None
    slo_p999_decode_s: Optional[float] = None
    slo_window: int = 512

    @property
    def decode_slo_s(self) -> Optional[float]:
        """The decode target violation prediction gates on."""
        return self.slo_p99_decode_s or self.slo_p95_decode_s


@dataclasses.dataclass
class ExpertOptions:
    """MoE expert tier residency + fused tiered-gather decode."""

    policy: Optional[str] = None   # None | "lru" | "predictive"
    fast_fraction: float = 0.25
    fused_gather: bool = False


@dataclasses.dataclass
class ClusterOptions:
    """The multi-host serving plane (new in the cluster PR — no flat
    legacy kwargs to migrate)."""

    replicas: int = 1
    router: str = "headroom-distance"
    shard_model: bool = True       # shard params over each replica mesh

    def __post_init__(self):
        if self.replicas < 1:
            raise ConfigError(f"cluster replicas must be >= 1, "
                              f"got {self.replicas}")
        if self.router not in ROUTER_POLICIES:
            raise ConfigError(
                f"unknown router policy {self.router!r}; choose from "
                f"{', '.join(ROUTER_POLICIES)}")


# section field -> flat ServingConfig field, per section attribute
SECTION_FIELDS = {
    "tiering": {
        "policy": "policy", "num_blocks": "num_blocks",
        "fast_block_budget": "fast_block_budget",
        "slow_kind": "slow_kind", "migrate_every": "migrate_every",
        "device_budget_bytes": "device_budget_bytes",
        "host_budget_bytes": "host_budget_bytes",
        "adaptive": "adaptive", "replan_every": "replan_every",
        "sample_rate": "sample_rate", "predictive": "predictive",
        "calibrate": "calibrate", "topology": "topology",
    },
    "qos_options": {
        "enabled": "qos", "cls": "qos_class",
        "slo_p95_ttft_s": "slo_p95_ttft_s",
        "slo_p95_decode_s": "slo_p95_decode_s",
        "slo_p99_decode_s": "slo_p99_decode_s",
        "slo_p999_decode_s": "slo_p999_decode_s",
        "slo_window": "slo_window",
    },
    "experts": {
        "policy": "expert_policy",
        "fast_fraction": "expert_fast_fraction",
        "fused_gather": "fused_gather",
    },
}
_SECTION_TYPES = {"tiering": TieringOptions, "qos_options": QoSOptions,
                  "experts": ExpertOptions}


def sync_sections(cfg) -> None:
    """Two-way section/flat migration for ``ServingConfig.__post_init__``.

    A section the caller passed wins: its values overwrite the flat
    fields every engine code path reads.  A section left at None is
    built from the flat fields, so old flat kwargs fully populate the
    new surface.
    """
    for attr, mapping in SECTION_FIELDS.items():
        section = getattr(cfg, attr)
        if section is not None:
            for sfield, flat in mapping.items():
                setattr(cfg, flat, getattr(section, sfield))
        else:
            setattr(cfg, attr, _SECTION_TYPES[attr](
                **{sfield: getattr(cfg, flat)
                   for sfield, flat in mapping.items()}))


def _flag(name: str) -> str:
    return "--" + name.replace("_", "-")


def validate_args(args) -> None:
    """Every cross-field rule of the serving surface, in one place.

    ``args`` is any namespace shaped like the serve CLI's (missing
    attributes read as their defaults).  Raises :class:`ConfigError`;
    the CLI maps that onto ``parser.error``.
    """
    get = lambda name, default=None: getattr(args, name, default)  # noqa: E731
    scheduler = get("scheduler", "continuous")
    continuous = scheduler == "continuous"

    if get("predictive") and not get("adaptive"):
        raise ConfigError(
            "--predictive requires --adaptive (prediction pre-stages "
            "the adaptive replanner's phase-cached plans)")
    if get("calibrate") and not get("adaptive"):
        raise ConfigError(
            "--calibrate requires --adaptive (the corrections feed "
            "the adaptive replanner's cost model)")
    if not continuous:
        if get("calibrate"):
            raise ConfigError(
                "--calibrate only takes effect with --scheduler "
                "continuous (the calibrator corrects the paged "
                "engine's planning tiers)")
        if get("tenant") is not None:
            raise ConfigError(
                "--tenant only takes effect with --scheduler "
                "continuous (the paged pool is what registers a "
                "ledger tenant)")
        for name in ("trace_out", "metrics_out", "audit_out",
                     "slo_p95_ttft", "slo_p95_decode", "slo_p99_decode",
                     "slo_p999_decode", "expert_policy"):
            if get(name) is not None:
                raise ConfigError(
                    f"{_flag(name)} only takes effect with --scheduler "
                    "continuous (the observability plane instruments "
                    "the paged engine)")
        if get("fused_gather"):
            raise ConfigError(
                "--fused-gather only takes effect with --scheduler "
                "continuous (it rewires the paged decode path)")
        if get("qos"):
            raise ConfigError(
                "--qos only takes effect with --scheduler continuous "
                "(the QoS plane instruments the paged engine's "
                "admission path)")
        if get("topology"):
            raise ConfigError(
                "--topology only takes effect with --scheduler "
                "continuous (contention-aware admission; add "
                "--adaptive to also price replans over it)")
        if get("replicas", 1) and int(get("replicas", 1)) > 1:
            raise ConfigError(
                "--replicas only takes effect with --scheduler "
                "continuous (the cluster plane routes sessions onto "
                "paged engines)")
    if get("qos"):
        if not get("topology") and int(get("replicas", 1) or 1) <= 1:
            raise ConfigError(
                "--qos requires --topology (blame attribution joins "
                "violations to topology links)")
        if get("slo_p99_decode") is None and get("slo_p95_decode") is None:
            raise ConfigError(
                "--qos requires a decode SLO (--slo-p99-decode or "
                "--slo-p95-decode) to predict violations against")
    replicas = int(get("replicas", 1) or 1)
    if replicas > 1:
        # cluster engines shard params over per-replica meshes; the
        # pooled fused-gather / expert stores are still committed to
        # the default device and would make jit see disjoint device
        # sets — gate them out until they are mesh-placed too
        if get("fused_gather"):
            raise ConfigError(
                "--fused-gather is not yet supported with --replicas "
                "> 1 (the pooled KV layout is not mesh-placed)")
        if get("expert_policy"):
            raise ConfigError(
                "--expert-policy is not yet supported with --replicas "
                "> 1 (expert stores are not mesh-placed)")
    router = get("router")
    if router is not None and router not in ROUTER_POLICIES:
        raise ConfigError(
            f"unknown --router policy {router!r}; choose from "
            f"{', '.join(ROUTER_POLICIES)}")
