"""Paged KV-cache block pool with tier-resident blocks (vLLM-style).

The serving analogue of the paper's Sec. IV-B finding: the KV cache is
the object whose capacity growth pays for CXL-class tiers, and it is
accessed at *block* granularity (decode streams the whole cache, but a
request's blocks go cold the moment the request finishes or is
preempted).  The pool therefore manages fixed-size token blocks:

  * a block holds ``block_tokens`` tokens of K and V for every attention
    layer of the model: k/v each ``(U, n_attn, block_tokens, KV, hd)``;
  * each block is resident in one JAX memory kind ("device" = HBM
    analogue, "pinned_host"/"unpinned_host" = the CXL-class capacity
    tiers), moved with ``migrate`` — the mechanism tiering.py drives;
  * tier *occupancy* is not private state: every alloc/free/migrate is
    recorded in a ``repro.pool.ResidencyLedger`` under the pool's
    tenant namespace, and ``blocks_on``/``fast_used`` read back through
    it — so several pools (tenants) can share one ledger and one
    arbitrated fast-tier budget (``ledger.can_place`` gates
    promotions, replacing the old private fast-block counter);
  * a block table maps ``seq_id -> [block ids]`` (logical order);
  * per-block access bits (touch count + last-touch step, the page-table
    A-bit analogue) feed the promotion/demotion policies adapted from
    ``core.migration``, while *aggregate* access heat is emitted as
    telemetry events (``attach_telemetry``) — reads on decode, writes on
    prefill/append — so phase detection and the adaptive replanner see
    the same traffic the tiering policies act on.

The pool also runs in *metadata-only* mode (``spec=None``): alloc/free/
migrate bookkeeping without array payloads, which is what the
trace-driven scheduler benchmark and the pure-logic tests use.

Data mode has two layouts:

  * **per-block** (default): each block owns its own (k, v) arrays,
    ``device_put`` onto the block's memory kind — migration moves the
    payload.  ``gather_seq`` stages a sequence into one contiguous
    buffer (the gather-then-compute path).
  * **pooled** (``pooled=True``): payloads live in two persistent
    per-layer stores ``(U, n_attn, num_blocks, bt, KV, hd)`` indexed by
    physical block id.  This is the layout the fused tiered-gather
    kernel computes over *directly* — ``gather_tables`` hands it the
    int32 block-index table instead of a staging copy — so tier
    residency becomes the ledger's logical bookkeeping (the discipline
    single-memory CPU hosts already use for every kind).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

FAST_KIND = "device"


@dataclasses.dataclass(frozen=True)
class KVBlockSpec:
    """Shape of one pool block (set from the model config)."""

    n_units: int
    n_attn: int          # attention layers per unit
    block_tokens: int
    n_kv: int
    head_dim: int
    dtype: str = "bfloat16"

    @property
    def kv_shape(self) -> Tuple[int, ...]:
        return (self.n_units, self.n_attn, self.block_tokens, self.n_kv,
                self.head_dim)

    @property
    def nbytes(self) -> int:
        # K and V
        import jax.numpy as jnp
        item = jnp.dtype(self.dtype).itemsize
        return 2 * int(np.prod(self.kv_shape)) * item


@dataclasses.dataclass
class KVBlock:
    """One physical block: payload + residency + heat."""

    bid: int
    kind: str                      # current memory kind
    seq_id: Optional[int] = None   # owner sequence (None = free)
    logical_idx: int = -1          # position in the owner's block table
    k: Optional[object] = None     # jax.Array (U, n_attn, bt, KV, hd)
    v: Optional[object] = None
    touch_count: int = 0
    last_touch_step: int = -(10 ** 9)

    @property
    def free(self) -> bool:
        return self.seq_id is None


class PoolExhausted(Exception):
    """No free blocks left — the scheduler must preempt."""


@dataclasses.dataclass
class PoolCounters:
    allocs: int = 0
    frees: int = 0
    promoted: int = 0
    demoted: int = 0
    migrated_bytes: int = 0
    defrags: int = 0


class PagedKVPool:
    """Fixed-size paged KV pool over tiered memory kinds.

    ``num_blocks`` bounds total KV capacity; ``fast_block_budget`` bounds
    how many blocks may reside on the fast kind at once (the HBM-analogue
    capacity budget from core.tiers / the cost model).
    """

    def __init__(self, num_blocks: int, block_tokens: int,
                 spec: Optional[KVBlockSpec] = None,
                 fast_block_budget: Optional[int] = None,
                 slow_kind: str = "pinned_host",
                 default_kind: Optional[str] = None,
                 ledger=None, tenant: str = "kv",
                 pooled: bool = False, sharding_fn=None):
        if num_blocks <= 0:
            raise ValueError("num_blocks must be positive")
        if block_tokens <= 0:
            raise ValueError("block_tokens must be positive")
        if spec is not None and spec.block_tokens != block_tokens:
            raise ValueError("spec.block_tokens != pool block_tokens")
        if pooled and spec is None:
            raise ValueError("pooled layout needs a data-mode spec")
        self.block_tokens = block_tokens
        self.spec = spec
        self.pooled = pooled
        # cluster replicas pin payloads to their replica mesh instead
        # of the process-default device, so block arrays and the
        # replica's sharded params share one device set under jit
        self.sharding_fn = sharding_fn
        self.k_store = self.v_store = None
        if pooled:
            import jax.numpy as jnp
            shape = (spec.n_units, spec.n_attn, num_blocks,
                     block_tokens, spec.n_kv, spec.head_dim)
            self.k_store = jnp.zeros(shape, dtype=spec.dtype)
            self.v_store = jnp.zeros(shape, dtype=spec.dtype)
        self.slow_kind = slow_kind
        self.default_kind = default_kind or slow_kind
        self.blocks: List[KVBlock] = [
            KVBlock(bid=i, kind=self.default_kind)
            for i in range(num_blocks)]
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self.table: Dict[int, List[int]] = {}   # seq_id -> [bid]
        self.seq_len: Dict[int, int] = {}       # seq_id -> tokens written
        self.counters = PoolCounters()
        self.telemetry = None                   # AccessTrace/AccessSampler
        # residency accounting lives in the (possibly shared) ledger; a
        # private one is created for the single-tenant default
        from ..pool.ledger import ResidencyLedger
        self.ledger = ledger if ledger is not None else ResidencyLedger()
        self.tenant = tenant
        self.ledger.register_tenant(tenant)
        self.fast_block_budget = (num_blocks if fast_block_budget is None
                                  else fast_block_budget)

    # ------------------------------------------------------------------ #
    # telemetry                                                          #
    # ------------------------------------------------------------------ #
    def attach_telemetry(self, recorder) -> None:
        """Attach an access recorder (anything with ``observe(obj,
        read_bytes, write_bytes, random_fraction, phase)`` — an
        AccessTrace or an AccessSampler front-end)."""
        self.telemetry = recorder

    def _emit(self, seq_id: int, read_bytes: int = 0, write_bytes: int = 0,
              phase: str = "") -> None:
        if self.telemetry is not None and (read_bytes or write_bytes):
            self.telemetry.observe(f"seq{seq_id}", read_bytes, write_bytes,
                                   0.0, phase=phase)

    # ------------------------------------------------------------------ #
    # capacity accounting (occupancy reads/writes go through the ledger) #
    # ------------------------------------------------------------------ #
    def _obj(self, seq_id: int) -> str:
        return f"seq{seq_id}"

    @property
    def fast_block_budget(self) -> int:
        b = self.ledger.budget(self.tenant, FAST_KIND)
        return self.num_blocks if b is None else b // self.block_nbytes()

    @fast_block_budget.setter
    def fast_block_budget(self, n_blocks: int) -> None:
        self.ledger.set_budget(self.tenant, FAST_KIND,
                               int(n_blocks) * self.block_nbytes())

    @property
    def num_blocks(self) -> int:
        return len(self.blocks)

    def free_block_count(self) -> int:
        return len(self._free)

    def used_block_count(self) -> int:
        return self.num_blocks - len(self._free)

    def blocks_on(self, kind: str) -> int:
        return self.ledger.bytes_on(kind, self.tenant) \
            // self.block_nbytes()

    def fast_used(self) -> int:
        return self.blocks_on(FAST_KIND)

    def occupancy(self) -> float:
        return self.used_block_count() / self.num_blocks

    def blocks_for_tokens(self, n_tokens: int) -> int:
        return max(1, math.ceil(n_tokens / self.block_tokens))

    def block_nbytes(self) -> int:
        return self.spec.nbytes if self.spec is not None else 1

    # ------------------------------------------------------------------ #
    # alloc / free                                                       #
    # ------------------------------------------------------------------ #
    def can_alloc(self, n_blocks: int) -> bool:
        return len(self._free) >= n_blocks

    def alloc(self, seq_id: int, n_blocks: int = 1,
              kind=None) -> List[int]:
        """Append ``n_blocks`` fresh blocks to ``seq_id``'s table.

        ``kind`` may be a memory-kind string, ``None`` (pool default),
        or a zero-arg callable evaluated per block — how a static-split
        allocator interleaves kinds at block granularity.
        """
        if n_blocks > len(self._free):
            raise PoolExhausted(
                f"need {n_blocks} blocks, {len(self._free)} free")
        tbl = self.table.setdefault(seq_id, [])
        self.seq_len.setdefault(seq_id, 0)
        out = []
        bn = self.block_nbytes()
        for _ in range(n_blocks):
            k = kind() if callable(kind) else kind
            bid = self._free.pop()
            b = self.blocks[bid]
            b.seq_id = seq_id
            b.logical_idx = len(tbl)
            b.kind = k or self.default_kind
            b.touch_count = 0
            b.last_touch_step = -(10 ** 9)
            tbl.append(bid)
            out.append(bid)
            self.counters.allocs += 1
            self.ledger.record_alloc(self.tenant, self._obj(seq_id),
                                     b.kind, bn)
        return out

    def free_seq(self, seq_id: int) -> int:
        """Release every block of a sequence; returns #blocks freed."""
        tbl = self.table.pop(seq_id, [])
        self.seq_len.pop(seq_id, None)
        if self.telemetry is not None:
            forget = getattr(self.telemetry, "forget", None)
            if forget is not None:
                forget(f"seq{seq_id}")
        for bid in tbl:
            b = self.blocks[bid]
            b.seq_id = None
            b.logical_idx = -1
            b.k = b.v = None
            self._free.append(bid)
            self.counters.frees += 1
        if tbl:
            self.ledger.retire(self.tenant, self._obj(seq_id))
        return len(tbl)

    def seq_blocks(self, seq_id: int) -> List[KVBlock]:
        return [self.blocks[bid] for bid in self.table.get(seq_id, [])]

    # ------------------------------------------------------------------ #
    # heat                                                               #
    # ------------------------------------------------------------------ #
    def touch_seq(self, seq_id: int, step: int) -> None:
        """Decode reads the whole block table of a sequence each step."""
        tbl = self.table.get(seq_id, [])
        for bid in tbl:
            b = self.blocks[bid]
            b.touch_count += 1
            b.last_touch_step = step
        self._emit(seq_id, read_bytes=len(tbl) * self.block_nbytes(),
                   phase="decode")

    # ------------------------------------------------------------------ #
    # payload I/O (data mode)                                            #
    # ------------------------------------------------------------------ #
    def _sharding(self, kind: str):
        if self.sharding_fn is not None:
            return self.sharding_fn(kind)
        from ..core.tiered_array import sharding_for_kind
        return sharding_for_kind(kind)

    def write_block(self, bid: int, k, v) -> None:
        """Place (k, v) payloads on the block's current kind."""
        if self.spec is None:
            return
        if self.pooled:
            # pooled layout: payloads live at the block's slot in the
            # persistent stores; residency is the ledger's (logical)
            self.k_store = self.k_store.at[:, :, bid].set(
                k.astype(self.k_store.dtype))
            self.v_store = self.v_store.at[:, :, bid].set(
                v.astype(self.v_store.dtype))
            return
        import jax
        b = self.blocks[bid]
        sh = self._sharding(b.kind)
        b.k = jax.device_put(k, sh)
        b.v = jax.device_put(v, sh)

    def write_prefill(self, seq_id: int, kv_k, kv_v, n_tokens: int,
                      kind: Optional[str] = None) -> None:
        """Split a contiguous prefill cache into this sequence's blocks.

        kv_k/kv_v: (U, n_attn, n_tokens, KV, hd) — batch already squeezed.
        Allocates exactly the blocks the tokens need, on ``kind``.
        """
        bt = self.block_tokens
        n_blocks = self.blocks_for_tokens(n_tokens)
        pad = n_blocks * bt - n_tokens
        if self.spec is not None and pad:
            import jax.numpy as jnp
            pads = [(0, 0)] * kv_k.ndim
            pads[2] = (0, pad)
            kv_k = jnp.pad(kv_k, pads)
            kv_v = jnp.pad(kv_v, pads)
        bids = self.alloc(seq_id, n_blocks, kind=kind)
        for i, bid in enumerate(bids):
            if self.spec is not None:
                self.write_block(bid, kv_k[:, :, i * bt:(i + 1) * bt],
                                 kv_v[:, :, i * bt:(i + 1) * bt])
        self.seq_len[seq_id] = n_tokens
        self._emit(seq_id, write_bytes=n_blocks * self.block_nbytes(),
                   phase="prefill")

    def append_token(self, seq_id: int, k_tok, v_tok) -> None:
        """Write one new token's (k, v) at the tail of the sequence.

        k_tok/v_tok: (U, n_attn, KV, hd).  The caller must have allocated
        a tail block when ``seq_len % block_tokens == 0``.
        """
        n = self.seq_len[seq_id]
        tbl = self.table[seq_id]
        blk_idx, off = divmod(n, self.block_tokens)
        if blk_idx >= len(tbl):
            raise PoolExhausted(
                f"seq {seq_id}: token {n} has no tail block")
        if self.pooled:
            bid = tbl[blk_idx]
            self.k_store = self.k_store.at[:, :, bid, off].set(
                k_tok.astype(self.k_store.dtype))
            self.v_store = self.v_store.at[:, :, bid, off].set(
                v_tok.astype(self.v_store.dtype))
        elif self.spec is not None:
            import jax.numpy as jnp
            b = self.blocks[tbl[blk_idx]]
            if b.k is None:            # fresh tail block
                b.k = jnp.zeros(self.spec.kv_shape, dtype=self.spec.dtype)
                b.v = jnp.zeros(self.spec.kv_shape, dtype=self.spec.dtype)
            b.k = b.k.at[:, :, off].set(k_tok.astype(b.k.dtype))
            b.v = b.v.at[:, :, off].set(v_tok.astype(b.v.dtype))
            sh = self._sharding(b.kind)
            import jax
            b.k = jax.device_put(b.k, sh)
            b.v = jax.device_put(b.v, sh)
        self.seq_len[seq_id] = n + 1
        self._emit(seq_id,
                   write_bytes=max(self.block_nbytes()
                                   // self.block_tokens, 1),
                   phase="decode")

    def gather_seq(self, seq_id: int, pad_blocks: int):
        """Contiguous (k, v) on the fast kind, padded to ``pad_blocks``.

        Returns (k, v) of shape (U, n_attn, pad_blocks*bt, KV, hd).  All
        block transfers are dispatched first (device_put is async) so
        host->device DMA of later blocks overlaps earlier concat work —
        the TieredArray.gather discipline.
        """
        import jax
        import jax.numpy as jnp
        assert self.spec is not None, "gather_seq needs a data-mode pool"
        dev = self._sharding(FAST_KIND)
        tbl = self.table.get(seq_id, [])
        if self.pooled:
            # staging copy out of the pooled stores (the baseline the
            # fused path's gather_tables exists to avoid): take the
            # sequence's blocks, flatten to token order, zero-pad.
            # Positions past seq_len may hold a prior owner's stale
            # tokens — every consumer masks by kv_len.
            n_pad = pad_blocks - len(tbl)
            if n_pad < 0:
                raise ValueError(f"seq {seq_id} has {len(tbl)} blocks "
                                 f"> pad_blocks={pad_blocks}")
            shape = list(self.spec.kv_shape)
            shape[2] = pad_blocks * self.block_tokens
            if not tbl:
                z = jnp.zeros(tuple(shape), dtype=self.spec.dtype)
                return z, z
            idx = jnp.asarray(tbl, jnp.int32)

            def take(store):
                g = jnp.take(store, idx, axis=2)   # (U,n_attn,nb,bt,..)
                g = g.reshape(g.shape[0], g.shape[1], -1, *g.shape[4:])
                if n_pad:
                    pads = [(0, 0)] * g.ndim
                    pads[2] = (0, n_pad * self.block_tokens)
                    g = jnp.pad(g, pads)
                return g

            return take(self.k_store), take(self.v_store)
        zero = None
        ks, vs = [], []
        for bid in tbl:
            b = self.blocks[bid]
            if b.k is None:            # allocated tail block, not written
                if zero is None:
                    zero = jnp.zeros(self.spec.kv_shape,
                                     dtype=self.spec.dtype)
                ks.append(zero)
                vs.append(zero)
            else:
                ks.append(jax.device_put(b.k, dev))
                vs.append(jax.device_put(b.v, dev))
        n_pad = pad_blocks - len(tbl)
        if n_pad < 0:
            raise ValueError(f"seq {seq_id} has {len(tbl)} blocks "
                             f"> pad_blocks={pad_blocks}")
        if n_pad:
            z = jnp.zeros(self.spec.kv_shape, dtype=self.spec.dtype)
            ks.extend([z] * n_pad)
            vs.extend([z] * n_pad)
        if not ks:
            shape = list(self.spec.kv_shape)
            shape[2] = pad_blocks * self.block_tokens
            z = jnp.zeros(tuple(shape), dtype=self.spec.dtype)
            return z, z
        return jnp.concatenate(ks, axis=2), jnp.concatenate(vs, axis=2)

    def gather_tables(self, seq_ids: Sequence[int], pad_blocks: int
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """Block-index tables for the fused tiered-gather kernel.

        Returns ``(tables, lens)``: ``tables`` is int32
        ``(len(seq_ids), pad_blocks)`` of physical block ids in logical
        order (pad slots hold block 0 — masked by ``lens``), ``lens``
        the per-sequence cached token counts.  This is the whole
        "gather": the kernel indexes ``k_store``/``v_store`` through it
        directly, no staging copy.
        """
        if not self.pooled:
            raise ValueError("gather_tables needs a pooled-layout pool")
        tables = np.zeros((len(seq_ids), pad_blocks), np.int32)
        lens = np.zeros((len(seq_ids),), np.int32)
        for i, sid in enumerate(seq_ids):
            tbl = self.table.get(sid, [])
            if len(tbl) > pad_blocks:
                raise ValueError(f"seq {sid} has {len(tbl)} blocks "
                                 f"> pad_blocks={pad_blocks}")
            tables[i, :len(tbl)] = tbl
            lens[i] = self.seq_len.get(sid, 0)
        return tables, lens

    # ------------------------------------------------------------------ #
    # migration                                                          #
    # ------------------------------------------------------------------ #
    def migrate(self, bid: int, kind: str) -> bool:
        """Move one block to ``kind``; returns False if it's a no-op.

        Promotions are gated by the ledger (``can_place``): the tenant's
        arbitrated fast-tier budget and any shared fast-tier capacity
        both bind, so pools sharing one ledger contend honestly.
        """
        b = self.blocks[bid]
        if b.free or b.kind == kind:
            return False
        bn = self.block_nbytes()
        was_fast = b.kind == FAST_KIND
        if kind == FAST_KIND and not was_fast:
            if not self.ledger.can_place(self.tenant, FAST_KIND, bn):
                return False
            self.counters.promoted += 1
        elif was_fast and kind != FAST_KIND:
            self.counters.demoted += 1
        self.ledger.record_move(self.tenant, self._obj(b.seq_id),
                                b.kind, kind, bn)
        b.kind = kind
        self.counters.migrated_bytes += bn
        # pooled layout keeps payloads in place: residency is logical
        # (ledger-tracked), which is how every kind behaves on a
        # single-memory CPU host anyway
        if self.spec is not None and not self.pooled and b.k is not None:
            import jax
            sh = self._sharding(kind)
            b.k = jax.device_put(b.k, sh)
            b.v = jax.device_put(b.v, sh)
        return True

    # ------------------------------------------------------------------ #
    # defrag                                                             #
    # ------------------------------------------------------------------ #
    def defrag(self) -> int:
        """Compact live blocks to the lowest physical ids.

        After long run with churn, live blocks scatter across the id
        space; compaction keeps each sequence's physical blocks
        contiguous and in logical order (so a future DMA engine can use
        strided descriptors).  Payloads and residency move with the
        block.  Returns the number of blocks relocated.
        """
        live: List[KVBlock] = []
        for seq_id in sorted(self.table):
            live.extend(self.blocks[bid] for bid in self.table[seq_id])
        moved = 0
        new_blocks = [KVBlock(bid=i, kind=self.default_kind)
                      for i in range(self.num_blocks)]
        new_table: Dict[int, List[int]] = {s: [] for s in self.table}
        for i, old in enumerate(live):
            nb = new_blocks[i]
            if old.bid != i:
                moved += 1
            nb.kind = old.kind
            nb.seq_id = old.seq_id
            nb.logical_idx = old.logical_idx
            nb.k, nb.v = old.k, old.v
            nb.touch_count = old.touch_count
            nb.last_touch_step = old.last_touch_step
            new_table[old.seq_id].append(i)
        if self.pooled and live:
            # permute the store rows with the block ids so slot i still
            # holds the payload of the block now labelled i
            import jax.numpy as jnp
            perm = [old.bid for old in live]
            rest = [i for i in range(self.num_blocks)
                    if i not in set(perm)]
            idx = jnp.asarray(perm + rest, jnp.int32)
            self.k_store = jnp.take(self.k_store, idx, axis=2)
            self.v_store = jnp.take(self.v_store, idx, axis=2)
        self.blocks = new_blocks
        self.table = new_table
        self._free = list(range(self.num_blocks - 1, len(live) - 1, -1))
        self.counters.defrags += 1
        return moved


# ---------------------------------------------------------------------- #
# TieredKVCache: whole-cache tier residency for the one-shot engine.      #
# ---------------------------------------------------------------------- #
class TieredKVCache:
    """Static-split KV residency for FlexGenEngine (one-shot path).

    Owns the tier placement of a contiguous decode cache between steps:
    ``stash`` writes the cache back to its tier shares, ``restore``
    materializes it on device.  This is the degenerate single-request
    case of the paged pool (one 'block' per share span), kept so the
    one-shot engine and the paged engine share one KV-management home.
    """

    def __init__(self, shares: Sequence[Tuple[str, float]],
                 keys: Sequence[str] = ("kv_k", "kv_v"),
                 ledger=None, tenant: str = "oneshot_kv"):
        self.shares = list(shares)
        self.keys = list(keys)
        self._tiered: Dict[str, object] = {}
        from ..pool.ledger import ResidencyLedger
        self.ledger = ledger if ledger is not None else ResidencyLedger()
        self.tenant = tenant
        self.ledger.register_tenant(tenant)

    @property
    def offloaded(self) -> bool:
        return any(f > 0 for kind, f in self.shares if kind != FAST_KIND)

    def _sync_ledger(self, key: str) -> None:
        """Mirror one buffer's realized per-kind bytes into the ledger
        (the TieredArray's block rounding is the truth, not the asked
        shares)."""
        from ..core.tiered_array import LOGICAL_KINDS
        ta = self._tiered[key]
        placement = {k: ta.bytes_on(k)
                     for k in set(LOGICAL_KINDS) | set(ta.kinds)
                     if ta.bytes_on(k) > 0}
        if self.ledger.has(self.tenant, key):
            self.ledger.retire(self.tenant, key)
        self.ledger.register(self.tenant, key, placement)

    def stash(self, cache: Dict[str, object]) -> None:
        """Place the cache's KV buffers across the configured shares."""
        from ..core.tiered_array import TieredArray
        if not self.offloaded:
            return
        for key in self.keys:
            if key in cache:
                arr = cache[key]
                self._tiered[key] = TieredArray.place(
                    arr.reshape(arr.shape[0], -1), self.shares)
                self._sync_ledger(key)

    def restore(self, cache: Dict[str, object]) -> Dict[str, object]:
        """Materialize tier-resident KV back into the cache dict."""
        if not self.offloaded:
            return cache
        for key, ta in self._tiered.items():
            cache[key] = ta.gather().reshape(cache[key].shape)
        return cache

    def update(self, cache: Dict[str, object]) -> None:
        """Write a stepped cache back, preserving placement."""
        if not self.offloaded:
            return
        for key in self._tiered:
            self._tiered[key] = self._tiered[key].update(
                cache[key].reshape(cache[key].shape[0], -1))

    def bytes_on(self, kind: str) -> int:
        """Tier occupancy, read through the ledger (single source)."""
        return self.ledger.bytes_on(kind, self.tenant)


def spec_from_config(cfg, block_tokens: int) -> KVBlockSpec:
    """Derive the pool block spec from a ModelConfig (attn layers only)."""
    n_attn = len(cfg.unit_attn_layers)
    if n_attn == 0:
        raise ValueError(f"{cfg.name}: no attention layers to page")
    dtype = "int8" if cfg.kv_cache_dtype == "int8" else "bfloat16"
    return KVBlockSpec(n_units=cfg.n_units, n_attn=n_attn,
                       block_tokens=block_tokens, n_kv=cfg.n_kv,
                       head_dim=cfg.head_dim, dtype=dtype)
