"""Serving metrics: per-request latency, throughput, pool + migration.

Timestamps are injected by the caller (wall clock in the engine, a
simulated clock in the trace-driven benchmark), so the same aggregator
serves both and stays deterministic under test.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np


def percentile(values: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile (q in [0, 100]); 0.0 when empty."""
    if not values:
        return 0.0
    return float(np.percentile(values, q))


@dataclasses.dataclass
class RequestMetrics:
    rid: int
    arrival_s: float = 0.0
    admitted_s: Optional[float] = None
    first_token_s: Optional[float] = None
    last_token_s: Optional[float] = None
    finished_s: Optional[float] = None
    prompt_tokens: int = 0
    new_tokens: int = 0
    preemptions: int = 0

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token (queueing + prefill)."""
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    @property
    def latency_s(self) -> Optional[float]:
        if self.finished_s is None:
            return None
        return self.finished_s - self.arrival_s

    @property
    def decode_tok_s(self) -> Optional[float]:
        """Tokens/s over the decode span (first token -> finish)."""
        if self.finished_s is None or self.first_token_s is None:
            return None
        span = self.finished_s - self.first_token_s
        if self.new_tokens <= 1:
            return None
        return (self.new_tokens - 1) / max(span, 1e-9)


@dataclasses.dataclass
class PoolSample:
    step: int
    used_blocks: int
    fast_blocks: int
    running: int
    waiting: int


class ServingMetrics:
    """Aggregates request lifecycles, pool occupancy, and migration.

    ``registry`` (a repro.obs.MetricsRegistry) and ``slo`` (a
    repro.obs.SLOMonitor) are optional sinks: when attached, request
    lifecycle events also stream into central histograms (TTFT,
    inter-token decode gap, end-to-end latency) and the live SLO
    windows, without changing any of the aggregate math here.
    """

    def __init__(self, registry=None, slo=None,
                 max_decode_gaps: int = 65536):
        self.requests: Dict[int, RequestMetrics] = {}
        self.samples: List[PoolSample] = []
        # retained inter-token gaps: exact tail quantiles (p95/p99)
        # over a bounded window — the QoS plane's victim-tail metric
        self.decode_gaps: Deque[float] = deque(
            maxlen=int(max_decode_gaps))
        self.iterations = 0
        self.prefills = 0
        self.decode_steps = 0
        self.decode_tokens = 0
        self.start_s: Optional[float] = None
        self.end_s: Optional[float] = None
        self.registry = registry
        self.slo = slo

    # ------------------------------------------------------------------ #
    def on_submit(self, rid: int, arrival_s: float,
                  prompt_tokens: int) -> None:
        self.requests[rid] = RequestMetrics(
            rid=rid, arrival_s=arrival_s, prompt_tokens=prompt_tokens)
        if self.start_s is None or arrival_s < self.start_s:
            self.start_s = arrival_s

    def on_admit(self, rid: int, now_s: float) -> None:
        r = self.requests[rid]
        if r.admitted_s is None:      # keep the first admission (TTFT)
            r.admitted_s = now_s
        self.prefills += 1

    def on_token(self, rid: int, now_s: float) -> None:
        r = self.requests[rid]
        if r.first_token_s is None:
            r.first_token_s = now_s
            ttft = now_s - r.arrival_s
            if self.registry is not None:
                self.registry.histogram(
                    "serving.ttft_s", help="time to first token").observe(ttft)
            if self.slo is not None:
                self.slo.observe("ttft", ttft, now=now_s)
        elif r.last_token_s is not None:
            gap = now_s - r.last_token_s
            self.decode_gaps.append(gap)
            if self.registry is not None:
                self.registry.histogram(
                    "serving.decode_gap_s",
                    help="inter-token decode latency").observe(gap)
            if self.slo is not None:
                self.slo.observe("decode_latency", gap, now=now_s)
        r.last_token_s = now_s
        r.new_tokens += 1
        self.decode_tokens += 1

    def on_preempt(self, rid: int, now_s: float = 0.0) -> None:
        """Record a preemption as it happens (not only at finish), so
        preempted-but-unfinished requests show up in the summary."""
        r = self.requests.get(rid)
        if r is not None:
            r.preemptions += 1
        if self.registry is not None:
            self.registry.counter(
                "serving.preemptions", help="request evictions").inc()

    def on_finish(self, rid: int, now_s: float, preemptions: int) -> None:
        r = self.requests[rid]
        r.finished_s = now_s
        # the scheduler's count is authoritative; on_preempt keeps the
        # live count, so take whichever saw more
        r.preemptions = max(r.preemptions, preemptions)
        if self.end_s is None or now_s > self.end_s:
            self.end_s = now_s
        if self.registry is not None:
            self.registry.counter(
                "serving.finished", help="completed requests").inc()
            if r.latency_s is not None:
                self.registry.histogram(
                    "serving.latency_s",
                    help="end-to-end request latency").observe(r.latency_s)

    def on_iteration(self, step: int, used_blocks: int, fast_blocks: int,
                     running: int, waiting: int) -> None:
        self.iterations += 1
        if running:
            self.decode_steps += 1
        self.samples.append(PoolSample(step, used_blocks, fast_blocks,
                                       running, waiting))

    # ------------------------------------------------------------------ #
    def aggregate_decode_tok_s(self) -> float:
        """New tokens per second of wall time across the whole trace."""
        if self.start_s is None or self.end_s is None:
            return 0.0
        return self.decode_tokens / max(self.end_s - self.start_s, 1e-9)

    def mean_occupancy(self) -> float:
        if not self.samples:
            return 0.0
        return sum(s.used_blocks for s in self.samples) / len(self.samples)

    def summary(self, tiering: Optional[Dict[str, int]] = None
                ) -> Dict[str, float]:
        done = [r for r in self.requests.values()
                if r.finished_s is not None]
        ttfts = [r.ttft_s for r in done if r.ttft_s is not None]
        lats = [r.latency_s for r in done if r.latency_s is not None]
        toks = [r.decode_tok_s for r in done
                if r.decode_tok_s is not None]
        out: Dict[str, float] = {
            "requests": float(len(self.requests)),
            "finished": float(len(done)),
            "iterations": float(self.iterations),
            "decode_tokens": float(self.decode_tokens),
            "throughput_tok_s": self.aggregate_decode_tok_s(),
            "mean_ttft_s": (sum(ttfts) / len(ttfts)) if ttfts else 0.0,
            "p50_ttft_s": percentile(ttfts, 50),
            "p95_ttft_s": percentile(ttfts, 95),
            "p99_ttft_s": percentile(ttfts, 99),
            "p50_latency_s": percentile(lats, 50),
            "p95_latency_s": percentile(lats, 95),
            "p99_latency_s": percentile(lats, 99),
            "p95_decode_gap_s": percentile(list(self.decode_gaps), 95),
            "p99_decode_gap_s": percentile(list(self.decode_gaps), 99),
            "mean_decode_tok_s": (sum(toks) / len(toks)) if toks else 0.0,
            "p50_decode_tok_s": percentile(toks, 50),
            "p95_decode_tok_s": percentile(toks, 95),
            "mean_pool_blocks": self.mean_occupancy(),
            # all requests, not just finished: a preempted request that
            # never re-finished must still count
            "preemptions": float(sum(r.preemptions
                                     for r in self.requests.values())),
        }
        if tiering:
            for k, v in tiering.items():
                out[f"tiering.{k}"] = float(v)
            # tiering overhead per unit of useful work: how many bytes
            # were migrated for each generated token
            out["migrated_bytes_per_token"] = (
                float(tiering.get("migrated_bytes", 0))
                / max(self.decode_tokens, 1))
        return out

    def per_request_rows(self) -> List[Tuple[int, Dict[str, float]]]:
        """Exportable per-request rows.

        ``ttft_s`` / ``decode_tok_s`` are *omitted* (not sentinel
        ``-1.0``) when undefined, so downstream tooling can never
        mistake a never-started request for a negative latency.
        """
        rows = []
        for rid in sorted(self.requests):
            r = self.requests[rid]
            row: Dict[str, float] = {
                "prompt_tokens": float(r.prompt_tokens),
                "new_tokens": float(r.new_tokens),
                "preemptions": float(r.preemptions),
            }
            if r.ttft_s is not None:
                row["ttft_s"] = r.ttft_s
            if r.decode_tok_s is not None:
                row["decode_tok_s"] = r.decode_tok_s
            rows.append((rid, row))
        return rows
