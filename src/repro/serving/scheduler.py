"""Continuous-batching request scheduler over the paged KV pool.

Pure bookkeeping — no JAX.  The engine owns the model math; the
scheduler owns *which* requests prefill, decode, or get preempted each
iteration, against the pool's block accounting:

  * FIFO admission from the wait queue, capped by (a) an admission
    budget derived from the cost model's capacity reasoning (LIO 3:
    batch scales with memory capacity), (b) the pool having enough
    free blocks for the request's prompt plus a growth margin, and
    (c) — with a ``TopologyGraph`` attached — a *link budget*: each
    running request's KV gather is a flow from its blocks' resident
    kinds to the fast kind, and ``TopologyGraph.contended_flows``
    fair-shares the PCIe/UPI links those flows cross; a candidate
    whose admission would drag any flow below
    ``link_efficiency_floor`` of its offered bandwidth stays queued
    (block capacity alone does not see shared-link saturation);
  * prefill/decode interleaving: at most ``max_prefill_per_iter`` new
    admissions per iteration, so admission bursts cannot starve the
    running batch (the latency/throughput split of Fig. 11);
  * preemption when the pool runs dry mid-decode: the *latest-admitted*
    running request is evicted (LIFO — it has the least sunk decode
    work), its blocks are freed, and it returns to the FRONT of the
    wait queue so it is re-admitted before fresh arrivals;
  * **ledger-driven preemption** (``preempt_over_budget``): when a
    ``TierBudgetArbiter`` shrinks this tenant's fast-tier budget in the
    shared ``ResidencyLedger``, the scheduler evicts the
    lowest-priority running sequences holding fast blocks until the
    tenant is back within budget — the grant moves to the other tenant
    immediately instead of leaking out block-by-block through tierer
    churn.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

import numpy as np

from .kv_pool import FAST_KIND, PagedKVPool


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    PREEMPTED = "preempted"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One serving request; tokens accumulate across preemptions."""

    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int
    arrival_s: float = 0.0
    # relative importance for budget preemption: when the arbiter
    # shrinks the tenant's fast budget, the lowest-priority running
    # sequences are evicted first (ties: latest-admitted)
    priority: float = 0.0
    state: RequestState = RequestState.WAITING
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    admit_order: int = -1              # monotone admission stamp
    preemptions: int = 0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens

    @property
    def context_len(self) -> int:
        """Tokens that must be in the KV cache to continue decoding."""
        return self.prompt_len + len(self.out_tokens)

    def prefill_tokens(self) -> np.ndarray:
        """Token ids to prefill on (re-)admission: prompt + generated."""
        if not self.out_tokens:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.out_tokens, np.int32)])


@dataclasses.dataclass
class AdmissionPlan:
    """Capacity-budget-derived scheduler sizing (LIO 3)."""

    max_batch: int
    total_blocks: int
    fast_blocks: int
    block_tokens: int

    @property
    def max_seq_blocks(self) -> int:
        return max(1, self.total_blocks // max(self.max_batch, 1))


def plan_admission(cfg, block_tokens: int, max_context: int,
                   device_budget_bytes: int, host_budget_bytes: int,
                   max_batch_cap: int = 64) -> AdmissionPlan:
    """Size the pool and the admission limit from a capacity budget.

    The KV budget is what remains of the device budget after bf16
    weights (the FlexGen inventory, core.objects.llm_serve_objects)
    plus the whole host budget; batch is capped so every admitted
    request can grow to ``max_context`` tokens without exhausting the
    pool — the paper's capacity -> batch -> throughput chain.
    """
    from .kv_pool import spec_from_config
    spec = spec_from_config(cfg, block_tokens)
    weight_bytes = 2 * cfg.param_count()
    device_kv = max(device_budget_bytes - weight_bytes, 0)
    total_kv = device_kv + host_budget_bytes
    total_blocks = max(int(total_kv // spec.nbytes), 1)
    fast_blocks = min(int(device_kv // spec.nbytes), total_blocks)
    blocks_per_seq = max(1, math.ceil(max_context / block_tokens))
    max_batch = max(1, min(max_batch_cap, total_blocks // blocks_per_seq))
    return AdmissionPlan(max_batch=max_batch, total_blocks=total_blocks,
                         fast_blocks=fast_blocks,
                         block_tokens=block_tokens)


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int = 8
    max_prefill_per_iter: int = 2
    # free blocks a request must leave after admission (growth margin,
    # in blocks) before it is let in — crude decode headroom control
    admission_margin_blocks: int = 1
    # contention-aware admission (repro.topology): a candidate is
    # admitted only while every gather flow keeps at least this
    # fraction of its offered bandwidth under fair link sharing
    link_efficiency_floor: float = 0.5
    # assumed iteration period for converting a request's KV gather
    # bytes into an offered bandwidth (GB/s = bytes / period / 1e9)
    gather_period_s: float = 0.05
    # interference class this tenant's KV gather traffic presents to
    # the class-aware contention model (read | write | prefetch)
    flow_class: str = "read"


class ContinuousBatchingScheduler:
    """Queue + running set + preemption over a PagedKVPool.

    ``topology`` (a repro.topology.TopologyGraph whose tier nodes are
    aliased to the pool's memory kinds) switches admission from pure
    block capacity to capacity + shared-link budgeting.
    """

    def __init__(self, pool: PagedKVPool,
                 cfg: Optional[SchedulerConfig] = None,
                 topology=None, tracer=None, predictor=None):
        self.pool = pool
        self.cfg = cfg or SchedulerConfig()
        self.topology = topology
        self.tracer = tracer          # optional repro.obs.TraceRecorder
        # optional repro.obs.ViolationPredictor: admission + preemption
        # gate on predicted SLO violation instead of the flat
        # link_efficiency_floor
        self.predictor = predictor
        self.waiting: Deque[Request] = deque()
        self.running: List[Request] = []
        self.finished: List[Request] = []
        self._admit_stamp = 0
        self.preemption_events = 0
        self.link_deferrals = 0       # admissions blocked by link budget
        self.budget_preemptions = 0   # evictions forced by ledger budget
        self.qos_deferrals = 0        # blocked by predicted violation
        self.slo_preemptions = 0      # evictions forced by predicted SLO

    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        req.state = RequestState.WAITING
        self.waiting.append(req)

    def submit_all(self, reqs: Sequence[Request]) -> None:
        for r in sorted(reqs, key=lambda r: r.arrival_s):
            self.submit(r)

    @property
    def active(self) -> bool:
        return bool(self.waiting or self.running)

    # ------------------------------------------------------------------ #
    def blocks_needed(self, req: Request) -> int:
        """Blocks for the request's current context + one decode token."""
        return self.pool.blocks_for_tokens(req.context_len + 1)

    # ------------------------------------------------------------------ #
    def _gather_flow(self, kind: str, n_blocks: int):
        """One KV-gather flow: ``n_blocks`` streamed from ``kind``'s
        node to the fast kind's node each iteration (None if the
        topology doesn't map the kinds or they share a node)."""
        from ..topology import Flow
        src = self.topology.node_of(kind)
        dst = self.topology.node_of(FAST_KIND)
        if src is None or dst is None or src == dst:
            return None
        offered = (n_blocks * self.pool.block_nbytes()
                   / self.cfg.gather_period_s / 1e9)
        if offered <= 0:
            return None
        return Flow(src, dst, offered, cls=self.cfg.flow_class,
                    tenant=self.pool.tenant)

    def _running_flows(self) -> List:
        """Per-request gather flows for the running set, grouped by the
        resident kind of each request's slow-tier blocks (read through
        the pool's ledger-backed residency)."""
        flows = []
        for req in self.running:
            per_kind: Dict[str, int] = {}
            for b in self.pool.seq_blocks(req.rid):
                if b.kind != FAST_KIND:
                    per_kind[b.kind] = per_kind.get(b.kind, 0) + 1
            for kind, n in per_kind.items():
                f = self._gather_flow(kind, n)
                if f is not None:
                    flows.append(f)
        return flows

    def _link_budget_allows(self, req: Request, running: List,
                            pending: List) -> bool:
        """Does admitting ``req`` keep its own gather flow above the
        efficiency floor without dragging any currently-healthy flow
        below it?  Only the candidate's *marginal* effect counts: a
        flow already below the floor (e.g. demotion-heavy residency on
        an unrelated link) must not head-of-line-block admissions that
        would not make it worse.  ``running`` is the admit-call's
        snapshot of ``_running_flows()`` (residency cannot change
        mid-admission); ``pending`` accumulates this call's admitted
        candidates."""
        cand = self._gather_flow(self.pool.default_kind,
                                 self.blocks_needed(req))
        if cand is None:
            return True
        floor = self.cfg.link_efficiency_floor
        base = running + pending
        healthy = [r.achieved_GBps >= floor * f.offered_GBps
                   for f, r in zip(base,
                                   self.topology.contended_flows(base))]
        flows = base + [cand]
        results = self.topology.contended_flows(flows,
                                                tracer=self.tracer)
        ok = results[-1].achieved_GBps >= floor * cand.offered_GBps \
            and all(r.achieved_GBps >= floor * f.offered_GBps
                    for (f, r), was in zip(zip(base, results), healthy)
                    if was)
        if ok:
            pending.append(cand)
        return ok

    def _qos_allows(self, req: Request, running: List,
                    pending: List) -> bool:
        """Violation-predictive admission: would admitting ``req`` keep
        every tenant with a registered SLO target (this one and the
        neighbors in the blame book) under its predicted-p99 threshold?
        Replaces the flat efficiency floor when a ``ViolationPredictor``
        is attached — the floor is blind to *who* the lost bandwidth
        hurts; the predictor prices the candidate against the victim's
        actual tail budget."""
        cand = self._gather_flow(self.pool.default_kind,
                                 self.blocks_needed(req))
        if cand is None:
            return True
        if not running and not pending:
            # empty-pool bootstrap: with nothing running, deferring the
            # sole workload protects no one — an unachievable own target
            # must not starve the engine (liveness over forecast)
            pending.append(cand)
            return True
        own = running + pending + [cand]
        ok = self.predictor.admission_ok(own, exclude=self.pool.tenant)
        if ok:
            pending.append(cand)
        elif self.tracer is not None:
            viol = self.predictor.violations(own,
                                             exclude=self.pool.tenant)
            self.tracer.event(
                "sched.qos_defer", cat="sched", rid=req.rid,
                offered_GBps=cand.offered_GBps,
                violations={t: {"predicted_s": p, "threshold_s": thr}
                            for t, (p, thr) in viol.items()})
        return ok

    def admit(self, now_s: float = 0.0) -> List[Request]:
        """Admit waiting requests FIFO under batch + block budgets.

        Preempted requests sit at the queue front (LIFO re-entry), so
        they win readmission over fresh arrivals.  Returns the newly
        admitted requests — the engine must prefill each one.
        """
        admitted: List[Request] = []
        pending_flows: List = []       # flows of this call's admissions
        running_flows: List = (self._running_flows()
                               if self.topology is not None else [])
        margin = self.cfg.admission_margin_blocks
        while (self.waiting
               and len(self.running) < self.cfg.max_batch
               and len(admitted) < self.cfg.max_prefill_per_iter):
            head = self.waiting[0]
            if head.arrival_s > now_s:
                break
            need = self.blocks_needed(head)
            if not self.pool.can_alloc(need + margin):
                break
            if self.topology is not None and self.predictor is not None:
                if not self._qos_allows(head, running_flows,
                                        pending_flows):
                    self.qos_deferrals += 1
                    break
            elif self.topology is not None and \
                    not self._link_budget_allows(head, running_flows,
                                                 pending_flows):
                self.link_deferrals += 1
                break
            self.waiting.popleft()
            head.state = RequestState.RUNNING
            head.admit_order = self._admit_stamp
            self._admit_stamp += 1
            self.running.append(head)
            admitted.append(head)
            if self.tracer is not None:
                self.tracer.event("sched.admit", cat="sched", ts=now_s,
                                  rid=head.rid, blocks=need,
                                  running=len(self.running),
                                  waiting=len(self.waiting),
                                  readmission=head.preemptions > 0)
        return admitted

    # ------------------------------------------------------------------ #
    def preempt_for_blocks(self, n_blocks: int,
                           protect: Optional[Request] = None
                           ) -> List[Request]:
        """Evict running requests (latest-admitted first) until
        ``n_blocks`` pool blocks are free.

        ``protect`` is exempt (the request that needs the blocks); if it
        is the only one left, it preempts itself — progress for older
        work beats holding a pool-starved tail request.  Evicted
        requests lose their pool blocks (re-prefill on readmission —
        preemption-by-recompute) and rejoin the queue FRONT.
        """
        victims: List[Request] = []
        order = sorted(self.running, key=lambda r: -r.admit_order)
        others = [r for r in order if r is not protect]
        last = [protect] if protect in order else []
        for victim in others + last:       # protect evicted only last
            if self.pool.free_block_count() >= n_blocks:
                break
            self._evict(victim, reason="capacity")
            victims.append(victim)
        return victims

    def preempt_over_budget(self) -> List[Request]:
        """Ledger-driven preemption: enforce an arbiter budget shrink
        *now* instead of waiting for tierer churn.

        While this tenant holds more fast-tier bytes than its ledger
        budget (``ledger.over_budget`` — e.g. a ``TierBudgetArbiter``
        handed the capacity to another tenant), evict the
        lowest-priority running sequence that still holds fast blocks
        (ties: latest-admitted, the least sunk decode work).  Eviction
        frees the sequence's pool blocks — the ledger retires its
        residency, reconciling the fast tier immediately — and the
        request re-enters the queue front for recompute once capacity
        (or budget) returns.  Sub-block excess is rounding, not
        squatting, and never triggers an eviction; a shrink with no
        running fast holder is left to the tierer (nothing a
        preemption could free).
        """
        pool = self.pool
        bn = max(pool.block_nbytes(), 1)
        victims: List[Request] = []
        while self.running:
            over = pool.ledger.over_budget(pool.tenant, FAST_KIND)
            if over < bn:
                break
            holders = [r for r in self.running
                       if any(b.kind == FAST_KIND
                              for b in pool.seq_blocks(r.rid))]
            if not holders:
                break
            victim = min(holders,
                         key=lambda r: (r.priority, -r.admit_order))
            self._evict(victim, reason="budget")
            self.budget_preemptions += 1
            victims.append(victim)
        return victims

    def preempt_predicted_violation(self) -> List[Request]:
        """Predictive QoS preemption: while this tenant's live gather
        flows push any tenant with a registered SLO target past its
        predicted-p99 threshold, evict the lowest-priority running
        sequence still holding slow-tier blocks (the ones generating
        cross-link traffic).  The flat-floor baseline only reacts after
        the victim's tail has already blown; this backs off while the
        violation is still a forecast."""
        if self.predictor is None:
            return []
        victims: List[Request] = []
        while self.running:
            own = self._running_flows()
            if not own:
                break
            viol = self.predictor.violations(own,
                                             exclude=self.pool.tenant)
            if not viol:
                break
            if set(viol) == {self.pool.tenant} and len(self.running) <= 1:
                # self-inflicted forecast with nothing left to shed
                # against: evicting the last sequence cannot improve its
                # own tail (the work still has to run) — it only
                # livelocks the engine through evict/readmit cycles
                break
            holders = [r for r in self.running
                       if any(b.kind != FAST_KIND
                              for b in self.pool.seq_blocks(r.rid))]
            if not holders:
                break
            victim = min(holders,
                         key=lambda r: (r.priority, -r.admit_order))
            self._evict(victim, reason="slo")
            self.slo_preemptions += 1
            victims.append(victim)
        return victims

    def _evict(self, req: Request, reason: str = "capacity") -> None:
        self.pool.free_seq(req.rid)
        self.running.remove(req)
        req.state = RequestState.PREEMPTED
        req.preemptions += 1
        self.preemption_events += 1
        if self.tracer is not None:
            self.tracer.event("sched.preempt", cat="sched", rid=req.rid,
                              reason=reason, priority=req.priority,
                              preemptions=req.preemptions)
        # LIFO re-entry: most recently evicted goes first
        self.waiting.appendleft(req)

    def finish(self, req: Request) -> None:
        self.pool.free_seq(req.rid)
        self.running.remove(req)
        req.state = RequestState.FINISHED
        self.finished.append(req)
        if self.tracer is not None:
            self.tracer.event("sched.finish", cat="sched", rid=req.rid,
                              new_tokens=len(req.out_tokens),
                              preemptions=req.preemptions)
