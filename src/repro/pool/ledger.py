"""ResidencyLedger: the single source of truth for bytes-per-tier-per-tenant.

"Dissecting CXL Memory Performance at Scale" (arXiv:2409.14317) and
"CXL-Interference" (arXiv:2411.18308) both show that what dominates
performance at scale is contention for the *shared* fast tier and the
shared links — not any one object's placement in isolation.  Arbitrating
that contention requires one consistent view of who holds what, where.
This repo previously kept three disconnected views (TieredArray block
kinds, PagedKVPool block residency, the replanner's realized shares);
the ledger unifies them:

  * every placeable object belongs to a **tenant** namespace (a serving
    engine, an offload trainer, a benchmark workload) and records its
    bytes per tier here — clients call ``record_alloc`` / ``record_free``
    / ``record_move`` as the physical placement changes;
  * per-tenant **budgets** (set by the ``TierBudgetArbiter``) and
    per-tier **capacities** gate placement: ``can_place`` is the one
    admission check promotions everywhere consult;
  * per-tenant **AccessTrace namespaces** attach here, so the arbiter
    and per-tenant replanners read demand from the same place they read
    residency;
  * priced moves ride the shared ``core.migration.MigrationExecutor``
    (topology-aware when one is attached), so every layer prices a byte
    move identically.

Tenant keys are hierarchical ``repro.cluster.Namespace`` values
(``replica/tenant``): the multi-host plane registers each replica's
pool under its own replica component, and glob patterns
(``bytes_on(tier, "replica0/*")``, ``aggregate("*/*")``) roll per-replica
views up to the fleet exactly.  Bare strings keep working — they
normalize to ``default/<tenant>`` through the deprecation shim.

Ownership rule for recording: whoever *physically* moves bytes records
the move (``PagedKVPool.migrate``, ``TieredStateStore.move_fn``).
Objects registered by a planner (``origin="plan"``) have no physical
client, so the planner itself updates their residency from realized
shares.  ``origin`` tracks which regime an object is under; a planner
never overwrites client-owned residency.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple, Union

from ..cluster.namespace import Namespace, is_pattern
from ..core.migration import BlockMove, MigrationExecutor, PlacementDelta
from ..core.tiers import MemoryTier

Share = Tuple[str, float]
# every public entry point accepts either form
TenantKey = Union[str, Namespace]

# effectively-unlimited headroom when neither budget nor capacity binds
UNBOUNDED = 1 << 62


class LedgerError(ValueError):
    """Inconsistent ledger operation (unknown tenant/object, bad bytes)."""


@dataclasses.dataclass
class Tenant:
    """One namespace sharing the pool: weight feeds priority-weighted
    arbitration; ``trace`` is the tenant's AccessTrace namespace."""

    name: str
    weight: float = 1.0
    trace: Optional[object] = None     # telemetry.AccessTrace
    ns: Optional[Namespace] = None     # the structured key


@dataclasses.dataclass
class LedgerCounters:
    allocs: int = 0
    frees: int = 0
    moves: int = 0
    migrated_bytes: int = 0
    denied_moves: int = 0


class ResidencyLedger:
    """Bytes-per-tier-per-tenant accounting with budget/capacity gates."""

    def __init__(self, tiers: Optional[Mapping[str, MemoryTier]] = None,
                 capacity_bytes: Optional[Mapping[str, int]] = None,
                 executor: Optional[MigrationExecutor] = None,
                 topology=None):
        self.tiers = dict(tiers) if tiers else {}
        # optional hard per-tier capacity across ALL tenants; a tier
        # absent here is uncapped (the physical client enforces its own
        # limit, e.g. a pool's block count)
        self.capacity_bytes: Dict[str, int] = dict(capacity_bytes or {})
        self.executor = executor or MigrationExecutor(self.tiers,
                                                      topology=topology)
        self.tenants: Dict[Namespace, Tenant] = {}
        # (tenant namespace, obj) -> {tier: bytes}
        self._res: Dict[Tuple[Namespace, str], Dict[str, int]] = {}
        # (tenant namespace, obj) -> "client" | "plan"
        self._origin: Dict[Tuple[Namespace, str], str] = {}
        # tenant namespace -> {tier: budget bytes} (arbiter-assigned)
        self._budget: Dict[Namespace, Dict[str, int]] = {}
        self.counters = LedgerCounters()

    # ------------------------------------------------------------------ #
    # tenants                                                            #
    # ------------------------------------------------------------------ #
    def register_tenant(self, name: TenantKey, weight: float = 1.0,
                        trace=None) -> Tenant:
        ns = Namespace.of(name).tenant_key()
        if ns in self.tenants:
            t = self.tenants[ns]
            if trace is not None:
                t.trace = trace
            return t
        t = Tenant(str(ns), weight, trace, ns=ns)
        self.tenants[ns] = t
        return t

    def attach_trace(self, tenant: TenantKey, trace) -> None:
        self.register_tenant(tenant).trace = trace

    def trace(self, tenant: TenantKey):
        t = self.tenants.get(Namespace.of(tenant).tenant_key())
        return t.trace if t is not None else None

    def tenant_info(self, tenant: TenantKey) -> Optional[Tenant]:
        """The Tenant record under any key form (None when absent)."""
        return self.tenants.get(Namespace.of(tenant).tenant_key())

    def _check_tenant(self, ns: Namespace) -> None:
        if ns not in self.tenants:
            raise LedgerError(f"unknown tenant {str(ns)!r}; "
                              f"register_tenant first")

    def tenants_matching(self, pattern: str) -> List[Namespace]:
        """Tenant namespaces matching a glob pattern, in sorted order
        (``"replica0/*"`` — one replica; ``"*/*"`` — the fleet)."""
        return sorted(ns for ns in self.tenants if ns.matches(pattern))

    def replicas(self) -> List[str]:
        """Replica components present among registered tenants."""
        return sorted({ns.replica for ns in self.tenants})

    # ------------------------------------------------------------------ #
    # object registration / accounting                                   #
    # ------------------------------------------------------------------ #
    def has(self, tenant: TenantKey, obj: str) -> bool:
        return (Namespace.of(tenant).tenant_key(), obj) in self._res

    def register(self, tenant: TenantKey, obj: str,
                 placement: Mapping[str, int],
                 origin: str = "client") -> None:
        """Register an object with its initial bytes-per-tier placement.

        Registration is allocation, not migration — no move is priced or
        gated (first touch put the bytes wherever the allocator chose).
        """
        ns = Namespace.of(tenant).tenant_key()
        self._check_tenant(ns)
        key = (ns, obj)
        if key in self._res:
            raise LedgerError(f"{ns.with_obj(obj)} already registered")
        self._res[key] = {t: int(b) for t, b in placement.items()
                          if int(b) > 0}
        self._origin[key] = origin
        self.counters.allocs += 1

    def retire(self, tenant: TenantKey, obj: str) -> int:
        """Drop an object entirely; returns the bytes released."""
        key = (Namespace.of(tenant).tenant_key(), obj)
        res = self._res.pop(key, None)
        self._origin.pop(key, None)
        if res is None:
            return 0
        self.counters.frees += 1
        return sum(res.values())

    def origin_of(self, tenant: TenantKey, obj: str) -> Optional[str]:
        return self._origin.get((Namespace.of(tenant).tenant_key(), obj))

    def record_alloc(self, tenant: TenantKey, obj: str, tier: str,
                     nbytes: int) -> None:
        """Grow an object on ``tier`` (client allocated more there)."""
        ns = Namespace.of(tenant).tenant_key()
        self._check_tenant(ns)
        if nbytes <= 0:
            return
        key = (ns, obj)
        if key not in self._res:
            self._res[key] = {}
            self._origin[key] = "client"
            self.counters.allocs += 1
        res = self._res[key]
        res[tier] = res.get(tier, 0) + int(nbytes)

    def record_free(self, tenant: TenantKey, obj: str, tier: str,
                    nbytes: int) -> None:
        """Shrink an object on ``tier`` (client released bytes there)."""
        ns = Namespace.of(tenant).tenant_key()
        key = (ns, obj)
        res = self._res.get(key)
        if res is None:
            return
        have = res.get(tier, 0)
        take = min(int(nbytes), have)
        if take >= have:
            res.pop(tier, None)
        else:
            res[tier] = have - take
        if not res:
            self.retire(ns, obj)

    def record_move(self, tenant: TenantKey, obj: str, src: str, dst: str,
                    nbytes: int) -> int:
        """Account a move that already physically happened.

        Clamped to the bytes the object actually has on ``src`` (the
        ledger never goes negative); returns the bytes recorded.
        """
        key = (Namespace.of(tenant).tenant_key(), obj)
        res = self._res.get(key)
        if res is None or nbytes <= 0 or src == dst:
            return 0
        moved = min(int(nbytes), res.get(src, 0))
        if moved <= 0:
            return 0
        res[src] -= moved
        if res[src] <= 0:
            res.pop(src, None)
        res[dst] = res.get(dst, 0) + moved
        self.counters.moves += 1
        self.counters.migrated_bytes += moved
        return moved

    def set_residency(self, tenant: TenantKey, obj: str,
                      placement: Mapping[str, int]) -> None:
        """Overwrite an object's bytes-per-tier (planner realizing a
        replan for a plan-origin object; clients use record_*)."""
        ns = Namespace.of(tenant).tenant_key()
        self._check_tenant(ns)
        key = (ns, obj)
        if key not in self._res:
            self.register(ns, obj, placement, origin="plan")
            return
        self._res[key] = {t: int(b) for t, b in placement.items()
                          if int(b) > 0}

    def resize(self, tenant: TenantKey, obj: str, new_total: int,
               grow_tier: Optional[str] = None) -> None:
        """Adjust an object's footprint to ``new_total`` bytes
        (plan-origin objects whose inventory drifted).  Growth lands on
        ``grow_tier`` (where a first-touch allocator puts fresh bytes —
        never silently inflating a budgeted fast tier); shrink removes
        proportionally across the current tiers."""
        key = (Namespace.of(tenant).tenant_key(), obj)
        res = self._res.get(key)
        if res is None:
            return
        old_total = sum(res.values())
        if old_total <= 0 or new_total == old_total:
            return
        if new_total > old_total:
            tier = grow_tier if grow_tier is not None \
                else max(res, key=res.get)
            res[tier] = res.get(tier, 0) + (new_total - old_total)
            return
        scaled = {t: int(b * new_total / old_total) for t, b in res.items()}
        slack = new_total - sum(scaled.values())
        if scaled and slack:
            # deterministic: remainder to the largest current holder
            scaled[max(scaled, key=scaled.get)] += slack
        self._res[key] = {t: b for t, b in scaled.items() if b > 0}

    # ------------------------------------------------------------------ #
    # queries                                                            #
    # ------------------------------------------------------------------ #
    def bytes_on(self, tier: str, tenant: Optional[TenantKey] = None) -> int:
        """Bytes resident on ``tier`` — one tenant, a glob pattern
        (``"replica0/*"``), or all tenants when omitted."""
        if tenant is None:
            return sum(res.get(tier, 0) for res in self._res.values())
        if isinstance(tenant, str) and is_pattern(tenant):
            return sum(res.get(tier, 0)
                       for (tn, _), res in self._res.items()
                       if tn.matches(tenant))
        ns = Namespace.of(tenant).tenant_key()
        return sum(res.get(tier, 0) for (tn, _), res in self._res.items()
                   if tn == ns)

    def aggregate(self, pattern: str = "*/*") -> Dict[str, int]:
        """Bytes-per-tier rolled up over every tenant matching a glob
        pattern — the fleet view (``"*/*"``), one replica
        (``"replica0/*"``), or one logical tenant across replicas
        (``"*/serving"``)."""
        out: Dict[str, int] = {}
        for (tn, _), res in self._res.items():
            if not tn.matches(pattern):
                continue
            for tier, b in res.items():
                out[tier] = out.get(tier, 0) + b
        return out

    def tenant_bytes(self, tenant: TenantKey) -> int:
        if isinstance(tenant, str) and is_pattern(tenant):
            return sum(sum(res.values())
                       for (tn, _), res in self._res.items()
                       if tn.matches(tenant))
        ns = Namespace.of(tenant).tenant_key()
        return sum(sum(res.values()) for (tn, _), res in self._res.items()
                   if tn == ns)

    def object_bytes(self, tenant: TenantKey, obj: str,
                     tier: Optional[str] = None) -> int:
        res = self._res.get((Namespace.of(tenant).tenant_key(), obj), {})
        return res.get(tier, 0) if tier is not None else sum(res.values())

    def objects(self, tenant: TenantKey) -> List[str]:
        ns = Namespace.of(tenant).tenant_key()
        return [o for (tn, o) in self._res if tn == ns]

    def nbytes_by_obj(self, tenant: TenantKey) -> Dict[str, int]:
        ns = Namespace.of(tenant).tenant_key()
        return {o: sum(res.values()) for (tn, o), res in self._res.items()
                if tn == ns}

    def placement(self, tenant: TenantKey, obj: str) -> Dict[str, int]:
        return dict(self._res.get(
            (Namespace.of(tenant).tenant_key(), obj), {}))

    def shares(self, tenant: TenantKey) -> Dict[str, List[Share]]:
        """Fractional per-object shares — the ``PlacementPlan.shares``
        view planners and executors consume."""
        ns = Namespace.of(tenant).tenant_key()
        out: Dict[str, List[Share]] = {}
        for (tn, obj), res in self._res.items():
            if tn != ns:
                continue
            total = sum(res.values())
            if total <= 0:
                continue
            out[obj] = [(t, b / total) for t, b in sorted(res.items())]
        return out

    def tier_occupancy(self, tier: str) -> Dict[str, int]:
        """Per-tenant bytes on one tier (the arbiter's realized view).

        Keys are the short display form (``"a"``, ``"replica0/serving"``).
        """
        out: Dict[str, int] = {str(t): 0 for t in self.tenants}
        for (tn, _), res in self._res.items():
            key = str(tn)
            out[key] = out.get(key, 0) + res.get(tier, 0)
        return out

    # ------------------------------------------------------------------ #
    # budgets & admission                                                #
    # ------------------------------------------------------------------ #
    def set_budget(self, tenant: TenantKey, tier: str, nbytes: int) -> None:
        ns = Namespace.of(tenant).tenant_key()
        self._check_tenant(ns)
        self._budget.setdefault(ns, {})[tier] = max(int(nbytes), 0)

    def budget(self, tenant: TenantKey, tier: str) -> Optional[int]:
        return self._budget.get(
            Namespace.of(tenant).tenant_key(), {}).get(tier)

    def headroom(self, tenant: TenantKey, tier: str) -> int:
        """Bytes ``tenant`` may still place on ``tier`` before its
        budget or the tier's capacity binds (can be negative after an
        arbiter shrinks a budget below current usage)."""
        ns = Namespace.of(tenant).tenant_key()
        room = UNBOUNDED
        b = self.budget(ns, tier)
        if b is not None:
            room = min(room, b - self.bytes_on(tier, ns))
        cap = self.capacity_bytes.get(tier)
        if cap is not None:
            room = min(room, cap - self.bytes_on(tier))
        return room

    def can_place(self, tenant: TenantKey, tier: str, nbytes: int) -> bool:
        return self.headroom(tenant, tier) >= nbytes

    def over_budget(self, tenant: TenantKey, tier: str) -> int:
        """Bytes above the tenant's budget on ``tier`` (0 if within)."""
        ns = Namespace.of(tenant).tenant_key()
        b = self.budget(ns, tier)
        if b is None:
            return 0
        return max(self.bytes_on(tier, ns) - b, 0)

    def over_budget_tenants(self, tier: str) -> Dict[str, int]:
        """Every tenant currently above its budget on ``tier`` — the
        view budget-compliance enforcers (scheduler preemption, state
        demotion) poll after an arbiter shrink."""
        out: Dict[str, int] = {}
        for t in self.tenants:
            over = self.over_budget(t, tier)
            if over > 0:
                out[str(t)] = over
        return out

    # ------------------------------------------------------------------ #
    # priced, gated moves                                                #
    # ------------------------------------------------------------------ #
    def move(self, tenant: TenantKey, obj: str, src: str, dst: str,
             nbytes: int, move_fn=None) -> Tuple[int, float]:
        """Move bytes of one object between tiers through the shared
        executor: gate on ``can_place``, price over the topology, apply
        through ``move_fn`` (physical) or account directly, and record.

        Returns (bytes moved, priced seconds).
        """
        ns = Namespace.of(tenant).tenant_key()
        self._check_tenant(ns)
        want = min(int(nbytes), self.object_bytes(ns, obj, src))
        grant = min(want, max(self.headroom(ns, dst), 0))
        if grant <= 0:
            self.counters.denied_moves += 1
            return 0, 0.0
        mv = BlockMove(obj, src, dst, grant)
        cost = self.executor.cost_s(PlacementDelta([mv]))
        # a block-granular physical client may round the grant up to
        # one whole block; report what it actually moved (its
        # record_move calls are the residency truth), never a clamp
        done = grant if move_fn is None else max(int(move_fn(
            obj, src, dst, grant)), 0)
        if done <= 0:
            self.counters.denied_moves += 1
            return 0, 0.0
        if move_fn is None:
            # no physical client: the ledger itself is the record
            self.record_move(ns, obj, src, dst, done)
        return done, cost

    # ------------------------------------------------------------------ #
    def summary(self) -> Dict[str, float]:
        tiers = sorted({t for res in self._res.values() for t in res})
        out: Dict[str, float] = {
            "tenants": float(len(self.tenants)),
            "objects": float(len(self._res)),
            "moves": float(self.counters.moves),
            "migrated_bytes": float(self.counters.migrated_bytes),
            "denied_moves": float(self.counters.denied_moves),
        }
        for t in tiers:
            out[f"bytes_on.{t}"] = float(self.bytes_on(t))
        return out

    def publish(self, registry, prefix: str = "ledger") -> int:
        """Publish the summary plus per-tenant residency and budgets
        into a repro.obs.MetricsRegistry as gauges; returns the number
        of gauges set.  Gauge names use the short tenant form, so
        cluster tenants publish under ``<prefix>.<replica>/<tenant>.*``
        while single-host names are unchanged."""
        n = registry.set_gauges(self.summary(), prefix=prefix)
        tiers = sorted({t for res in self._res.values() for t in res})
        for ns in sorted(self.tenants):
            tenant = str(ns)
            for tier in tiers:
                registry.gauge(
                    f"{prefix}.{tenant}.bytes_on.{tier}").set(
                        float(self.bytes_on(tier, ns)))
                n += 1
            for tier, b in sorted(self._budget.get(ns, {}).items()):
                registry.gauge(
                    f"{prefix}.{tenant}.budget.{tier}").set(float(b))
                n += 1
        return n
