"""TierBudgetArbiter: fair-share splitting of the fast tier across tenants.

The paper's central system question — how a fixed fast-tier (DRAM)
budget plus CXL expansion should be shared — becomes, with multiple
workloads on one pool, an arbitration problem: "Dissecting CXL Memory
Performance at Scale" shows contention for the shared fast tier
dominates per-object placement effects.  The arbiter reads each
tenant's *measured* demand from its AccessTrace namespace in the
``ResidencyLedger`` and splits the fast-tier capacity under a pluggable
objective:

  * ``fair_share``   — max-min fairness: equal entitlements, capped by
    demand, with unused capacity water-filled to still-hungry tenants
    (no tenant can raise its grant without lowering a poorer one's);
  * ``throughput``   — aggregate-throughput: fast bytes flow to the
    tenants with the highest traffic intensity (bytes/step per resident
    byte — the marginal step-time saved per fast byte is proportional
    to it), filling each tenant's hot set in intensity order;
  * ``priority``     — weighted fair share: entitlements proportional
    to each tenant's ``Tenant.weight``.

Budgets land in the ledger (``set_budget``), where every placement path
— pool promotions, replanner deltas, state-store re-places — consults
them through ``can_place``.

**Predictive arbitration** (``predictive=True``): measured demand reacts
one epoch *after* a phase shift — a recurring decode burst runs its
first epoch under the previous lull's budget (the burst-entry lag the
multi-tenant bench exposes).  The predictive arbiter runs a
``PhaseDetector`` over each tenant's trace namespace and keeps a small
**phase -> demand table** keyed by recurrence signature: each rebalance
it (a) EMA-learns the demand measured under the *current* signature and
(b) grants from the demand remembered for the signatures *predicted*
for the next two epochs (element-wise max — budget arrives one epoch
early and is released the epoch a phase actually ends).  Unknown
signatures fall back to the reactive measured demand, and entries whose
signature stops recurring are TTL-evicted.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, List, Mapping, Optional

from ..cluster.namespace import Namespace
from .ledger import ResidencyLedger

OBJECTIVES = ("fair_share", "throughput", "priority")


@dataclasses.dataclass
class TenantDemand:
    """One tenant's measured appetite for the fast tier."""

    tenant: str
    resident_bytes: int        # total footprint in the ledger
    hot_bytes: int             # bytes with observed traffic (fast-worthy)
    bytes_per_step: float      # traffic rate over the demand window
    weight: float = 1.0
    source: str = "measured"   # measured | predicted

    @property
    def intensity(self) -> float:
        """Traffic per resident byte — the marginal utility of giving
        this tenant one more fast byte."""
        return self.bytes_per_step / max(self.hot_bytes, 1)


@dataclasses.dataclass
class PhaseDemand:
    """Remembered demand for one recurrence signature."""

    hot_bytes: float
    bytes_per_step: float
    last_seen_epoch: int
    hits: int = 1


class PhaseDemandTable:
    """signature -> EMA-smoothed demand, with TTL + size-bounded eviction.

    The table is deliberately small: it remembers *recurring* phases
    (burst/lull/steady), not every epoch — ``max_entries`` bounds it and
    ``ttl_epochs`` retires signatures that stopped recurring so a dead
    phase cannot keep pre-claiming fast capacity.
    """

    def __init__(self, ttl_epochs: int = 256, max_entries: int = 32,
                 alpha: float = 0.5):
        self.ttl_epochs = int(ttl_epochs)
        self.max_entries = int(max_entries)
        self.alpha = float(alpha)
        self.entries: Dict[Hashable, PhaseDemand] = {}
        self.evictions = 0

    def observe(self, sig: Hashable, hot_bytes: float,
                bytes_per_step: float, epoch: int) -> None:
        e = self.entries.get(sig)
        if e is None:
            self.entries[sig] = PhaseDemand(float(hot_bytes),
                                            float(bytes_per_step), epoch)
        else:
            a = self.alpha
            e.hot_bytes += a * (hot_bytes - e.hot_bytes)
            e.bytes_per_step += a * (bytes_per_step - e.bytes_per_step)
            e.last_seen_epoch = epoch
            e.hits += 1

    def lookup(self, sig: Hashable, epoch: int) -> Optional[PhaseDemand]:
        e = self.entries.get(sig)
        if e is None or epoch - e.last_seen_epoch > self.ttl_epochs:
            return None
        return e

    def evict_stale(self, epoch: int) -> None:
        stale = {s for s, e in self.entries.items()
                 if epoch - e.last_seen_epoch > self.ttl_epochs}
        live = [s for s in self.entries if s not in stale]
        if len(live) > self.max_entries:
            live.sort(key=lambda s: self.entries[s].last_seen_epoch)
            stale.update(live[: len(live) - self.max_entries])
        for s in stale:
            del self.entries[s]
            self.evictions += 1


@dataclasses.dataclass
class ArbiterDecision:
    """One rebalance: measured demands and the budgets that resulted."""

    epoch: int
    objective: str
    budgets: Dict[str, int]
    demands: List[TenantDemand]

    def budget_of(self, tenant: str) -> int:
        return self.budgets.get(tenant, 0)


class TierBudgetArbiter:
    """Splits one tier's capacity across the ledger's tenants."""

    def __init__(self, ledger: ResidencyLedger, fast_tier: str,
                 capacity_bytes: Optional[int] = None,
                 objective: str = "fair_share",
                 window_epochs: Optional[int] = 4,
                 floor_bytes: int = 0,
                 hot_threshold: float = 0.05,
                 predictive: bool = False,
                 signature_ttl_epochs: int = 256,
                 tracer=None, audit=None,
                 blame=None, blame_debit: float = 0.5,
                 replica_capacity: Optional[Mapping[str, int]] = None):
        if objective not in OBJECTIVES:
            raise ValueError(f"unknown objective {objective!r}; "
                             f"choose from {OBJECTIVES}")
        self.ledger = ledger
        self.fast_tier = fast_tier
        if capacity_bytes is None:
            capacity_bytes = ledger.capacity_bytes.get(fast_tier)
        if capacity_bytes is None:
            raise ValueError(
                f"no capacity for tier {fast_tier!r}: pass "
                f"capacity_bytes or set it on the ledger")
        self.capacity_bytes = int(capacity_bytes)
        self.objective = objective
        self.window_epochs = window_epochs
        # every tenant keeps at least this much fast headroom even when
        # its trace shows no demand (cold-start protection)
        self.floor_bytes = int(floor_bytes)
        # an object is fast-worthy only while it is access-intensive:
        # per-epoch traffic at least this fraction of its footprint
        # (the paper's §V-B selection criterion, applied per tenant) —
        # a drained serving engine's cold KV stops counting as demand
        self.hot_threshold = float(hot_threshold)
        self.decisions: List[ArbiterDecision] = []
        # predictive mode: per-tenant phase detectors + demand tables
        self.predictive = bool(predictive)
        self.signature_ttl_epochs = int(signature_ttl_epochs)
        self._detectors: Dict[str, object] = {}
        self._tables: Dict[str, PhaseDemandTable] = {}
        self.predicted_grants = 0     # demands served from the table
        self.tracer = tracer          # optional repro.obs.TraceRecorder
        self.audit = audit            # optional obs.PredictionLedger
        # QoS blame coupling (optional obs.BlameLedger): a tenant the
        # blame plane names as a noisy neighbor gets up to
        # ``blame_debit`` of its above-floor grant debited, re-water-
        # filled to the unblamed still-hungry tenants — tail excursions
        # it caused cost it fast capacity, not just reputation
        self.blame = blame
        self.blame_debit = float(blame_debit)
        self.blame_debited_bytes = 0
        # multi-host plane: each replica's *physical* fast-tier capacity
        # (keyed by replica name).  The split water-fills across replica
        # groups first — a tenant on host A can never be granted host
        # B's DRAM — then per-tenant within each group's grant.  With
        # every tenant in the "default" replica and no capacities given
        # this degenerates exactly to the single-pool split.
        self.replica_capacity: Dict[str, int] = \
            {r: int(c) for r, c in (replica_capacity or {}).items()}
        # last next-phase signature filed with the audit, per tenant —
        # joined (hit/miss) when the next rebalance sees the actual one
        self._predicted_sigs: Dict[str, Hashable] = {}

    # ------------------------------------------------------------------ #
    # demand measurement                                                 #
    # ------------------------------------------------------------------ #
    def demand(self, tenant: str,
               window: Optional[int] = None) -> TenantDemand:
        """Read one tenant's demand from its trace namespace: hot bytes
        are the footprints of objects with traffic in the window; with
        no trace attached the whole residency counts as hot."""
        ns = Namespace.of(tenant).tenant_key()
        name = str(ns)
        info = self.ledger.tenants[ns]
        nbytes = self.ledger.nbytes_by_obj(ns)
        resident = sum(nbytes.values())
        trace = info.trace
        if trace is None:
            return TenantDemand(name, resident, resident, float(resident),
                                info.weight)
        traffic = trace.object_traffic(
            self.window_epochs if window is None else window)
        hot = 0
        rate = 0.0
        for obj, t in traffic.items():
            if t.total_bytes <= 0:
                continue
            per_epoch = t.total_bytes / max(t.epochs, 1)
            rate += per_epoch
            size = nbytes.get(obj, 0)
            if size > 0 and per_epoch >= self.hot_threshold * size:
                hot += size
        return TenantDemand(name, resident, min(hot, resident), rate,
                            info.weight)

    def demands(self, epoch: int = 0) -> List[TenantDemand]:
        # sorted Namespace order groups each replica's tenants together;
        # downstream state (detectors, tables, audit, budgets) keys on
        # the short display string
        names = [str(ns) for ns in sorted(self.ledger.tenants)]
        if not self.predictive:
            return [self.demand(t) for t in names]
        return [self._predicted_demand(t, epoch) for t in names]

    # ------------------------------------------------------------------ #
    # prediction                                                         #
    # ------------------------------------------------------------------ #
    def detector(self, tenant: str):
        """The tenant's PhaseDetector (created lazily over its trace;
        None when the tenant has no trace namespace to detect on)."""
        det = self._detectors.get(tenant)
        if det is None:
            trace = self.ledger.trace(tenant)
            if trace is None:
                return None
            from ..telemetry.phases import PhaseDetector
            det = PhaseDetector(
                trace, signature_ttl_epochs=self.signature_ttl_epochs)
            self._detectors[tenant] = det
        return det

    def expected_signature(self, tenant: str, ahead: int = 1):
        """The tenant's predicted recurrence signature ``ahead`` epochs
        past the last completed one (None without a trace/history)."""
        det = self.detector(tenant)
        return det.expected_signature(ahead) if det is not None else None

    def table(self, tenant: str) -> PhaseDemandTable:
        t = self._tables.get(tenant)
        if t is None:
            t = PhaseDemandTable(ttl_epochs=self.signature_ttl_epochs)
            self._tables[tenant] = t
        return t

    def _predicted_demand(self, tenant: str, epoch: int) -> TenantDemand:
        """Demand for the *upcoming* epochs: learn the measured demand
        under the current signature, then grant from the table entries
        of the signatures predicted one and two epochs ahead (max — the
        two-epoch horizon is what lets a pre-staged promotion run the
        epoch *before* a burst).  Reactive fallback throughout."""
        det = self.detector(tenant)
        if det is None:
            return self.demand(tenant)
        det.update()
        sig = det.signature
        # phase-prediction audit: the previous rebalance predicted the
        # signature now live — join it as a hit (1.0) or miss (0.0)
        if self.audit is not None:
            prev_sig = self._predicted_sigs.pop(tenant, None)
            if prev_sig is not None and self.audit.has_pending(
                    "arbiter.phase", tenant):
                self.audit.realize("arbiter.phase", tenant,
                                   1.0 if sig == prev_sig else 0.0)
        # attribute the measurement to the signature's own run so a
        # long window cannot smear the previous phase into this one
        window = self.window_epochs
        if window is not None and det.epochs_in_signature > 0:
            window = min(window, det.epochs_in_signature)
        measured = self.demand(tenant, window=window)
        # demand audit: the grant predicted last rebalance meets the
        # demand the ledger/trace actually observed since
        if self.audit is not None and self.audit.has_pending(
                "arbiter.demand", tenant):
            self.audit.realize("arbiter.demand", tenant,
                               float(measured.hot_bytes))
        table = self.table(tenant)
        if sig is not None:
            table.observe(sig, measured.hot_bytes,
                          measured.bytes_per_step, epoch)
        table.evict_stale(epoch)
        hits = []
        for ahead in (1, 2):
            nxt = det.expected_signature(ahead)
            if ahead == 1 and self.audit is not None and nxt is not None:
                # file the next-phase prediction (value 1.0 = "will
                # match"); joined hit/miss above next rebalance, so the
                # model's accuracy ratio is its live hit rate
                self.audit.predict("arbiter.phase", tenant, 1.0,
                                   epoch=epoch, signature=str(nxt))
                self._predicted_sigs[tenant] = nxt
            if nxt is None:
                continue
            hit = table.lookup(nxt, epoch)
            if hit is not None:
                hits.append(hit)
        if not hits:
            return measured
        hot = max(h.hot_bytes for h in hits)
        rate = max(h.bytes_per_step for h in hits)
        if hot == measured.hot_bytes and rate == measured.bytes_per_step:
            return measured
        self.predicted_grants += 1
        granted = min(int(hot), measured.resident_bytes)
        if self.audit is not None:
            self.audit.predict("arbiter.demand", tenant, float(granted),
                               epoch=epoch)
        return TenantDemand(tenant, measured.resident_bytes, granted,
                            rate, measured.weight, source="predicted")

    # ------------------------------------------------------------------ #
    # split objectives                                                   #
    # ------------------------------------------------------------------ #
    @staticmethod
    def _water_fill(asks: Mapping[str, int], weights: Mapping[str, float],
                    capacity: int) -> Dict[str, int]:
        """Weighted max-min: grant each claimant up to its ask,
        entitlements proportional to weight, redistributing capacity
        freed by satisfied claimants until none is left."""
        grant = {t: 0 for t in asks}
        live = {t for t, a in asks.items() if a > 0}
        left = capacity
        while live and left > 0:
            wsum = sum(weights[t] for t in live)
            step = {t: int(left * weights[t] / wsum) for t in live}
            # integer slack goes to the heaviest claimant
            slack = left - sum(step.values())
            if slack:
                step[max(live, key=lambda t: weights[t])] += slack
            progressed = False
            for t in sorted(live):
                take = min(step[t], asks[t] - grant[t])
                if take > 0:
                    grant[t] += take
                    left -= take
                    progressed = True
                if grant[t] >= asks[t]:
                    live.discard(t)
            if not progressed:
                break
        return grant

    def _split_group(self, demands: List[TenantDemand],
                     asks: Mapping[str, int],
                     capacity: int) -> Dict[str, int]:
        """Objective-specific per-tenant split within one capacity pool."""
        if self.objective == "fair_share":
            w = {d.tenant: 1.0 for d in demands}
            return self._water_fill({d.tenant: asks[d.tenant]
                                     for d in demands}, w, capacity)
        if self.objective == "priority":
            w = {d.tenant: max(d.weight, 1e-9) for d in demands}
            return self._water_fill({d.tenant: asks[d.tenant]
                                     for d in demands}, w, capacity)
        # throughput: fill hot sets in traffic-intensity order
        grant = {d.tenant: 0 for d in demands}
        left = capacity
        for d in sorted(demands, key=lambda d: -d.intensity):
            take = min(asks[d.tenant], left)
            grant[d.tenant] = take
            left -= take
        return grant

    def split(self, demands: List[TenantDemand]) -> Dict[str, int]:
        cap = self.capacity_bytes
        floors = {d.tenant: min(self.floor_bytes, d.resident_bytes)
                  for d in demands}
        cap_after_floor = max(cap - sum(floors.values()), 0)
        asks = {d.tenant: max(d.hot_bytes - floors[d.tenant], 0)
                for d in demands}
        # group tenants by replica: a replica's tenants share that
        # host's physical fast tier, so the split is hierarchical —
        # water-fill capacity across replica groups first (each capped
        # by its physical capacity), then the objective split within
        # each group's grant
        groups: Dict[str, List[TenantDemand]] = {}
        for d in demands:
            groups.setdefault(Namespace.of(d.tenant).replica,
                              []).append(d)
        if len(groups) <= 1 and not self.replica_capacity:
            # single pool (every tenant in one replica, no physical
            # per-host caps): identical to the pre-cluster split
            grant = self._split_group(demands, asks, cap_after_floor)
        else:
            group_ask: Dict[str, int] = {}
            group_cap: Dict[str, int] = {}
            for r, ds in groups.items():
                rc = self.replica_capacity.get(r)
                rc_after_floor = cap_after_floor if rc is None else \
                    max(int(rc) - sum(floors[d.tenant] for d in ds), 0)
                group_cap[r] = rc_after_floor
                group_ask[r] = min(sum(asks[d.tenant] for d in ds),
                                   rc_after_floor)
            group_grant = self._water_fill(
                group_ask, {r: 1.0 for r in groups}, cap_after_floor)
            grant = {}
            for r, ds in sorted(groups.items()):
                grant.update(self._split_group(
                    ds, asks, min(group_grant[r], group_cap[r])))
        # capacity beyond measured demand stays free: handing it out by
        # footprint would just re-enable hoarding by idle tenants — the
        # next rebalance grants it the moment demand shows up
        if self.blame is not None and self.blame_debit > 0.0:
            grant = self._apply_blame_debit(grant, asks)
        return {t: floors[t] + g for t, g in grant.items()}

    def _apply_blame_debit(self, grant: Dict[str, int],
                           asks: Mapping[str, int]) -> Dict[str, int]:
        """Debit high-blame tenants' above-floor grants by their noisy-
        neighbor score, re-water-filling the freed capacity to unblamed
        tenants whose asks were not yet satisfied."""
        grant = dict(grant)
        freed = 0
        scores = {t: self.blame.noisy_neighbor_score(t) for t in grant}
        for t, g in grant.items():
            cut = int(g * min(self.blame_debit * scores[t], 1.0))
            if cut > 0:
                grant[t] = g - cut
                freed += cut
        if freed > 0:
            self.blame_debited_bytes += freed
            residual = {t: max(asks.get(t, 0) - grant[t], 0)
                        for t in grant if scores[t] <= 0.0}
            if residual:
                refill = self._water_fill(
                    residual, {t: 1.0 for t in residual}, freed)
                for t, extra in refill.items():
                    grant[t] += extra
        return grant

    # ------------------------------------------------------------------ #
    def rebalance(self, epoch: int = 0) -> ArbiterDecision:
        """Measure (or predict) demand, split, and push budgets into
        the ledger."""
        demands = self.demands(epoch)
        budgets = self.split(demands)
        for tenant, b in budgets.items():
            self.ledger.set_budget(tenant, self.fast_tier, b)
        d = ArbiterDecision(epoch, self.objective, budgets, demands)
        self.decisions.append(d)
        if self.tracer is not None:
            by_tenant = {dm.tenant: dm for dm in demands}
            for tenant, b in sorted(budgets.items()):
                dm = by_tenant.get(tenant)
                self.tracer.event(
                    "arbiter.grant", cat="arbiter", tid=tenant,
                    epoch=epoch, tenant=tenant, budget_bytes=b,
                    objective=self.objective,
                    hot_bytes=dm.hot_bytes if dm else 0,
                    resident_bytes=dm.resident_bytes if dm else 0,
                    bytes_per_step=dm.bytes_per_step if dm else 0.0,
                    source=dm.source if dm else "measured",
                    blame_score=(self.blame.noisy_neighbor_score(tenant)
                                 if self.blame is not None else 0.0))
        return d
