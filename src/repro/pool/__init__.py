"""repro.pool: unified multi-tenant residency ledger + tier arbitration.

The repo's answer to the paper's central system question — how a fixed
fast-tier budget plus CXL-class expansion is shared across competing
workloads:

- ledger:      ``ResidencyLedger``, the single source of truth for
               bytes-per-tier-per-tenant; TieredArray state, the paged
               KV pool, and the adaptive replanner all read/write tier
               occupancy here, and per-tenant budgets gate placement
- arbiter:     ``TierBudgetArbiter`` splits the fast tier across tenant
               namespaces from measured per-tenant demand (fair-share /
               aggregate-throughput / priority-weighted objectives)
- state_store: ``TieredStateStore`` holds pytrees (fp32 optimizer
               state) as TieredArrays and executes replanner deltas as
               real block re-placements recorded in the ledger
"""
from .ledger import (LedgerCounters, LedgerError, ResidencyLedger, Tenant,
                     UNBOUNDED)
from .arbiter import (OBJECTIVES, ArbiterDecision, TenantDemand,
                      TierBudgetArbiter)
from .state_store import TieredStateStore

__all__ = [
    "LedgerCounters", "LedgerError", "ResidencyLedger", "Tenant",
    "UNBOUNDED",
    "OBJECTIVES", "ArbiterDecision", "TenantDemand", "TierBudgetArbiter",
    "TieredStateStore",
]
