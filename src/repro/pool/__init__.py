"""repro.pool: unified multi-tenant residency ledger + tier arbitration.

The repo's answer to the paper's central system question — how a fixed
fast-tier budget plus CXL-class expansion is shared across competing
workloads:

- ledger:      ``ResidencyLedger``, the single source of truth for
               bytes-per-tier-per-tenant; TieredArray state, the paged
               KV pool, and the adaptive replanner all read/write tier
               occupancy here, and per-tenant budgets gate placement
- arbiter:     ``TierBudgetArbiter`` splits the fast tier across tenant
               namespaces from measured per-tenant demand (fair-share /
               aggregate-throughput / priority-weighted objectives)
- state_store: ``TieredStateStore`` holds pytrees (fp32 optimizer
               state) as TieredArrays and executes replanner deltas as
               real block re-placements recorded in the ledger
- movesched:   ``MoveScheduler`` batches every tenant's placement
               deltas per round, coalesces them, and orders them
               priority-weighted over the bottleneck links their
               topology paths share before execution
"""
from .arbiter import (ArbiterDecision, OBJECTIVES, PhaseDemand,
                      PhaseDemandTable, TenantDemand, TierBudgetArbiter)
from .ledger import (LedgerCounters, LedgerError, ResidencyLedger, Tenant,
                     UNBOUNDED)
from .movesched import MoveRound, MoveScheduler, ScheduledMove
from .state_store import TieredStateStore

__all__ = [
    "LedgerCounters", "LedgerError", "ResidencyLedger", "Tenant",
    "UNBOUNDED",
    "OBJECTIVES", "ArbiterDecision", "PhaseDemand", "PhaseDemandTable",
    "TenantDemand", "TierBudgetArbiter",
    "MoveRound", "MoveScheduler", "ScheduledMove",
    "TieredStateStore",
]
