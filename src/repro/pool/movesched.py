"""MoveScheduler: cross-tenant migration batching over shared links.

"CXL-Interference" shows the failure mode this module closes: tenants
that execute their placement deltas *independently* contend on the
bottleneck UPI/CXL links their moves share — the ``MigrationExecutor``
already prices that serialization per delta, but nothing orders moves
*across* tenants, so every tenant pays as if it owned the link.  The
scheduler collects all tenants' ``PlacementDelta``s for one round and:

  1. **coalesces** — within each submitted delta, same-direction
     moves of one object merge, and opposing moves (A->B queued
     together with B->A) net out before any byte is copied (netting
     is per-submission: objects are tenant-namespaced, and a
     replanner defers at most one apply per round, so cross-submission
     opposition does not arise);
  2. **groups by bottleneck resource** — each move's occupied
     resources (endpoint tiers + every link on its ``TopologyGraph``
     path) come from ``MigrationExecutor.move_resource_times``;
  3. **orders** — priority-weighted (the ledger's tenant weights),
     with capacity-*freeing* moves (demotions out of the contended
     fast tier) ahead of promotions at equal priority so a physical
     client's promote is not denied for space a queued demote is
     about to release;
  4. **schedules** — fluid list schedule: in order, each move's
     traffic queues behind the earlier moves' traffic on every
     resource it crosses, so moves sharing a bottleneck serialize
     while moves on disjoint resources overlap.  The round's
     ``makespan_s`` is what the batch actually costs; its
     ``independent_s`` is what the same moves cost executed
     per-tenant with no coordination (the sum the bench compares
     against);
  5. **executes** — in scheduled order through each submission's
     ``move_fn`` (the tenant's physical client), crediting per-tenant
     ``MigrationStats`` and invoking each submission's completion
     callback with the realized ``(move, done_bytes)`` list so a
     deferring ``AdaptiveReplanner`` adopts the residency that really
     resulted;
  6. **preempts** — a submission whose ``submit`` lands *mid-round*
     (reentrantly, from a client's ``move_fn``) with strictly higher
     priority than the move about to execute interrupts the round:
     its moves are priced and spliced ahead of everything remaining,
     and the interrupted tenant's copy resumes afterwards.  Long
     low-priority copies yield at block granularity — per queued
     ``BlockMove``, or finer when the submitter opted into
     ``chunk_bytes`` splitting (declaring its ``move_fn`` safe to
     call with partial byte counts).  ``movesched.preemptions``
     counts the interruptions; each emits a ``movesched.preempt``
     trace event.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

from ..core.migration import (BlockMove, MigrationExecutor, MigrationStats,
                              PlacementDelta)
from .ledger import ResidencyLedger


@dataclasses.dataclass
class ScheduledMove:
    """One move with its placement in the round's schedule."""

    tenant: str
    move: BlockMove
    priority: float
    resources: List[object]
    cost_s: float                  # priced alone (bottleneck + overhead)
    start_s: float = 0.0
    finish_s: float = 0.0
    done_bytes: int = 0
    orig_move: Optional[BlockMove] = None  # pre-chunking move (if split)


@dataclasses.dataclass
class MoveRound:
    """One flush: the ordered schedule and its makespan accounting."""

    epoch: int
    moves: List[ScheduledMove]
    makespan_s: float              # batched, link-aware schedule
    independent_s: float           # per-tenant uncoordinated execution
    coalesced_bytes: int           # bytes netted away before copying

    @property
    def saved_s(self) -> float:
        return max(self.independent_s - self.makespan_s, 0.0)

    def tenant_finish_s(self, tenant: str) -> float:
        """When the tenant's last move completes (0.0 if it had none)."""
        return max((m.finish_s for m in self.moves if m.tenant == tenant),
                   default=0.0)

    def moved_bytes(self, tenant: Optional[str] = None) -> int:
        return sum(m.done_bytes for m in self.moves
                   if tenant is None or m.tenant == tenant)


@dataclasses.dataclass
class _Submission:
    tenant: str
    delta: PlacementDelta
    move_fn: Optional[Callable[[str, str, str, int], int]]
    priority: float
    on_done: Optional[Callable[[List[Tuple[BlockMove, int]]], None]]
    stats: Optional[MigrationStats]
    order: int                     # submission sequence (stable ties)
    chunk_bytes: Optional[int] = None  # split long copies (opt-in)


class MoveScheduler:
    """Collects tenants' deltas per round and executes them as one
    ordered, link-aware batch through the shared executor."""

    def __init__(self, executor: MigrationExecutor,
                 ledger: Optional[ResidencyLedger] = None,
                 tracer=None):
        self.executor = executor
        self.ledger = ledger
        self.tracer = tracer           # optional repro.obs.TraceRecorder
        self.audit = None              # optional obs.PredictionLedger
        self.calibrator = None         # optional obs.CostModelCalibrator
        self.rounds: List[MoveRound] = []
        self.preemptions = 0           # mid-round higher-priority splices
        self._pending: List[_Submission] = []
        self._rounds_audited = 0
        self._order_seq = 0

    # ------------------------------------------------------------------ #
    @property
    def pending_moves(self) -> int:
        return sum(len(s.delta.moves) for s in self._pending)

    @property
    def has_pending(self) -> bool:
        """Any submission queued for the next flush (even move-less
        ones, whose ``on_done`` must still fire)."""
        return bool(self._pending)

    def submit(self, tenant: str, delta: PlacementDelta,
               move_fn: Optional[Callable] = None,
               priority: Optional[float] = None,
               on_done: Optional[Callable] = None,
               stats: Optional[MigrationStats] = None,
               chunk_bytes: Optional[int] = None) -> None:
        """Queue one tenant's delta for the next ``flush``.

        ``priority`` defaults to the tenant's ledger weight (1.0 when
        neither is known); ``move_fn`` is the tenant's physical client
        hook (None = accounting only); ``on_done`` receives the
        realized ``[(BlockMove, done_bytes)]`` list after execution.
        ``chunk_bytes`` opts this tenant's long copies into sub-block
        splitting — extra preemption points mid-copy — and asserts its
        ``move_fn`` accepts partial byte counts for one object.

        Submitting from inside a ``move_fn`` while a round executes is
        legal: a strictly-higher-priority delta preempts the round
        (see ``flush``), anything else waits for the next one.
        """
        if priority is None:
            info = self.ledger.tenant_info(tenant) \
                if self.ledger is not None else None
            priority = info.weight if info is not None else 1.0
        self._pending.append(_Submission(
            tenant, delta, move_fn, float(priority), on_done, stats,
            self._order_seq,
            int(chunk_bytes) if chunk_bytes else None))
        self._order_seq += 1

    # ------------------------------------------------------------------ #
    @staticmethod
    def _coalesce(delta: PlacementDelta) -> Tuple[List[BlockMove], int]:
        """Merge same-direction moves and net opposing ones within one
        submission; returns (moves, bytes netted away)."""
        directed: Dict[Tuple[str, str, str], int] = {}
        for m in delta.moves:
            if m.nbytes <= 0 or m.src == m.dst:
                continue
            key = (m.obj, m.src, m.dst)
            directed[key] = directed.get(key, 0) + m.nbytes
        out: List[BlockMove] = []
        netted = 0
        seen = set()
        for key in sorted(directed):
            if key in seen:
                continue
            obj, src, dst = key
            rkey = (obj, dst, src)
            seen.add(key)
            seen.add(rkey)
            fwd, rev = directed[key], directed.get(rkey, 0)
            netted += 2 * min(fwd, rev)
            if fwd > rev:
                out.append(BlockMove(obj, src, dst, fwd - rev))
            elif rev > fwd:
                out.append(BlockMove(obj, dst, src, rev - fwd))
        return out, netted

    def _is_demotion(self, m: BlockMove, rank: Dict[str, int]) -> bool:
        return rank.get(m.dst, 0) > rank.get(m.src, 0)

    def _build_sms(self, sub: _Submission) -> Tuple[List[ScheduledMove],
                                                    int]:
        """Coalesce one submission and price its scheduled moves,
        splitting long copies into ``chunk_bytes`` pieces when the
        tenant opted in (each piece is a preemption point)."""
        ex = self.executor
        moves, netted = self._coalesce(sub.delta)
        sms: List[ScheduledMove] = []
        for m in moves:
            pieces = [m]
            if sub.chunk_bytes and m.nbytes > sub.chunk_bytes:
                pieces = []
                left = m.nbytes
                while left > 0:
                    nb = min(left, sub.chunk_bytes)
                    pieces.append(BlockMove(m.obj, m.src, m.dst, nb))
                    left -= nb
            for p in pieces:
                sms.append(ScheduledMove(sub.tenant, p, sub.priority,
                                         ex.move_resources(p),
                                         ex.move_cost_s(p), orig_move=m))
        return sms, netted

    def _fluid(self, scheduled: List[ScheduledMove]) -> float:
        """Fluid list schedule: each move's traffic queues behind all
        earlier-scheduled traffic on every resource it occupies."""
        busy: Dict[object, float] = {}
        makespan = 0.0
        for sm in scheduled:
            res_time, overhead = self.executor.move_resource_times(sm.move)
            start = max((busy.get(r, 0.0) for r in res_time), default=0.0)
            finish = start + overhead
            for r, t in res_time.items():
                busy[r] = max(busy.get(r, 0.0), start) + t
                finish = max(finish, busy[r] + overhead)
            sm.start_s = start
            sm.finish_s = finish
            makespan = max(makespan, finish)
        return makespan

    def flush(self, epoch: int = 0) -> MoveRound:
        """Coalesce, order, schedule, and execute everything pending.

        Submissions landing *during* execution (from a client's
        ``move_fn``) with strictly higher priority than the move about
        to run preempt the round: their moves splice in ahead of
        everything remaining and the interrupted copy resumes after.
        Lower/equal-priority mid-round arrivals wait for the next
        flush.
        """
        ex = self.executor
        rank = ex.tier_rank()
        # snapshot: reentrant submits during execution land in
        # self._pending, where the preemption check watches for them
        pending, self._pending = self._pending, []
        scheduled: List[ScheduledMove] = []
        per_sub: List[Tuple[_Submission, List[ScheduledMove]]] = []
        coalesced = 0
        independent_s = 0.0
        for sub in pending:
            sms, netted = self._build_sms(sub)
            coalesced += netted
            # uncoordinated baseline: each tenant executes its own
            # (un-netted) delta as if alone, one tenant after another
            # on the shared executor — what independent replanners do
            independent_s += ex.cost_s(sub.delta)
            scheduled.extend(sms)
            per_sub.append((sub, sms))

        # priority first; capacity-freeing demotions before promotions
        # at equal priority; submission order is the stable tiebreak
        order_of = {id(sm): i for i, sm in enumerate(scheduled)}
        scheduled.sort(key=lambda sm: (
            -sm.priority,
            0 if self._is_demotion(sm.move, rank) else 1,
            order_of[id(sm)]))

        makespan = self._fluid(scheduled)

        # audit the fluid schedule's promised makespan against the wall
        # time the batch really took — only when the clients perform
        # physical transfers whose wall time matches the model's unit
        audited = (self.audit is not None and scheduled
                   and getattr(ex, "physical_moves", False))
        if audited:
            self._rounds_audited += 1
            audit_key = self._rounds_audited
            self.audit.predict("movesched.makespan", audit_key, makespan,
                               epoch=epoch, moves=len(scheduled))
            wall_t0 = time.perf_counter()

        # execute in scheduled order through each tenant's client,
        # yielding to higher-priority mid-round arrivals between moves
        done_by_sub: Dict[int, Dict[int, List]] = {}
        sub_of = {id(sm): sub for sub, sms in per_sub for sm in sms}
        queue: Deque[ScheduledMove] = deque(scheduled)
        executed: List[ScheduledMove] = []
        preempted = False
        while queue:
            sm = queue[0]
            urgent = [s for s in self._pending if s.priority > sm.priority]
            if urgent:
                preempted = True
                self.preemptions += 1
                new_sms: List[ScheduledMove] = []
                for s in sorted(urgent,
                                key=lambda s: (-s.priority, s.order)):
                    self._pending.remove(s)
                    sms, netted = self._build_sms(s)
                    coalesced += netted
                    independent_s += ex.cost_s(s.delta)
                    per_sub.append((s, sms))
                    for nsm in sms:
                        sub_of[id(nsm)] = s
                    new_sms.extend(sms)
                new_sms.sort(key=lambda x: (
                    -x.priority,
                    0 if self._is_demotion(x.move, rank) else 1))
                if self.tracer is not None:
                    self.tracer.event(
                        "movesched.preempt", cat="movesched", epoch=epoch,
                        tenant=sm.tenant, obj=sm.move.obj,
                        priority=sm.priority,
                        urgent_tenants=sorted({s.tenant for s in urgent}),
                        urgent_priority=max(s.priority for s in urgent),
                        urgent_moves=len(new_sms),
                        resumed_moves=len(queue))
                queue.extendleft(reversed(new_sms))
                continue
            queue.popleft()
            sub = sub_of[id(sm)]
            m = sm.move
            done = (sub.move_fn(m.obj, m.src, m.dst, m.nbytes)
                    if sub.move_fn is not None else m.nbytes)
            sm.done_bytes = max(int(done), 0)
            executed.append(sm)
            # chunked copies report once per original move to on_done,
            # with their pieces' realized bytes summed
            orig = sm.orig_move if sm.orig_move is not None else m
            agg = done_by_sub.setdefault(sub.order, {})
            rec = agg.get(id(orig))
            first_progress = rec is None or rec[1] == 0
            if rec is None:
                agg[id(orig)] = [orig, sm.done_bytes]
            else:
                rec[1] += sm.done_bytes
            stats = sub.stats
            if stats is not None and sm.done_bytes > 0:
                stats.migrated_bytes += sm.done_bytes
                # count each object's tier change once, not per chunk
                if first_progress:
                    if self._is_demotion(m, rank):
                        stats.demoted += 1
                    elif rank.get(m.dst, 0) < rank.get(m.src, 0):
                        stats.promoted += 1
        scheduled = executed
        if preempted:
            # re-time the schedule over the order that actually ran so
            # the round record and trace spans show the spliced batch
            makespan = self._fluid(scheduled)
        if audited:
            realized = time.perf_counter() - wall_t0
            touched = sorted({t for sm in scheduled
                              for t in (sm.move.src, sm.move.dst)})
            self.audit.realize("movesched.makespan", audit_key, realized,
                               resources=touched)
            if self.calibrator is not None and makespan > 0.0:
                self.calibrator.observe_time_ratio(realized / makespan,
                                                   tiers=touched)
                ex.recalibrate()

        for sub, _ in per_sub:
            if sub.on_done is not None:
                sub.on_done([(orig, done) for orig, done in
                             done_by_sub.get(sub.order, {}).values()])

        round_ = MoveRound(epoch, scheduled, makespan, independent_s,
                           coalesced)
        self.rounds.append(round_)
        # NOT cleared: lower/equal-priority mid-round arrivals stay
        # queued for the next flush (the snapshot emptied the rest)
        if self.tracer is not None:
            now = float(self.tracer.clock())
            self.tracer.event(
                "movesched.round", cat="movesched", epoch=epoch,
                moves=len(scheduled), makespan_s=makespan,
                independent_s=independent_s, saved_s=round_.saved_s,
                coalesced_bytes=coalesced)
            # per-move spans anchored at flush time, offset by their
            # fluid-schedule start/finish — the timeline a trace viewer
            # shows is the schedule the batch actually priced
            for sm in scheduled:
                m = sm.move
                self.tracer.complete(
                    "movesched.move", cat="movesched", tid=sm.tenant,
                    ts=now + sm.start_s,
                    dur=max(sm.finish_s - sm.start_s, 0.0),
                    epoch=epoch, tenant=sm.tenant, obj=m.obj,
                    src=m.src, dst=m.dst, nbytes=m.nbytes,
                    done_bytes=sm.done_bytes, priority=sm.priority,
                    resources=[str(r) for r in sm.resources])
        return round_

    # ------------------------------------------------------------------ #
    def summary(self) -> Dict[str, float]:
        return {
            "rounds": float(len(self.rounds)),
            "scheduled_moves": float(sum(len(r.moves)
                                         for r in self.rounds)),
            "batched_makespan_s": float(sum(r.makespan_s
                                            for r in self.rounds)),
            "independent_s": float(sum(r.independent_s
                                       for r in self.rounds)),
            "saved_s": float(sum(r.saved_s for r in self.rounds)),
            "coalesced_bytes": float(sum(r.coalesced_bytes
                                         for r in self.rounds)),
            "preemptions": float(self.preemptions),
        }
