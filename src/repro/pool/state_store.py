"""TieredStateStore: ledger-registered pytrees with a re-place executor.

The missing piece between ``launch/train.py --adaptive`` and reality:
the replanner used to *plan* moves of fp32 optimizer state and stop
there.  The store holds named pytrees (e.g. ``opt_state_fp32``) as
block-granular ``TieredArray``s whose per-block *tier labels* live here
(a tier name like HOST or CXL maps to a JAX memory kind only at
``device_put`` time, so logically distinct tiers stay distinct on
single-memory CI hosts), and exposes ``move_fn`` — the
``MigrationExecutor`` hook that realizes an object-level byte move as
real block re-placements, gated by the ledger's budgets and recorded
there (the store is the physical client, so it does the recording).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax

from ..core.tiered_array import (sharding_for_kind, TIER_TO_MEMORY_KIND,
                                 TieredArray)
from .ledger import ResidencyLedger

Share = Tuple[str, float]


@dataclasses.dataclass
class _Leaf:
    """One pytree leaf: the placed array + per-block tier labels."""

    ta: TieredArray
    labels: List[str]       # tier name of each block (kinds may collide)


class TieredStateStore:
    """Named pytrees placed across tiers, moved through the ledger."""

    def __init__(self, ledger: ResidencyLedger, tenant: str,
                 tier_to_kind: Optional[Mapping[str, str]] = None,
                 block_rows: Optional[int] = None):
        self.ledger = ledger
        self.tenant = tenant
        ledger.register_tenant(tenant)
        self.tier_to_kind = dict(tier_to_kind or TIER_TO_MEMORY_KIND)
        self.block_rows = block_rows
        self._objs: Dict[str, List[_Leaf]] = {}
        self._treedefs: Dict[str, object] = {}

    def _kind(self, tier: str) -> str:
        return self.tier_to_kind.get(tier, "device")

    # ------------------------------------------------------------------ #
    def put(self, name: str, tree, shares: Sequence[Share]) -> None:
        """Place every leaf of ``tree`` under ``name`` with tier-name
        ``shares`` and register the residency with the ledger."""
        if name in self._objs:
            self.drop(name)
        import jax.numpy as jnp
        flat, treedef = jax.tree.flatten(tree)
        leaves: List[_Leaf] = []
        placement: Dict[str, int] = {}
        for x in flat:
            x = jnp.asarray(x)
            if x.ndim == 0:
                x = x[None]
            spans = TieredArray.plan_blocks(x.shape[0], shares,
                                            self.block_rows)
            blocks, kinds, labels = [], [], []
            per_row = x.nbytes // max(x.shape[0], 1)
            for a, b, tier in spans:
                kind = self._kind(tier)
                blocks.append(jax.device_put(x[a:b],
                                             sharding_for_kind(kind)))
                kinds.append(kind)
                labels.append(tier)
                placement[tier] = placement.get(tier, 0) \
                    + (b - a) * per_row
            leaves.append(_Leaf(TieredArray(blocks, kinds,
                                            tuple(x.shape), x.dtype),
                                labels))
        self._objs[name] = leaves
        self._treedefs[name] = treedef
        if self.ledger.has(self.tenant, name):
            self.ledger.retire(self.tenant, name)
        self.ledger.register(self.tenant, name, placement)

    def drop(self, name: str) -> None:
        self._objs.pop(name, None)
        self._treedefs.pop(name, None)
        self.ledger.retire(self.tenant, name)

    # ------------------------------------------------------------------ #
    def gather(self, name: str):
        """Materialize the object's pytree on device."""
        leaves = [lf.ta.gather() for lf in self._objs[name]]
        return jax.tree.unflatten(self._treedefs[name], leaves)

    def update(self, name: str, tree) -> None:
        """Write fresh values back, preserving block placement — the
        mid-run refresh that keeps a migration moving *current* bytes."""
        flat, _ = jax.tree.flatten(tree)
        leaves = self._objs[name]
        if len(flat) != len(leaves):
            raise ValueError(f"{name}: tree shape changed")
        for lf, x in zip(leaves, flat):
            import jax.numpy as jnp
            x = jnp.asarray(x)
            if x.ndim == 0:
                x = x[None]
            lf.ta = lf.ta.update(x)

    def nbytes(self, name: str) -> int:
        return sum(lf.ta.nbytes for lf in self._objs.get(name, ()))

    def bytes_on(self, name: str, tier: str) -> int:
        """Tier occupancy, read through the ledger (single source)."""
        return self.ledger.object_bytes(self.tenant, name, tier)

    def shares(self, name: str) -> List[Share]:
        total = self.nbytes(name)
        place = self.ledger.placement(self.tenant, name)
        return [(t, b / max(total, 1)) for t, b in sorted(place.items())]

    # ------------------------------------------------------------------ #
    def demote_over_budget(self, fast_tier: str, slow_tier: str) -> int:
        """Ledger-driven compliance for training state: when an arbiter
        shrank this tenant's ``fast_tier`` budget below its holdings,
        demote blocks to ``slow_tier`` until the ledger reconciles —
        the state-store mirror of the scheduler's budget preemption
        (which evicts sequences; state has no queue to re-enter, so it
        demotes in place).  Returns the bytes demoted."""
        moved = 0
        for name in sorted(self._objs):
            over = self.ledger.over_budget(self.tenant, fast_tier)
            if over <= 0:
                break
            moved += self.move_fn(name, fast_tier, slow_tier, over)
        return moved

    def move_fn(self, obj: str, src: str, dst: str, nbytes: int) -> int:
        """MigrationExecutor hook: realize an object-level byte move as
        block re-placements.  Budget-gated per block through the ledger;
        returns the bytes actually moved."""
        leaves = self._objs.get(obj)
        if leaves is None or src == dst:
            return 0
        dst_kind = self._kind(dst)
        moved = 0
        for lf in leaves:
            per_row = lf.ta.nbytes // max(lf.ta.shape[0], 1)
            for i, label in enumerate(lf.labels):
                if moved >= nbytes:
                    break
                if label != src:
                    continue
                blk_bytes = lf.ta.blocks[i].shape[0] * per_row
                if moved and moved + blk_bytes > nbytes:
                    break      # next whole block would overshoot the
                    #            request (a sub-block request may still
                    #            round up to its single first block)
                if not self.ledger.can_place(self.tenant, dst, blk_bytes):
                    break
                lf.ta.move_block(i, dst_kind)
                lf.labels[i] = dst
                self.ledger.record_move(self.tenant, obj, src, dst,
                                        blk_bytes)
                moved += blk_bytes
        return moved
