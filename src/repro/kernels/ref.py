"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def fused_adam(master: jax.Array, m: jax.Array, v: jax.Array,
               g: jax.Array, *, lr: float, b1: float, b2: float,
               eps: float, wd: float, b1c, b2c
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """AdamW update (fp32). Returns (new_master, new_m, new_v)."""
    g = g.astype(jnp.float32)
    m2 = b1 * m + (1.0 - b1) * g
    v2 = b2 * v + (1.0 - b2) * g * g
    mh = m2 / b1c
    vh = v2 / b2c
    new = master - lr * (mh / (jnp.sqrt(vh) + eps) + wd * master)
    return new, m2, v2


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd). Returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    rep = H // KV
    kf = jnp.repeat(k, rep, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kf)
    s = s / math.sqrt(hd)
    if causal:
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return o.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array) -> jax.Array:
    """GQA decode: q (B, H, hd); caches (B, S, KV, hd); kv_len scalar.

    Returns (B, H, hd)."""
    B, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    rep = H // KV
    kf = jnp.repeat(k_cache, rep, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v_cache, rep, axis=2).astype(jnp.float32)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32), kf)
    s = s / math.sqrt(hd)
    mask = jnp.arange(S)[None, None, :] < kv_len
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p, vf).astype(q.dtype)
