"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def fused_adam(master: jax.Array, m: jax.Array, v: jax.Array,
               g: jax.Array, *, lr: float, b1: float, b2: float,
               eps: float, wd: float, b1c, b2c
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """AdamW update (fp32). Returns (new_master, new_m, new_v)."""
    g = g.astype(jnp.float32)
    m2 = b1 * m + (1.0 - b1) * g
    v2 = b2 * v + (1.0 - b2) * g * g
    mh = m2 / b1c
    vh = v2 / b2c
    new = master - lr * (mh / (jnp.sqrt(vh) + eps) + wd * master)
    return new, m2, v2


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd). Returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    rep = H // KV
    kf = jnp.repeat(k, rep, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kf)
    s = s / math.sqrt(hd)
    if causal:
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return o.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array) -> jax.Array:
    """GQA decode: q (B, H, hd); caches (B, S, KV, hd); kv_len scalar.

    Returns (B, H, hd)."""
    B, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    rep = H // KV
    kf = jnp.repeat(k_cache, rep, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v_cache, rep, axis=2).astype(jnp.float32)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32), kf)
    s = s / math.sqrt(hd)
    mask = jnp.arange(S)[None, None, :] < kv_len
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p, vf).astype(q.dtype)


def paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tbl: jax.Array,
                           kv_len: jax.Array, k_new: jax.Array,
                           v_new: jax.Array) -> jax.Array:
    """Gather-then-compute oracle for the fused tiered-gather kernel.

    Stages the pool blocks into a contiguous (B, nb*bt, KV, hd) cache
    (``jnp.take`` over the block table — the copy the fused kernel
    eliminates), scatters the new token at position ``kv_len``, and
    runs plain decode attention over ``kv_len + 1`` positions.
    """
    B = q.shape[0]
    bt = k_pool.shape[1]
    nb = block_tbl.shape[1]
    KV, hd = k_pool.shape[2], k_pool.shape[3]
    gather = lambda pool: jnp.take(pool, block_tbl, axis=0).reshape(
        B, nb * bt, KV, hd)
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (B,))
    barange = jnp.arange(B)
    k_cache = gather(k_pool).at[barange, kv_len].set(
        k_new.astype(k_pool.dtype))
    v_cache = gather(v_pool).at[barange, kv_len].set(
        v_new.astype(v_pool.dtype))
    return decode_attention(q, k_cache, v_cache,
                            (kv_len + 1)[:, None, None])


def expert_ffn(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
               w_down: jax.Array, expert_ids: jax.Array,
               expert_wts: jax.Array) -> jax.Array:
    """Gather-then-compute oracle for the fused expert FFN.

    Materializes the routed experts' weights — (B, K, D, F) selections
    out of the (E, D, F) store, the staging copy the fused kernel
    skips — then applies the weighted silu FFN per (token, slot).
    """
    xf = x.astype(jnp.float32)
    wg = jnp.take(w_gate, expert_ids, axis=0).astype(jnp.float32)
    wu = jnp.take(w_up, expert_ids, axis=0).astype(jnp.float32)
    wd = jnp.take(w_down, expert_ids, axis=0).astype(jnp.float32)
    h = jax.nn.silu(jnp.einsum("bd,bkdf->bkf", xf, wg)) \
        * jnp.einsum("bd,bkdf->bkf", xf, wu)
    out = jnp.einsum("bkf,bkfd->bkd", h, wd)
    return jnp.einsum("bk,bkd->bd", expert_wts.astype(jnp.float32),
                      out).astype(x.dtype)
