"""Fused AdamW Pallas kernel — the ZeRO-Offload hot loop (Sec. IV-A).

The paper shows the CPU-side ADAM step is the bandwidth-bound critical
path of offloaded training ("the optimizer ... is sensitive to memory
latency/bandwidth"; 2-18% slowdown on CXL).  A fused single-pass update
touches each of (master, m, v, g) exactly once — 4 reads + 3 writes per
element instead of the ~10 reads + 6 writes of an unfused chain, moving
the tier-bandwidth bottleneck down by ~2.3x.

TPU mapping: 1D parameter tensors are viewed as (rows, 128) lanes; the
grid walks row-blocks sized to keep all four operand tiles resident in
VMEM (4 tiles x block x 128 x 4 B ≈ 1 MiB per step at block=512).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEF_BLOCK_ROWS = 512


def _adam_kernel(master_ref, m_ref, v_ref, g_ref, lr_ref, hyp_ref,
                 out_master_ref, out_m_ref, out_v_ref):
    """One (block_rows, LANES) tile; hyp = [b1, b2, eps, wd, b1c, b2c]."""
    b1 = hyp_ref[0]
    b2 = hyp_ref[1]
    eps = hyp_ref[2]
    wd = hyp_ref[3]
    b1c = hyp_ref[4]
    b2c = hyp_ref[5]
    lr = lr_ref[0]

    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...]
    v = v_ref[...]
    master = master_ref[...]

    m2 = b1 * m + (1.0 - b1) * g
    v2 = b2 * v + (1.0 - b2) * g * g
    mh = m2 / b1c
    vh = v2 / b2c
    new = master - lr * (mh / (jnp.sqrt(vh) + eps) + wd * master)

    out_master_ref[...] = new
    out_m_ref[...] = m2
    out_v_ref[...] = v2


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_adam_2d(master, m, v, g, lr, hyp, *,
                  block_rows: int = DEF_BLOCK_ROWS,
                  interpret: bool = True):
    """master/m/v: (R, LANES) fp32; g: (R, LANES) any float; lr: (1,);
    hyp: (6,) = [b1, b2, eps, wd, b1c, b2c]."""
    R = master.shape[0]
    blk = min(block_rows, R)
    grid = (-(-R // blk),)
    spec = pl.BlockSpec((blk, LANES), lambda i: (i, 0))
    scal = pl.BlockSpec(memory_space=pl.ANY) if False else \
        pl.BlockSpec((1,), lambda i: (0,))
    hyp_spec = pl.BlockSpec((6,), lambda i: (0,))
    out_shape = [jax.ShapeDtypeStruct((R, LANES), jnp.float32)] * 3
    return pl.pallas_call(
        _adam_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec, scal, hyp_spec],
        out_specs=[spec, spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )(master, m, v, g, lr, hyp)


def fused_adam(master: jax.Array, m: jax.Array, v: jax.Array,
               g: jax.Array, *, lr: float, b1: float, b2: float,
               eps: float, wd: float, b1c, b2c,
               block_rows: int = DEF_BLOCK_ROWS,
               interpret: bool = True
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Arbitrary-shape wrapper: pads/reshapes to (R, 128) lanes."""
    shape = master.shape
    n = master.size
    R = -(-n // LANES)
    pad = R * LANES - n

    def to2d(x, dt=jnp.float32):
        x = x.reshape(-1).astype(dt)
        if pad:
            x = jnp.pad(x, (0, pad))
        return x.reshape(R, LANES)

    lr_a = jnp.asarray([lr], jnp.float32)
    hyp = jnp.stack([jnp.asarray(b1, jnp.float32),
                     jnp.asarray(b2, jnp.float32),
                     jnp.asarray(eps, jnp.float32),
                     jnp.asarray(wd, jnp.float32),
                     jnp.asarray(b1c, jnp.float32),
                     jnp.asarray(b2c, jnp.float32)])
    nm, m2, v2 = fused_adam_2d(to2d(master), to2d(m), to2d(v), to2d(g),
                               lr_a, hyp, block_rows=block_rows,
                               interpret=interpret)

    def back(x):
        return x.reshape(-1)[:n].reshape(shape)

    return back(nm), back(m2), back(v2)
