"""GQA decode attention — Pallas TPU kernel (the FlexGen Sec. IV-B hot spot).

The paper runs decode attention on the CPU next to the offloaded KV cache
("computation offloaded to the CPU benefits from the extra CXL
bandwidth").  On TPU the analogous structure is a bandwidth-bound kernel
streaming the (possibly tier-resident) KV cache through VMEM in blocks:
one query row per sequence, online softmax across kv blocks, grouped
heads so each KV head is read ONCE for its `rep` query heads (a GQA
bandwidth optimization a naive repeat would forfeit).

Grid: (B, nk) — kv blocks innermost and sequential, accumulators live in
VMEM scratch.  kv_len masks the unwritten tail of the cache buffer.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, block_k: int, rep: int,
                   scale: float):
    ik = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[0]
    q = q_ref[0].astype(jnp.float32)             # (H, hd)  H = KV*rep
    k = k_ref[0].astype(jnp.float32)             # (block_k, KV, hd)
    v = v_ref[0].astype(jnp.float32)
    KV = k.shape[1]
    hd = q.shape[-1]
    # grouped scores: q (KV, rep, hd) x k (block_k, KV, hd) -> (KV,rep,bk)
    qg = q.reshape(KV, rep, hd)
    s = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (1,)))) * scale   # (KV, rep, block_k)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (KV, rep, k.shape[0]), 2)
    s = jnp.where(k_pos < kv_len, s, NEG_INF)

    m_prev = m_scr[...]                           # (KV, rep)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    # p (KV, rep, bk) x v (bk, KV, hd) -> (KV, rep, hd)
    pv = jax.lax.dot_general(
        p, v, (((2,), (0,)), ((0,), (1,))))
    acc_scr[...] = acc_scr[...] * corr[..., None] + pv
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = out.reshape(KV * rep, hd).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, kv_len, *, block_k: int = 256,
                     interpret: bool = True):
    """q: (B, H, hd); caches: (B, S, KV, hd); kv_len: (B,) or scalar.

    Returns (B, H, hd).  S % block_k == 0 (cache buffers are padded)."""
    B, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    rep = H // KV
    assert S % block_k == 0, f"cache len {S} % block {block_k}"
    nk = S // block_k
    scale = 1.0 / math.sqrt(hd)
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (B,))
    kernel = functools.partial(_decode_kernel, block_k=block_k, rep=rep,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(B, nk),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_k, KV, hd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, block_k, KV, hd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1,), lambda b, j: (b,)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((KV, rep), jnp.float32),
            pltpu.VMEM((KV, rep), jnp.float32),
            pltpu.VMEM((KV, rep, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, kv_len)
