"""Blocked flash attention (prefill) — Pallas TPU kernel.

TPU adaptation of the FlexGen/ZeRO compute hot spot: VMEM-tiled blocks
sized for the MXU (q/k tiles with 128-multiple dims), online softmax with
running (m, l) in VMEM scratch that persists across the innermost
(sequential) kv grid dimension.

Grid: (B * H, nq, nk) — the kv axis is innermost, so scratch accumulators
carry across kv blocks for one (head, q-block) before moving on.  Causal
blocks beyond the diagonal are skipped with pl.when (no MXU work issued).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (not causal) or (ik * block_k <= iq * block_q + block_q - 1)

    @pl.when(run if isinstance(run, bool) else run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (block_q, hd)
        k = k_ref[0].astype(jnp.float32)          # (block_k, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))) * scale
        if causal:
            q_pos = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q",
                                             "block_k", "interpret"))
def flash_attention_bh(q, k, v, *, causal: bool = True,
                       block_q: int = 128, block_k: int = 128,
                       interpret: bool = True):
    """Flat-head flash attention.

    q: (BH, Sq, hd); k, v: (BH, Sk, hd).  Returns (BH, Sq, hd).
    Sq % block_q == 0 and Sk % block_k == 0 (wrapper pads).
    """
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    nq = Sq // block_q
    nk = Sk // block_k
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """(B, Sq, H, hd) x (B, Sk, KV, hd) GQA wrapper around the kernel."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    rep = H // KV
    kf = jnp.repeat(k, rep, axis=2)
    vf = jnp.repeat(v, rep, axis=2)

    def pad_to(x, blk, axis):
        S = x.shape[axis]
        t = -(-S // blk) * blk - S
        if t == 0:
            return x
        pads = [(0, 0)] * x.ndim
        pads[axis] = (0, t)
        return jnp.pad(x, pads)

    qb = pad_to(q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd),
                block_q, 1)
    kb = pad_to(kf.transpose(0, 2, 1, 3).reshape(B * H, Sk, hd),
                block_k, 1)
    vb = pad_to(vf.transpose(0, 2, 1, 3).reshape(B * H, Sk, hd),
                block_k, 1)
    # padded kv columns must not attend: causal masking handles q-pad rows;
    # kv pads sit at positions >= Sk which are masked when causal.  For the
    # non-causal case we mask via a huge negative bias on padded keys.
    if not causal and kb.shape[1] != Sk:
        raise ValueError("non-causal flash requires Sk % block_k == 0")
    out = flash_attention_bh(qb, kb, vb, causal=causal, block_q=block_q,
                             block_k=block_k, interpret=interpret)
    out = out[:, :Sq].reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
    return out
