"""Public jit'd wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (the kernel body executes as pure
JAX on CPU — exactly how the test suite validates against ref.py); on a
TPU backend the same calls compile to Mosaic.
"""
from __future__ import annotations

from typing import Tuple

import jax

from . import decode_attention as _dec
from . import flash_attention as _fa
from . import fused_adam as _adam
from . import tiered_gather as _tg


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fused_adam(master, m, v, g, *, lr, b1, b2, eps, wd, b1c, b2c,
               block_rows: int = 512
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    return _adam.fused_adam(master, m, v, g, lr=lr, b1=b1, b2=b2, eps=eps,
                            wd=wd, b1c=b1c, b2c=b2c,
                            block_rows=block_rows,
                            interpret=_interpret())


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128) -> jax.Array:
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=_interpret())


def decode_attention(q, k_cache, v_cache, kv_len, *, block_k: int = 256
                     ) -> jax.Array:
    return _dec.decode_attention(q, k_cache, v_cache, kv_len,
                                 block_k=block_k, interpret=_interpret())


def paged_decode_attention(q, k_pool, v_pool, block_tbl, kv_len,
                           k_new, v_new, *, block_tokens: int
                           ) -> jax.Array:
    return _tg.paged_decode_attention(q, k_pool, v_pool, block_tbl,
                                      kv_len, k_new, v_new,
                                      block_tokens=block_tokens,
                                      interpret=_interpret())


def fused_expert_ffn(x, w_gate, w_up, w_down, expert_ids, expert_wts
                     ) -> jax.Array:
    return _tg.fused_expert_ffn(x, w_gate, w_up, w_down, expert_ids,
                                expert_wts, interpret=_interpret())
