"""Pallas TPU kernels for the paper's compute hot spots.

fused_adam       -- ZeRO-Offload optimizer hot loop (Sec. IV-A)
flash_attention  -- blocked prefill attention
decode_attention -- GQA decode over (tier-resident) KV cache (Sec. IV-B)
tiered_gather    -- fused tiered-gather decode: paged-KV attention and
                    top-k expert FFN indexed straight into pool layouts
                    via scalar-prefetched block/expert tables (no
                    contiguous staging copy)

Each kernel: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper), ref.py (pure-jnp oracle used by the allclose tests).
"""
from . import ops, ref
