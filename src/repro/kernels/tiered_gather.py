"""Fused tiered-gather kernels: compute directly over tier-resident
block layouts (the PR 9 hot path).

The paper's Sec. IV-B offloaded-inference study is bandwidth-bound on
the tier link, and "Demystifying CXL Memory" quantifies the cliff a
gather-then-compute path pays twice: staging tier-resident blocks into
a contiguous buffer reads every byte once to copy it and once more to
compute on it (plus the staging write).  These kernels instead index
the *pool* layout directly through a scalar-prefetched block table, so
each tier-resident byte crosses the link exactly once, into VMEM,
already in compute order.

Two kernels:

``paged_decode_attention``
    GQA decode attention over the paged KV pool: the per-layer pool
    stores ``(num_blocks, block_tokens, KV, hd)`` and a per-sequence
    block table names which pool blocks hold the sequence's tokens.
    The grid walks ``(batch, table slot)``; the block table rides the
    scalar-prefetch channel so each slot's ``index_map`` resolves to
    the *physical* pool block — no contiguous staging copy exists.
    The new token's (k, v) — computed this step, not yet in the pool —
    folds into the online softmax at finalize, replacing the unfused
    path's cache scatter.

``fused_expert_ffn``
    Top-k MoE expert FFN over the stacked expert store
    ``(n_experts, d_model, d_ff)``: the routed expert ids ride the
    scalar-prefetch channel, so each (token, slot) grid step streams
    exactly its expert's weights from their resident tier into VMEM.
    The gather-then-compute baseline (``ref.expert_ffn``) materializes
    the ``(B, k, d_model, d_ff)`` selection first — top_k/n_experts of
    the store copied per token *before* any FLOP.

Both run under ``interpret=True`` off-TPU (CPU CI), like every kernel
in this package.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# ---------------------------------------------------------------------- #
# paged decode attention                                                  #
# ---------------------------------------------------------------------- #
def _paged_decode_kernel(tbl_ref, q_ref, k_ref, v_ref, len_ref,
                         knew_ref, vnew_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, block_tokens: int,
                         rep: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = len_ref[0]
    q = q_ref[0].astype(jnp.float32)              # (H, hd)  H = KV*rep
    k = k_ref[0].astype(jnp.float32)              # (bt, KV, hd)
    v = v_ref[0].astype(jnp.float32)
    KV = k.shape[1]
    hd = q.shape[-1]
    qg = q.reshape(KV, rep, hd)
    s = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (1,)))) * scale   # (KV, rep, bt)
    # logical position of each pool-block slot: table order, not
    # physical block id — padded table entries land beyond kv_len
    k_pos = j * block_tokens + jax.lax.broadcasted_iota(
        jnp.int32, (KV, rep, k.shape[0]), 2)
    s = jnp.where(k_pos < kv_len, s, NEG_INF)

    m_prev = m_scr[...]                           # (KV, rep)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    pv = jax.lax.dot_general(
        p, v, (((2,), (0,)), ((0,), (1,))))       # (KV, rep, hd)
    acc_scr[...] = acc_scr[...] * corr[..., None] + pv
    m_scr[...] = m_new

    @pl.when(j == nb - 1)
    def _finalize():
        # fold the step's new token (position kv_len, computed in-layer
        # so never in the pool) into the online softmax — the fused
        # replacement for the unfused path's cache scatter
        kn = knew_ref[0].astype(jnp.float32)      # (KV, hd)
        vn = vnew_ref[0].astype(jnp.float32)
        sn = (qg * kn[:, None, :]).sum(-1) * scale      # (KV, rep)
        m_fin = jnp.maximum(m_scr[...], sn)
        pn = jnp.exp(sn - m_fin)
        corr_f = jnp.exp(m_scr[...] - m_fin)
        l_fin = l_scr[...] * corr_f + pn
        acc = acc_scr[...] * corr_f[..., None] + pn[..., None] \
            * vn[:, None, :]
        out = acc / jnp.maximum(l_fin, 1e-30)[..., None]
        o_ref[0] = out.reshape(KV * rep, hd).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_tokens", "interpret"))
def paged_decode_attention(q, k_pool, v_pool, block_tbl, kv_len,
                           k_new, v_new, *, block_tokens: int,
                           interpret: bool = True):
    """Decode attention straight over the paged pool layout.

    q: (B, H, hd); k_pool/v_pool: (num_blocks, block_tokens, KV, hd) —
    the tier-resident per-layer pool stores; block_tbl: (B, nb) int32
    physical block ids in logical order (pad slots may repeat id 0 —
    they are masked by ``kv_len``); kv_len: (B,) tokens already cached;
    k_new/v_new: (B, KV, hd) — this step's token, attended at position
    ``kv_len`` without ever being staged.  Returns (B, H, hd) attention
    over ``kv_len + 1`` positions.
    """
    B, H, hd = q.shape
    KV = k_pool.shape[2]
    rep = H // KV
    nb = block_tbl.shape[1]
    assert k_pool.shape[1] == block_tokens, \
        f"pool block_tokens {k_pool.shape[1]} != {block_tokens}"
    scale = 1.0 / math.sqrt(hd)
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (B,))
    block_tbl = block_tbl.astype(jnp.int32)
    kernel = functools.partial(_paged_decode_kernel,
                               block_tokens=block_tokens, rep=rep,
                               scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, j, tbl: (b, 0, 0)),
            pl.BlockSpec((1, block_tokens, KV, hd),
                         lambda b, j, tbl: (tbl[b, j], 0, 0, 0)),
            pl.BlockSpec((1, block_tokens, KV, hd),
                         lambda b, j, tbl: (tbl[b, j], 0, 0, 0)),
            pl.BlockSpec((1,), lambda b, j, tbl: (b,)),
            pl.BlockSpec((1, KV, hd), lambda b, j, tbl: (b, 0, 0)),
            pl.BlockSpec((1, KV, hd), lambda b, j, tbl: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, j, tbl: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, rep), jnp.float32),
            pltpu.VMEM((KV, rep), jnp.float32),
            pltpu.VMEM((KV, rep, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(block_tbl, q, k_pool, v_pool, kv_len, k_new, v_new)


# ---------------------------------------------------------------------- #
# fused expert FFN                                                        #
# ---------------------------------------------------------------------- #
def _expert_ffn_kernel(ids_ref, x_ref, wg_ref, wu_ref, wd_ref, wts_ref,
                       o_ref, acc_scr):
    k = pl.program_id(1)
    K = pl.num_programs(1)

    @pl.when(k == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0].astype(jnp.float32)              # (D,)
    wg = wg_ref[0].astype(jnp.float32)            # (D, F)
    wu = wu_ref[0].astype(jnp.float32)
    wd = wd_ref[0].astype(jnp.float32)            # (F, D)
    w = wts_ref[0, k].astype(jnp.float32)
    h = jax.nn.silu(x @ wg) * (x @ wu)            # (F,)
    acc_scr[...] = acc_scr[...] + w * (h @ wd)

    @pl.when(k == K - 1)
    def _finalize():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_expert_ffn(x, w_gate, w_up, w_down, expert_ids, expert_wts,
                     *, interpret: bool = True):
    """Top-k expert FFN gathered straight from the stacked expert store.

    x: (B, D); w_gate/w_up: (E, D, F); w_down: (E, F, D) — the
    tier-resident expert weight blocks; expert_ids: (B, K) int32 routed
    experts per token; expert_wts: (B, K) normalized router weights.
    Returns (B, D): sum_k w[b,k] * ffn_silu(x[b]; expert ids[b,k]).
    Only the K routed experts' weights are read per token.
    """
    B, D = x.shape
    E, _, F = w_gate.shape
    K = expert_ids.shape[1]
    expert_ids = expert_ids.astype(jnp.int32)
    expert_wts = expert_wts.astype(jnp.float32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, k, ids: (b, 0)),
            pl.BlockSpec((1, D, F), lambda b, k, ids: (ids[b, k], 0, 0)),
            pl.BlockSpec((1, D, F), lambda b, k, ids: (ids[b, k], 0, 0)),
            pl.BlockSpec((1, F, D), lambda b, k, ids: (ids[b, k], 0, 0)),
            pl.BlockSpec((1, K), lambda b, k, ids: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda b, k, ids: (b, 0)),
        scratch_shapes=[pltpu.VMEM((D,), jnp.float32)],
    )
    return pl.pallas_call(
        _expert_ffn_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), x.dtype),
        interpret=interpret,
    )(expert_ids, x, w_gate, w_up, w_down, expert_wts)
