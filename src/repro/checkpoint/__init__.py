from .store import latest_step, restore, save

__all__ = ["latest_step", "restore", "save"]
