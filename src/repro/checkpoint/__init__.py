from .store import save, restore, latest_step
