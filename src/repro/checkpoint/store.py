"""Sharded, atomic, elastic checkpointing.

Fault-tolerance design (DESIGN.md §4):
  * step-atomic: writes go to ``step_XXXXXX.tmp`` and are renamed only
    after the manifest (with per-array checksums) is fsynced — a killed
    writer never corrupts the latest checkpoint;
  * sharded: each host writes only its addressable shards (here: one
    process writes everything, but the layout is per-shard files keyed by
    (leaf path, shard index) so multi-host writers compose);
  * elastic: restore() re-shards to ANY mesh — arrays are saved logically
    (global shape) and re-device_put with the target sharding;
  * self-describing: the manifest stores the pytree structure, dtypes,
    global shapes, adler32 checksums, and user metadata (step, data state);
  * keep-last-k garbage collection.
"""
from __future__ import annotations

import json
import shutil
import zlib
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _leaf_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(ckpt_dir: str | Path, step: int, tree: Any,
         metadata: Optional[Dict] = None, keep_last: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    manifest = {"step": step, "metadata": metadata or {},
                "treedef": str(treedef), "leaves": {}}
    for i, (path, leaf) in enumerate(flat):
        key = _leaf_key(path)
        arr = np.asarray(jax.device_get(leaf))
        fn = f"leaf_{i:05d}.npy"
        np.save(tmp / fn, arr)
        manifest["leaves"][key] = {
            "file": fn, "index": i, "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "adler32": zlib.adler32(arr.tobytes()) & 0xFFFFFFFF,
        }
    mpath = tmp / "manifest.json"
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        import os
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit

    # GC old checkpoints
    steps = sorted(p for p in ckpt_dir.glob("step_????????")
                   if p.is_dir())
    for old in steps[:-keep_last]:
        shutil.rmtree(old, ignore_errors=True)
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    steps = sorted(ckpt_dir.glob("step_????????"))
    if not steps:
        return None
    return int(steps[-1].name.split("_")[1])


def restore(ckpt_dir: str | Path, target_tree: Any,
            step: Optional[int] = None, shardings: Any = None,
            verify: bool = True) -> Tuple[Any, Dict]:
    """Restore into the structure of `target_tree` (shapes must match).

    `shardings`: optional pytree of shardings (elastic re-shard onto any
    mesh); leaves without a sharding land on the default device.
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())

    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    sh_flat = None
    if shardings is not None:
        sh_flat = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: x is None or hasattr(x, "spec")
        )[0]
    leaves = []
    for i, (path, leaf) in enumerate(flat):
        key = _leaf_key(path)
        ent = manifest["leaves"].get(key)
        if ent is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(d / ent["file"])
        if arr.dtype.kind == "V":
            # numpy round-trips ml_dtypes (bfloat16 etc.) as raw void;
            # view back using the manifest's recorded dtype
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, ent["dtype"])))
        if verify:
            chk = zlib.adler32(arr.tobytes()) & 0xFFFFFFFF
            if chk != ent["adler32"]:
                raise IOError(f"checksum mismatch for {key!r}")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key!r}: shape {arr.shape} != "
                             f"{tuple(leaf.shape)}")
        sh = sh_flat[i] if sh_flat is not None else None
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.device_put(arr))
    return (jax.tree_util.tree_unflatten(treedef, leaves),
            manifest["metadata"])
