"""repro: tiered-memory-aware JAX training/serving framework.

Reproduction + TPU adaptation of "Exploring and Evaluating Real-world
CXL: Use Cases and System Adoption" (IPDPS'25).  See DESIGN.md.
"""
__version__ = "1.0.0"
