"""repro: tiered-memory-aware JAX training/serving framework.

Reproduction + TPU adaptation of "Exploring and Evaluating Real-world
CXL: Use Cases and System Adoption" (IPDPS'25).  See DESIGN.md.

Subpackages (imported lazily so ``import repro`` stays light):
  core      tier models, placement policies, cost model, migration
  pool      multi-tenant residency ledger + fair-share tier arbitration
  serving   continuous-batching paged-KV serving subsystem
  offload   one-shot ZeRO-Offload / FlexGen engines
"""
import importlib

__version__ = "1.2.0"

_LAZY_SUBPACKAGES = ("core", "serving", "offload", "models", "kernels",
                     "configs", "data", "optim", "checkpoint",
                     "telemetry", "topology", "pool")


def __getattr__(name):
    if name in _LAZY_SUBPACKAGES:
        mod = importlib.import_module(f".{name}", __name__)
        globals()[name] = mod
        return mod
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY_SUBPACKAGES))
