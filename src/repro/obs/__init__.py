"""repro.obs: unified observability plane for the control plane.

The paper's method is measurement — latency/bandwidth/tail behavior
under tiering — and this package gives the repro's own control plane
the same treatment:

- trace:    ring-bounded structured spans/events across the decision
            path (phase detect -> arbiter grant -> replan verdict ->
            move round -> executed deltas), exportable as JSONL and
            Chrome trace_event JSON
- registry: central counters/gauges/histograms with DDSketch-style
            streaming percentile sketches + Prometheus text exporter
- slo:      live rolling-window SLO monitors (TTFT / decode latency
            p50/p95/p99 vs thresholds) and the online burst-entry /
            steady lag-ratio monitor
- audit:    prediction ledger joining every planner forecast (move
            times, step costs, demand grants, phase predictions) with
            its realized outcome; residual histograms + drift detectors
- calibrate: cost-model calibrator fitting per-link latency/bandwidth
            corrections from probes and applying online EWMA scales
            from audit residuals
- qos:      interference-class QoS plane: per-tenant flow attribution
            (BlameLedger joining SLO violations to bottleneck links and
            noisy neighbors) and violation-predictive admission
            (ViolationPredictor priced on the class-aware contention
            model, audited as the ``qos.violation`` model)
"""
from .audit import DriftDetector, PredictionLedger, PredictionRecord
from .calibrate import (CostModelCalibrator, LinkCorrection, TierProbe,
                        measure_transfer_probes, probe_testbed)
from .qos import (BlameLedger, Excursion, QOS_VIOLATION_MODEL,
                  QOS_VIOLATION_TOLERANCE, ViolationPredictor)
from .registry import (Counter, Gauge, Histogram, MetricsRegistry,
                       PercentileSketch)
from .slo import LagRatioMonitor, SLOMonitor, SLOTarget
from .trace import qos_chains, replan_chains, TraceEvent, TraceRecorder

__all__ = [
    "TraceEvent", "TraceRecorder", "qos_chains", "replan_chains",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "PercentileSketch",
    "LagRatioMonitor", "SLOMonitor", "SLOTarget",
    "DriftDetector", "PredictionLedger", "PredictionRecord",
    "CostModelCalibrator", "LinkCorrection", "TierProbe",
    "measure_transfer_probes", "probe_testbed",
    "BlameLedger", "Excursion", "QOS_VIOLATION_MODEL",
    "QOS_VIOLATION_TOLERANCE", "ViolationPredictor",
]
