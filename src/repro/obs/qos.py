"""Interference-class QoS plane: blame attribution + violation prediction.

The SLO monitor can count a tail excursion; this module says *which
link* and *which neighbor* caused it, and predicts the next one before
admission lets it happen:

- :class:`BlameLedger` — every tenant's control plane publishes its
  current gather/write flows here (class- and tenant-tagged
  ``topology.Flow``s).  When an :class:`~repro.obs.slo.SLOMonitor`
  violation fires, ``on_violation`` joins the victim's *bottleneck
  link* (the highest class-weighted utilization hop on its paths at
  violation time) with the co-located tenants' offered load on that
  link, records the excursion, and names the **antagonist** — the
  neighbor applying the most interference-weighted pressure to the
  victim's traffic.  Exports ``qos.blame.<tenant>.<link>.<class>``
  gauges, a per-tenant ``noisy_neighbor_score``, and a structured
  ``blame_report()``.

- :class:`ViolationPredictor` — estimates each tenant's tail latency
  under a candidate flow set from the class-aware contention model
  (``TopologyGraph.contended_flows`` with the asymmetric
  :class:`~repro.topology.InterferenceMatrix`): a tenant's predicted
  p99 is its uncontended baseline scaled by the offered-weighted
  slowdown of its flows.  Admission and preemption gate on predicted
  violation instead of a flat link-efficiency floor, and every
  forecast is audited end-to-end through the
  :class:`~repro.obs.audit.PredictionLedger` as the ``qos.violation``
  model.

Everything is zero-dependency, bounded-memory, and clock-injected,
like the rest of ``repro.obs``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import (Any, Callable, Deque, Dict, List, Mapping, Optional,
                    Sequence, Tuple)

from ..cluster.namespace import Namespace

__all__ = ["BlameLedger", "Excursion", "ViolationPredictor",
           "QOS_VIOLATION_MODEL", "QOS_VIOLATION_TOLERANCE"]


def _norm(tenant: Any) -> str:
    """Canonical short-form tenant key (``"a"``, ``"replica0/serving"``).

    The blame book and predictor key every structure by this form, so a
    caller passing ``Namespace("replica0", "serving")`` and one passing
    the equivalent string blame/score the same tenant."""
    return str(Namespace.of(tenant).tenant_key())

# the audit model name every qos.violation forecast files under, and
# the accuracy tolerance it is judged at (tail latency under queueing
# is noisier than byte-counting move times)
QOS_VIOLATION_MODEL = "qos.violation"
QOS_VIOLATION_TOLERANCE = 0.35


@dataclasses.dataclass
class Excursion:
    """One SLO violation joined to its bottleneck link and neighbors."""

    now: float
    victim: str                     # tenant whose SLO fired
    metric: str                     # e.g. "decode_latency.p99"
    observed_s: float
    threshold_s: float
    link: Optional[Tuple[str, str]]  # bottleneck LinkKey (None: no path)
    link_kind: str = ""
    rho: float = 0.0                # victim's weighted utilization there
    antagonist: Optional[str] = None
    # co-located offered load on the bottleneck link at violation time,
    # keyed by (tenant, interference class), GB/s
    loads: Dict[Tuple[str, str], float] = dataclasses.field(
        default_factory=dict)
    # interference-weighted pressure each neighbor applied to the
    # victim's traffic class on that link (the blame mass)
    pressure: Dict[str, float] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class _FlowSnapshot:
    now: float
    flows: List[Any]


class BlameLedger:
    """Join SLO violations to bottleneck links and noisy neighbors.

    ``publish_flows`` keeps the latest class-tagged flow snapshot per
    tenant (each control plane publishes its own every epoch);
    ``on_violation`` — wired as an ``SLOMonitor`` violation hook —
    recomputes the contended state over the union of snapshots, finds
    the victim's worst class-weighted link, and splits the blame over
    the neighbors by their interference-weighted pressure there.
    """

    def __init__(self, topology, registry=None, tracer=None,
                 clock: Optional[Callable[[], float]] = None,
                 max_excursions: int = 512):
        self.topology = topology
        self.registry = registry
        self.tracer = tracer
        self.clock = clock if clock is not None else (lambda: 0.0)
        self._snapshots: Dict[str, _FlowSnapshot] = {}
        self.excursions: Deque[Excursion] = deque(
            maxlen=int(max_excursions))
        # accumulated blame mass per antagonist tenant, and how many
        # excursions each tenant was the victim of
        self._blame_mass: Dict[str, float] = {}
        self._victim_count: Dict[str, int] = {}
        self.total_excursions = 0

    # ------------------------------------------------------------------ #
    # flow book                                                          #
    # ------------------------------------------------------------------ #
    def publish_flows(self, tenant: str, flows: Sequence[Any],
                      now: Optional[float] = None) -> None:
        """Record ``tenant``'s current offered flows (replaces its
        previous snapshot).  Flows are re-tagged with the publishing
        tenant so attribution cannot be spoofed by a stale tag."""
        now = float(self.clock() if now is None else now)
        tenant = _norm(tenant)
        tagged = [dataclasses.replace(f, tenant=tenant) for f in flows]
        self._snapshots[tenant] = _FlowSnapshot(now, tagged)
        if self.registry is not None:
            for key, per in self.topology.link_loads(tagged).items():
                link = f"{key[0]}-{key[1]}"
                for (t, cls), gbps in per.items():
                    self.registry.gauge(
                        f"qos.offered.{t}.{link}.{cls}",
                        help="offered load per tenant/link/class "
                             "(GB/s)").set(gbps)

    def flows(self, exclude: Optional[str] = None) -> List[Any]:
        """The current flow union (optionally minus one tenant — a
        scheduler merging its *live* flows must drop its own possibly
        stale snapshot)."""
        out: List[Any] = []
        if exclude is not None:
            exclude = _norm(exclude)
        for tenant, snap in sorted(self._snapshots.items()):
            if tenant == exclude:
                continue
            out.extend(snap.flows)
        return out

    def tenants(self) -> List[str]:
        return sorted(self._snapshots)

    # ------------------------------------------------------------------ #
    # violation join                                                     #
    # ------------------------------------------------------------------ #
    def _victim_bottleneck(self, victim_flows: Sequence[Any],
                           all_flows: Sequence[Any]):
        """The victim's worst class-weighted link: (LinkKey, kind, rho).

        Recomputed from the flow book at violation time — the same
        pricing admission used, so blame and control agree."""
        g = self.topology
        m = g.interference
        loads = g.link_loads(all_flows)
        worst = (None, "", 0.0)
        for f in victim_flows:
            for link in g.path(f.src, f.dst):
                per = loads.get(link.key, {})
                wtotal = sum(m.weight(link.kind, f.cls, cls,
                                      link=link.key) * gbps
                             for (_t, cls), gbps in per.items())
                rho = wtotal / link.bw_GBps
                if rho > worst[2]:
                    worst = (link.key, link.kind, rho)
        return worst

    def on_violation(self, victim: str, metric: str, observed_s: float,
                     threshold_s: float,
                     now: Optional[float] = None) -> Optional[Excursion]:
        """Join one SLO violation to its bottleneck link + neighbors.

        Returns the recorded :class:`Excursion` (None when the victim
        has no published flows to attribute against)."""
        now = float(self.clock() if now is None else now)
        victim = _norm(victim)
        snap = self._snapshots.get(victim)
        if snap is None or not snap.flows:
            return None
        all_flows = self.flows()
        key, kind, rho = self._victim_bottleneck(snap.flows, all_flows)
        ex = Excursion(now=now, victim=victim, metric=metric,
                       observed_s=float(observed_s),
                       threshold_s=float(threshold_s),
                       link=key, link_kind=kind, rho=rho)
        if key is not None:
            per = self.topology.link_loads(all_flows).get(key, {})
            ex.loads = dict(per)
            m = self.topology.interference
            # pressure a neighbor applies to the victim's class mix on
            # this link: its offered load weighted by the interference
            # matrix against each victim flow class crossing the link
            victim_classes = sorted({f.cls for f in snap.flows})
            for (tenant, cls), gbps in per.items():
                if tenant == victim:
                    continue
                w = max(m.weight(kind, vc, cls, link=key)
                        for vc in victim_classes)
                ex.pressure[tenant] = ex.pressure.get(tenant, 0.0) \
                    + w * gbps
            if ex.pressure:
                ex.antagonist = max(ex.pressure, key=ex.pressure.get)
        self.excursions.append(ex)
        self.total_excursions += 1
        self._victim_count[victim] = self._victim_count.get(victim, 0) + 1
        total_pressure = sum(ex.pressure.values())
        for tenant, p in ex.pressure.items():
            share = p / total_pressure if total_pressure > 0 else 0.0
            self._blame_mass[tenant] = \
                self._blame_mass.get(tenant, 0.0) + share
        if self.registry is not None:
            link = f"{key[0]}-{key[1]}" if key else "none"
            self.registry.counter(
                "qos.excursions",
                help="SLO violations joined to a bottleneck link").inc()
            for (tenant, cls), gbps in ex.loads.items():
                if tenant == victim:
                    continue
                self.registry.gauge(
                    f"qos.blame.{tenant}.{link}.{cls}",
                    help="co-located offered load at violation time "
                         "(GB/s)").set(gbps)
            for tenant in self.tenants():
                self.registry.gauge(
                    f"qos.noisy_neighbor.{tenant}",
                    help="blame mass per excursion").set(
                        self.noisy_neighbor_score(tenant))
        if self.tracer is not None:
            self.tracer.event(
                "qos.blame", cat="qos", ts=now, victim=victim,
                metric=metric, observed_s=float(observed_s),
                threshold_s=float(threshold_s),
                link=f"{key[0]}-{key[1]}" if key else None,
                link_kind=kind, rho=rho, antagonist=ex.antagonist,
                pressure={t: round(p, 3)
                          for t, p in sorted(ex.pressure.items())})
        return ex

    # ------------------------------------------------------------------ #
    # scores + report                                                    #
    # ------------------------------------------------------------------ #
    def noisy_neighbor_score(self, tenant: str) -> float:
        """Fraction of recorded excursions this tenant was blamed for
        (blame-mass share summed over excursions / total excursions) —
        0.0 for a clean tenant, toward 1.0 for the sole antagonist of
        every tail excursion."""
        if self.total_excursions <= 0:
            return 0.0
        return min(self._blame_mass.get(_norm(tenant), 0.0)
                   / self.total_excursions, 1.0)

    def blame_report(self) -> Dict[str, Any]:
        """Structured report naming the antagonist per tail excursion."""
        counts: Dict[Tuple[str, str], int] = {}
        for ex in self.excursions:
            if ex.antagonist is not None and ex.link is not None:
                k = (ex.antagonist, f"{ex.link[0]}-{ex.link[1]}")
                counts[k] = counts.get(k, 0) + 1
        top = max(counts, key=counts.get) if counts else (None, None)
        return {
            "excursions": [
                {"now": ex.now, "victim": ex.victim, "metric": ex.metric,
                 "observed_s": ex.observed_s,
                 "threshold_s": ex.threshold_s,
                 "link": (f"{ex.link[0]}-{ex.link[1]}"
                          if ex.link else None),
                 "link_kind": ex.link_kind, "rho": ex.rho,
                 "antagonist": ex.antagonist,
                 "loads_GBps": {f"{t}/{c}": v
                                for (t, c), v in sorted(ex.loads.items())}}
                for ex in self.excursions],
            "total_excursions": self.total_excursions,
            "victims": dict(sorted(self._victim_count.items())),
            "noisy_neighbor_scores": {
                t: self.noisy_neighbor_score(t) for t in self.tenants()},
            "top_antagonist": top[0],
            "top_link": top[1],
        }

    def summary(self) -> Dict[str, float]:
        """Flat numeric summary (telemetry publication)."""
        out = {"qos.excursions": float(self.total_excursions)}
        for t in self.tenants():
            out[f"qos.noisy_neighbor.{t}"] = self.noisy_neighbor_score(t)
        return out


class ViolationPredictor:
    """Predict per-tenant tail latency from the class-aware flow model.

    The model: a tenant's tail latency scales with the offered-weighted
    *slowdown* of its flows under contention — per flow the worse of
    the loaded-latency stretch (queueing) and the bandwidth stretch
    (offered / achieved).  ``set_baseline`` anchors the scale: the
    tenant's uncontended tail latency at slowdown ``base_slowdown``
    (1.0 = unloaded), so

        predicted_p99 = baseline_p99 * slowdown(now) / base_slowdown.

    Admission asks ``violations()``: does any tenant with a registered
    target exceed its threshold under the candidate flow union?  Every
    ``file_prediction`` is joined by ``realize`` through the audit
    ledger under the ``qos.violation`` model.
    """

    def __init__(self, topology, blame: Optional[BlameLedger] = None,
                 audit=None, headroom: float = 1.0):
        self.topology = topology
        self.blame = blame
        self.audit = audit
        # admission safety factor: deny when predicted exceeds
        # headroom * threshold (headroom < 1 reserves margin)
        self.headroom = float(headroom)
        self.targets: Dict[str, float] = {}
        self.baselines: Dict[str, float] = {}
        self._base_slowdown: Dict[str, float] = {}
        if audit is not None and hasattr(audit, "set_model_tolerance"):
            audit.set_model_tolerance(QOS_VIOLATION_MODEL,
                                      QOS_VIOLATION_TOLERANCE)

    # ------------------------------------------------------------------ #
    def set_target(self, tenant: str, threshold_s: float) -> None:
        self.targets[_norm(tenant)] = float(threshold_s)

    def set_baseline(self, tenant: str, p99_s: float,
                     base_slowdown: float = 1.0) -> None:
        tenant = _norm(tenant)
        self.baselines[tenant] = float(p99_s)
        self._base_slowdown[tenant] = max(float(base_slowdown), 1e-9)

    def observe_p99(self, tenant: str, p99_s: float) -> None:
        """Online baseline learning: keep the best (lowest) observed
        tail as the tenant's uncontended anchor."""
        if not p99_s > 0.0:
            return
        tenant = _norm(tenant)
        cur = self.baselines.get(tenant)
        if cur is None or p99_s < cur:
            self.baselines[tenant] = float(p99_s)
            self._base_slowdown.setdefault(tenant, 1.0)

    # ------------------------------------------------------------------ #
    def _merged(self, extra_flows: Sequence[Any],
                exclude: Optional[str]) -> List[Any]:
        flows = list(extra_flows)
        if self.blame is not None:
            flows.extend(self.blame.flows(exclude=exclude))
        return flows

    def tenant_slowdowns(self, flows: Sequence[Any]) -> Dict[str, float]:
        """Offered-weighted mean per-flow slowdown per tenant under the
        class-aware contention model."""
        if not flows:
            return {}
        results = self.topology.contended_flows(flows)
        agg: Dict[str, List[float]] = {}
        for f, r in zip(flows, results):
            unloaded = sum(l.latency_ns
                           for l in self.topology.path(f.src, f.dst))
            lat_stretch = (r.latency_ns / unloaded
                           if unloaded > 0 else 1.0)
            bw_stretch = f.offered_GBps / max(r.achieved_GBps, 1e-12)
            s = max(lat_stretch, bw_stretch, 1.0)
            a = agg.setdefault(_norm(f.tenant), [0.0, 0.0])
            a[0] += s * f.offered_GBps
            a[1] += f.offered_GBps
        return {t: n / max(d, 1e-12) for t, (n, d) in agg.items()}

    def predict_p99s(self, extra_flows: Sequence[Any] = (),
                     exclude: Optional[str] = None) -> Dict[str, float]:
        """Predicted tail latency per tenant with a baseline, under
        ``extra_flows`` merged with the blame book (minus ``exclude``)."""
        flows = self._merged(extra_flows, exclude)
        slow = self.tenant_slowdowns(flows)
        out: Dict[str, float] = {}
        for tenant, base in self.baselines.items():
            s = slow.get(tenant)
            if s is None:
                continue               # tenant idle: baseline holds
            out[tenant] = base * s / self._base_slowdown.get(tenant, 1.0)
        return out

    def predict_p99(self, tenant: str, extra_flows: Sequence[Any] = (),
                    exclude: Optional[str] = None) -> Optional[float]:
        return self.predict_p99s(extra_flows, exclude).get(_norm(tenant))

    def violations(self, extra_flows: Sequence[Any] = (),
                   exclude: Optional[str] = None
                   ) -> Dict[str, Tuple[float, float]]:
        """Tenants whose predicted tail exceeds their target under the
        candidate flow union: {tenant: (predicted_s, threshold_s)}."""
        out: Dict[str, Tuple[float, float]] = {}
        for tenant, pred in self.predict_p99s(extra_flows,
                                              exclude).items():
            thr = self.targets.get(tenant)
            if thr is not None and pred > thr * self.headroom:
                out[tenant] = (pred, thr)
        return out

    def admission_ok(self, own_flows: Sequence[Any],
                     exclude: Optional[str] = None) -> bool:
        """Would this flow set (own running + pending + candidate, on
        top of the book's other tenants) keep every registered target
        satisfied?"""
        return not self.violations(own_flows, exclude)

    # ------------------------------------------------------------------ #
    # audit joins (model: qos.violation)                                 #
    # ------------------------------------------------------------------ #
    def file_prediction(self, key, tenant: str,
                        extra_flows: Sequence[Any] = (),
                        exclude: Optional[str] = None,
                        epoch: Optional[int] = None) -> Optional[float]:
        """File the tenant's predicted tail under ``key`` for a later
        ``realize`` join; returns the predicted value (None when the
        tenant has no baseline or no live flows)."""
        tenant = _norm(tenant)
        pred = self.predict_p99(tenant, extra_flows, exclude)
        if pred is not None and self.audit is not None:
            self.audit.predict(QOS_VIOLATION_MODEL, (tenant, key), pred,
                               epoch=epoch, tenant=tenant)
        return pred

    def realize(self, key, tenant: str, observed_s: float):
        """Join a filed prediction with the measured tail latency."""
        if self.audit is None:
            return None
        return self.audit.realize(QOS_VIOLATION_MODEL, (_norm(tenant), key),
                                  float(observed_s))
