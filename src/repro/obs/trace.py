"""Structured control-plane tracing.

A zero-dependency span/event recorder for the decision path that the
paper's measurement methodology motivates: phase detection -> arbiter
grant -> replan verdict -> scheduled move round -> executed deltas.
Events are ring-bounded (bounded memory even on long serves), carry an
injected clock (deterministic tests, engine-virtual time), and export as
both JSONL (machine diffing / round-trips) and Chrome ``trace_event``
JSON (drop the file into chrome://tracing or Perfetto for a timeline).

Event phases follow the trace_event vocabulary we need:

- ``"i"``  instant   -- a decision point (grant, verdict, admit, ...)
- ``"X"``  complete  -- a span with explicit start + duration (moves,
                        rounds; the MoveScheduler's fluid schedule gives
                        exact start/finish times)
- ``"C"``  counter   -- a sampled numeric series
"""
from __future__ import annotations

import json
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, Iterable, Iterator, List, Optional

__all__ = ["TraceEvent", "TraceRecorder", "qos_chains", "replan_chains"]


def _json_safe(value: Any) -> Any:
    """Coerce a trace-arg value into something json.dumps accepts."""
    if value is None or isinstance(value, (bool, int, float, str)):
        return value
    if isinstance(value, (list, tuple)):
        return [_json_safe(v) for v in value]
    if isinstance(value, dict):
        return {str(k): _json_safe(v) for k, v in value.items()}
    # numpy scalars expose .item(); anything else degrades to repr.
    item = getattr(value, "item", None)
    if callable(item):
        try:
            return item()
        except Exception:  # pragma: no cover - defensive
            pass
    return repr(value)


@dataclass
class TraceEvent:
    """One structured event on the control-plane timeline."""

    name: str
    cat: str
    ts_s: float
    ph: str = "i"              # "i" instant | "X" complete | "C" counter
    dur_s: float = 0.0         # only meaningful for ph == "X"
    tid: str = "main"          # logical track (tenant, component, ...)
    args: Dict[str, Any] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "name": self.name,
            "cat": self.cat,
            "ts_s": self.ts_s,
            "ph": self.ph,
            "tid": self.tid,
            "args": self.args,
        }
        if self.ph == "X":
            d["dur_s"] = self.dur_s
        return d

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "TraceEvent":
        return TraceEvent(
            name=d["name"],
            cat=d["cat"],
            ts_s=float(d["ts_s"]),
            ph=d.get("ph", "i"),
            dur_s=float(d.get("dur_s", 0.0)),
            tid=d.get("tid", "main"),
            args=dict(d.get("args", {})),
        )


class TraceRecorder:
    """Ring-bounded recorder of :class:`TraceEvent`.

    ``clock`` is injected so the engine can record in its virtual
    timebase and tests can use fake clocks; it defaults to a monotonic
    zero-origin clock. When the ring is full the oldest events are
    evicted and ``dropped`` counts them, so a misbehaving hot path can
    never grow memory unboundedly.
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 max_events: int = 65536) -> None:
        if max_events <= 0:
            raise ValueError("max_events must be positive")
        if clock is None:
            import time

            t0 = time.monotonic()
            clock = lambda: time.monotonic() - t0  # noqa: E731
        self.clock = clock
        self.max_events = int(max_events)
        self.events: Deque[TraceEvent] = deque(maxlen=self.max_events)
        self.dropped = 0

    def __len__(self) -> int:
        return len(self.events)

    # ---------------------------------------------------------- record
    def _push(self, ev: TraceEvent) -> TraceEvent:
        if len(self.events) == self.max_events:
            self.dropped += 1
        self.events.append(ev)
        return ev

    def event(self, name: str, cat: str = "obs", tid: str = "main",
              ts: Optional[float] = None, **args: Any) -> TraceEvent:
        """Record an instant event at ``ts`` (default: now)."""
        return self._push(TraceEvent(
            name=name, cat=cat, ph="i",
            ts_s=float(self.clock() if ts is None else ts),
            tid=tid, args={k: _json_safe(v) for k, v in args.items()},
        ))

    def complete(self, name: str, cat: str = "obs", tid: str = "main",
                 ts: float = 0.0, dur: float = 0.0,
                 **args: Any) -> TraceEvent:
        """Record a complete span with explicit start time + duration."""
        return self._push(TraceEvent(
            name=name, cat=cat, ph="X", ts_s=float(ts),
            dur_s=max(0.0, float(dur)), tid=tid,
            args={k: _json_safe(v) for k, v in args.items()},
        ))

    def counter(self, name: str, value: float, cat: str = "obs",
                tid: str = "main", ts: Optional[float] = None) -> TraceEvent:
        """Record a counter sample (rendered as a series in viewers)."""
        return self._push(TraceEvent(
            name=name, cat=cat, ph="C",
            ts_s=float(self.clock() if ts is None else ts),
            tid=tid, args={"value": float(value)},
        ))

    @contextmanager
    def span(self, name: str, cat: str = "obs", tid: str = "main",
             **args: Any) -> Iterator[Dict[str, Any]]:
        """Time a block of code as a complete event.

        Yields the args dict so the body can attach results before the
        span closes.
        """
        safe = {k: _json_safe(v) for k, v in args.items()}
        start = float(self.clock())
        try:
            yield safe
        finally:
            end = float(self.clock())
            self._push(TraceEvent(
                name=name, cat=cat, ph="X", ts_s=start,
                dur_s=max(0.0, end - start), tid=tid,
                args={k: _json_safe(v) for k, v in safe.items()},
            ))

    # ----------------------------------------------------------- query
    def filter(self, name: Optional[str] = None, cat: Optional[str] = None,
               tid: Optional[str] = None) -> List[TraceEvent]:
        out = []
        for ev in self.events:
            if name is not None and ev.name != name:
                continue
            if cat is not None and ev.cat != cat:
                continue
            if tid is not None and ev.tid != tid:
                continue
            out.append(ev)
        return out

    # ---------------------------------------------------------- export
    def to_jsonl(self, path: str) -> int:
        """Write one JSON object per line; returns the event count."""
        n = 0
        with open(path, "w") as fh:
            for ev in self.events:
                fh.write(json.dumps(ev.to_dict(), sort_keys=True) + "\n")
                n += 1
        return n

    @staticmethod
    def read_jsonl(path: str) -> List[TraceEvent]:
        out: List[TraceEvent] = []
        with open(path) as fh:
            for line in fh:
                line = line.strip()
                if line:
                    out.append(TraceEvent.from_dict(json.loads(line)))
        return out

    def to_chrome(self, path: str) -> int:
        """Write Chrome ``trace_event`` JSON (ts/dur in microseconds)."""
        events = []
        for ev in self.events:
            entry: Dict[str, Any] = {
                "name": ev.name,
                "cat": ev.cat,
                "ph": ev.ph,
                "ts": ev.ts_s * 1e6,
                "pid": 0,
                "tid": ev.tid,
                "args": ev.args,
            }
            if ev.ph == "X":
                entry["dur"] = ev.dur_s * 1e6
            if ev.ph == "i":
                entry["s"] = "t"  # instant scope: thread
            events.append(entry)
        with open(path, "w") as fh:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms",
                       "metadata": {"dropped_events": self.dropped}}, fh)
        return len(events)


def replan_chains(events: Iterable[TraceEvent]) -> Dict[int, Dict[str, List[TraceEvent]]]:
    """Group control-plane events by epoch into decision chains.

    Returns ``{epoch: {"phases": [...], "grants": [...], "decisions":
    [...], "rounds": [...], "moves": [...]}}`` — the reconstruction the
    acceptance criteria ask for: phase detection -> arbiter grant ->
    replan verdict -> scheduled move round -> executed migration moves.
    Events without an ``epoch`` arg are skipped.
    """
    slot_for = {
        "phase.update": "phases",
        "arbiter.grant": "grants",
        "replan.decision": "decisions",
        "movesched.round": "rounds",
        "movesched.move": "moves",
        "migration.move": "moves",
    }
    chains: Dict[int, Dict[str, List[TraceEvent]]] = {}
    for ev in events:
        slot = slot_for.get(ev.name)
        if slot is None or "epoch" not in ev.args:
            continue
        epoch = int(ev.args["epoch"])
        chain = chains.setdefault(epoch, {
            "phases": [], "grants": [], "decisions": [],
            "rounds": [], "moves": [],
        })
        chain[slot].append(ev)
    return chains


def qos_chains(events: Iterable[TraceEvent]
               ) -> List[Dict[str, Optional[TraceEvent]]]:
    """Pair each ``slo.violation`` with its ``qos.blame`` attribution.

    The BlameLedger fires synchronously from the SLO monitor's
    violation hook, so a blame event directly follows its violation on
    the timeline. Returns one ``{"violation": ev, "blame": ev-or-None,
    "saturations": [...]}`` entry per violation, where ``saturations``
    are the ``link.saturated`` events observed since the previous
    violation — the clamped-rho breadcrumbs leading into the excursion.
    """
    out: List[Dict[str, Any]] = []
    pending_sat: List[TraceEvent] = []
    for ev in events:
        if ev.name == "link.saturated":
            pending_sat.append(ev)
        elif ev.name == "slo.violation":
            out.append({"violation": ev, "blame": None,
                        "saturations": pending_sat})
            pending_sat = []
        elif ev.name == "qos.blame" and out and out[-1]["blame"] is None:
            out[-1]["blame"] = ev
    return out
