"""Live SLO monitors over serving latency streams.

Two monitors:

- :class:`SLOMonitor` — rolling-window p50/p95/p99 per metric stream
  (TTFT, decode inter-token latency) checked against threshold targets,
  with violation counters and optional trace events. Windows are exact
  (numpy percentile over a bounded deque) because SLO checks are
  control-plane-rate, not token-rate.
- :class:`LagRatioMonitor` — the ROADMAP's online burst-entry/steady
  lag ratio, computed from live per-epoch serving rates instead of the
  bench's analytic derivation. A ratio near 1.0 at burst entry means
  the predictive prefetch path hid the tier-promotion lag.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["SLOTarget", "SLOMonitor", "LagRatioMonitor"]


def _qkey(q: float) -> str:
    """'95' for 0.95, '99.9' for 0.999 — no collision at extreme tails
    (int rounding would alias p99.9 to p100)."""
    return f"{round(q * 100, 4):g}"


@dataclass(frozen=True)
class SLOTarget:
    """Threshold on a quantile of a latency stream (seconds)."""

    metric: str            # e.g. "ttft" or "decode_latency"
    quantile: float        # e.g. 0.95
    threshold_s: float     # violation when quantile > threshold

    @property
    def key(self) -> str:
        return f"{self.metric}.p{_qkey(self.quantile)}"

    def warmup_samples(self, min_samples: int) -> int:
        """Samples the window must hold before this target can violate.

        Extreme-tail targets (beyond p99) need at least 1/(1-q) samples
        for the empirical quantile to be a tail at all — a 50-sample
        "p99.9" is its max, an arrival artifact.  p95/p99 targets keep
        the caller's ``min_samples`` contract unchanged.
        """
        if self.quantile > 0.99:
            return max(min_samples,
                       int(math.ceil(1.0 / (1.0 - self.quantile))))
        return min_samples


class SLOMonitor:
    """Rolling-window quantile checks with violation counting.

    ``observe`` feeds a sample into a metric's window; ``check``
    evaluates every target against its current window and bumps
    violation counters. The clock is injected so tests can drive
    violations deterministically.
    """

    QUANTILES = (0.50, 0.95, 0.99, 0.999)

    def __init__(self, targets: Optional[List[SLOTarget]] = None,
                 window: int = 256,
                 clock: Optional[Callable[[], float]] = None,
                 registry=None, tracer=None,
                 min_samples: int = 4) -> None:
        self.targets = list(targets or [])
        # the window must be able to hold every target's warmup — a
        # p99.9 target inside a 256-sample window could never become
        # eligible (and its "p99.9" would just be the window max)
        need = max((t.warmup_samples(max(int(min_samples), 1))
                    for t in self.targets), default=0)
        self.window = max(int(window), need)
        self.clock = clock if clock is not None else (lambda: 0.0)
        self.registry = registry
        self.tracer = tracer
        # warmup: a target's window must hold at least this many samples
        # before it can violate — a 2-sample "p99" is an arrival
        # artifact, not a tail
        self.min_samples = max(int(min_samples), 1)
        self._streams: Dict[str, Deque[float]] = {}
        self.violations: Dict[str, int] = {t.key: 0 for t in self.targets}
        # checks where the target's window was past warmup — the
        # denominator of its violation rate
        self.eligible_checks: Dict[str, int] = {t.key: 0
                                                for t in self.targets}
        self.checks = 0
        self.last_quantiles: Dict[str, float] = {}
        # violation hooks: fn(target, observed_value, now) — the QoS
        # blame plane joins each firing to its bottleneck link here
        self._hooks: List[Callable[[SLOTarget, float, float], None]] = []

    def add_violation_hook(
            self, fn: Callable[[SLOTarget, float, float], None]) -> None:
        self._hooks.append(fn)

    def violation_rate(self, key: str) -> Optional[float]:
        """Violations per eligible (post-warmup) check for one target."""
        eligible = self.eligible_checks.get(key, 0)
        if eligible <= 0:
            return None
        return self.violations.get(key, 0) / eligible

    def observe(self, metric: str, value: float,
                now: Optional[float] = None) -> None:
        stream = self._streams.get(metric)
        if stream is None:
            stream = self._streams[metric] = deque(maxlen=self.window)
        stream.append(float(value))
        if self.registry is not None:
            self.registry.histogram(f"slo.{metric}").observe(float(value))

    def quantile(self, metric: str, q: float) -> Optional[float]:
        stream = self._streams.get(metric)
        if not stream:
            return None
        return float(np.percentile(np.asarray(stream, dtype=np.float64),
                                   q * 100.0))

    def check(self, now: Optional[float] = None) -> List[Tuple[SLOTarget, float]]:
        """Evaluate all targets; returns the violated (target, value)s."""
        now = float(self.clock() if now is None else now)
        self.checks += 1
        violated: List[Tuple[SLOTarget, float]] = []
        for metric, stream in self._streams.items():
            if not stream:
                continue
            arr = np.asarray(stream, dtype=np.float64)
            for q in self.QUANTILES:
                self.last_quantiles[f"{metric}.p{_qkey(q)}"] = \
                    float(np.percentile(arr, q * 100.0))
        for t in self.targets:
            value = self.last_quantiles.get(t.key)
            if value is None:
                continue
            stream = self._streams.get(t.metric)
            if stream is None or \
                    len(stream) < t.warmup_samples(self.min_samples):
                continue               # warmup: too few samples to judge
            self.eligible_checks[t.key] += 1
            if value > t.threshold_s:
                self.violations[t.key] += 1
                violated.append((t, value))
                if self.tracer is not None:
                    self.tracer.event("slo.violation", cat="slo", ts=now,
                                      metric=t.metric, quantile=t.quantile,
                                      threshold_s=t.threshold_s,
                                      observed_s=value)
                if self.registry is not None:
                    self.registry.counter(
                        f"slo.violations.{t.key}",
                        help="rolling-window SLO threshold breaches").inc()
                for hook in self._hooks:
                    hook(t, value, now)
            if self.registry is not None:
                self.registry.gauge(
                    f"slo.violation_rate.{t.key}",
                    help="violations per post-warmup check").set(
                        self.violations[t.key]
                        / self.eligible_checks[t.key])
        return violated

    def summary(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "checks": self.checks,
            "targets": [
                {"metric": t.metric, "quantile": t.quantile,
                 "threshold_s": t.threshold_s,
                 "violations": self.violations[t.key],
                 "eligible_checks": self.eligible_checks[t.key],
                 "violation_rate": self.violation_rate(t.key)}
                for t in self.targets
            ],
        }
        out.update(self.last_quantiles)
        return out


@dataclass
class _PhaseRun:
    """Accumulator for one contiguous run of a phase label."""

    label: str
    occurrence: int
    pos: int = 0


class LagRatioMonitor:
    """Online burst-entry / steady lag ratio from live serving rates.

    Feed one sample per telemetry epoch: the detected phase label, the
    work done (tokens) and the wall/virtual time spent. Epochs are
    classified by their position inside a contiguous run of the same
    label: position 0 is *entry*, positions >= ``steady_from`` are
    *steady*. The first ``warmup_occurrences`` runs of each label are
    discarded (the predictive table has not seen the phase yet), which
    matches the bench's analytic ``burst_entry_ratio`` definition, so
    live and analytic values agree on identical data.

    ``ratio()`` = mean entry rate / mean steady rate for the phase; a
    reactive-only control plane shows a dip (<1) at burst entry while
    prefetching pulls it toward 1.
    """

    def __init__(self, warmup_occurrences: int = 2,
                 steady_from: int = 2) -> None:
        self.warmup_occurrences = int(warmup_occurrences)
        self.steady_from = int(steady_from)
        self._run: Optional[_PhaseRun] = None
        self._occurrences: Dict[str, int] = {}
        self.entry_rates: Dict[str, List[float]] = {}
        self.steady_rates: Dict[str, List[float]] = {}
        self.epochs = 0

    def observe_epoch(self, phase: str, work: float, time_s: float) -> None:
        self.epochs += 1
        phase = str(phase)
        if self._run is None or self._run.label != phase:
            occ = self._occurrences.get(phase, 0) + 1
            self._occurrences[phase] = occ
            self._run = _PhaseRun(label=phase, occurrence=occ, pos=0)
        else:
            self._run.pos += 1
        if not (time_s > 0.0):   # also rejects NaN, not just <= 0
            return
        if self._run.occurrence <= self.warmup_occurrences:
            return
        rate = float(work) / float(time_s)
        if not math.isfinite(rate):
            return
        if self._run.pos == 0:
            self.entry_rates.setdefault(phase, []).append(rate)
        elif self._run.pos >= self.steady_from:
            self.steady_rates.setdefault(phase, []).append(rate)

    def _default_phase(self) -> Optional[str]:
        """The phase with the highest mean steady rate (the 'burst')."""
        best, best_rate = None, -1.0
        for phase, rates in self.steady_rates.items():
            if phase not in self.entry_rates:
                continue
            mean = sum(rates) / len(rates)
            if mean > best_rate:
                best, best_rate = phase, mean
        return best

    def ratio(self, phase: Optional[str] = None) -> Optional[float]:
        """Entry/steady rate ratio for ``phase`` (default: busiest)."""
        if phase is None:
            phase = self._default_phase()
        if phase is None:
            return None
        entry = self.entry_rates.get(phase)
        steady = self.steady_rates.get(phase)
        # an empty or all-zero steady window yields no ratio, not a
        # ZeroDivisionError or inf
        if not entry or not steady:
            return None
        steady_mean = sum(steady) / len(steady)
        if steady_mean <= 0.0 or not math.isfinite(steady_mean):
            return None
        result = (sum(entry) / len(entry)) / steady_mean
        return result if math.isfinite(result) else None

    def summary(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"epochs": self.epochs}
        r = self.ratio()
        if r is not None:
            out["burst_entry_ratio"] = r
            out["phase"] = self._default_phase()
        return out
