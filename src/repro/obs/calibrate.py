"""Self-calibrating cost model: fit the topology to measured reality.

Topology builders encode vendor-typical numbers; production hardware
drifts per vendor, per socket distance, even per DIMM population
(the paper's Fig. 2 latencies differ across all three systems).
"Dissecting CXL Memory Performance at Scale" (arxiv 2409.14317) closes
the gap with a measure->model->optimize loop; this module is that loop
for the repro's planners, in two stages:

1. **Startup probe fit** — :func:`probe_testbed` (analytic, for benches
   that know the "true" perturbed testbed) or
   :func:`measure_transfer_probes` (real ``jax.device_put`` timings,
   the `tier_characterization` data path) yield per-tier end-to-end
   latency/bandwidth observations from the compute origin.
   :meth:`CostModelCalibrator.fit_probes` turns them into per-link
   corrections (additive latency, multiplicative bandwidth): tiers are
   processed nearest-first and each tier's residual lands on the final
   (tier-specific) link of its path, so corrections stay end-to-end
   exact per tier even when attribution onto a shared earlier hop is
   ambiguous.  Tiers without a graph path calibrate their descriptor
   directly.

2. **Online EWMA loop** — audit residuals from the
   :class:`~repro.obs.audit.PredictionLedger` (realized/predicted move
   -time ratios) feed :meth:`observe_time_ratio`, which nudges a
   bandwidth scale per tier (and a global one): ``s <- (1-a)*s +
   a*(s/r)`` converges to the true bandwidth ratio, so sustained
   mispredictions self-correct without a re-probe.  Scales are clamped
   to ``[min_scale, max_scale]`` so one wild wall-clock sample cannot
   wreck the model.

:meth:`calibrated_graph` / :meth:`calibrated_tiers` thread the
corrected parameters into ``TopologyGraph.effective_tiers``,
``plan_step_cost``, and ``MigrationExecutor`` — migration pricing,
replan verdicts, and fluid move schedules all run on measured numbers.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, Hashable, Iterable, List, Mapping, Optional, Tuple

from ..core.tiers import MemoryTier

__all__ = ["TierProbe", "LinkCorrection", "CostModelCalibrator",
           "probe_testbed", "measure_transfer_probes"]


@dataclasses.dataclass(frozen=True)
class TierProbe:
    """One measured end-to-end observation of a tier from the origin.

    ``latency_ns`` may be None when the probe method cannot observe
    latency (bulk-transfer timing measures bandwidth only)."""

    tier: str
    bw_GBps: float
    latency_ns: Optional[float] = None


@dataclasses.dataclass
class LinkCorrection:
    """Fitted correction for one link (or one tier descriptor)."""

    latency_add_ns: float = 0.0
    bw_scale: float = 1.0


def probe_testbed(graph, tiers: Mapping[str, MemoryTier],
                  origin: Optional[str] = None, noise: float = 0.0,
                  samples: int = 1, seed: int = 0) -> List[TierProbe]:
    """Analytic probes against a (possibly perturbed) "true" testbed.

    Plays the role of an MLC/STREAM run on real hardware: reports each
    tier's effective unloaded latency and peak bandwidth as seen from
    ``origin``, with optional multiplicative measurement noise
    (uniform in ``±noise``) so downstream fits must average."""
    rng = random.Random(seed)
    eff = graph.effective_tiers(tiers, origin) if graph is not None \
        else dict(tiers)
    out: List[TierProbe] = []
    for name, tier in sorted(eff.items()):
        for _ in range(max(1, int(samples))):
            jl = 1.0 + noise * rng.uniform(-1.0, 1.0)
            jb = 1.0 + noise * rng.uniform(-1.0, 1.0)
            out.append(TierProbe(
                name,
                bw_GBps=tier.peak_bw_GBps * jb,
                latency_ns=(tier.unloaded_latency_ns
                            + tier.hop_latency_ns) * jl))
    return out


def measure_transfer_probes(kinds: Iterable[str] = ("pinned_host",
                                                    "unpinned_host"),
                            n_mb: int = 32, iters: int = 3
                            ) -> List[TierProbe]:
    """Real device->host transfer bandwidth per memory kind.

    The runtime twin of ``tier_characterization.measured_host_tier_rows``
    — times ``jax.device_put`` round trips and returns bandwidth-only
    probes (bulk copies cannot separate latency).  Kinds that fail to
    probe (no such memory space on this backend) are skipped."""
    import time

    import jax
    import jax.numpy as jnp

    from ..core.tiered_array import _device_sharding

    x = jnp.zeros((n_mb * 1024 * 1024 // 4,), jnp.float32)
    x = jax.device_put(x, _device_sharding("device"))
    jax.block_until_ready(x)
    out: List[TierProbe] = []
    for kind in kinds:
        try:
            t0 = time.perf_counter()
            for _ in range(max(1, iters)):
                y = jax.device_put(x, _device_sharding(kind))
                jax.block_until_ready(y)
            dt = (time.perf_counter() - t0) / max(1, iters)
            if dt > 0.0:
                out.append(TierProbe(kind, bw_GBps=n_mb / 1024 / dt))
        except Exception:  # pragma: no cover - backend-dependent
            continue
    return out


class CostModelCalibrator:
    """Per-link/tier corrections fitted from probes + audit residuals."""

    def __init__(self, tiers: Mapping[str, MemoryTier], graph=None,
                 origin: Optional[str] = None, ewma_alpha: float = 0.3,
                 min_scale: float = 0.05, max_scale: float = 20.0):
        if not 0.0 < ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if not 0.0 < min_scale <= 1.0 <= max_scale:
            raise ValueError("need min_scale <= 1.0 <= max_scale")
        self.base_tiers: Dict[str, MemoryTier] = dict(tiers)
        self.graph = graph
        self.origin = origin if origin is not None else \
            (graph.origin if graph is not None else None)
        self.alpha = float(ewma_alpha)
        self.min_scale = float(min_scale)
        self.max_scale = float(max_scale)
        self.link_corr: Dict[Tuple[str, str], LinkCorrection] = {}
        self.tier_corr: Dict[str, LinkCorrection] = {}
        self._link_owner: Dict[Tuple[str, str], str] = {}
        # online EWMA bandwidth scales; "*" is the unattributed bucket
        self.online_scale: Dict[Hashable, float] = {}
        # online interference-pair scales keyed
        # (link_kind, victim_cls, aggressor_cls)
        self.interference_scale: Dict[Tuple[str, str, str], float] = {}
        self.fitted = False
        self.probes_fit = 0
        self.observations = 0

    # ------------------------------------------------------------------ #
    # startup fit                                                        #
    # ------------------------------------------------------------------ #
    def fit_probes(self, probes: Iterable[TierProbe]) -> int:
        """Fit link/tier corrections from probe observations.

        Multiple probes of one tier are averaged first.  Tiers are
        processed nearest-first (path hop count) so shared upstream
        links are priced before the tiers behind them; each tier's
        remaining residual lands on the last link of its path — the
        link only that tier crosses — keeping every tier's *end-to-end*
        calibrated numbers exact.  When two tier names alias one node
        (e.g. pinned/unpinned host behind one PCIe link) the second
        tier's residual goes onto its descriptor instead of re-writing
        the shared link."""
        by_tier: Dict[str, List[TierProbe]] = {}
        for p in probes:
            if p.tier in self.base_tiers and p.bw_GBps > 0.0:
                by_tier.setdefault(p.tier, []).append(p)
        if not by_tier:
            return 0

        def hops(t: str) -> int:
            if self.graph is None:
                return 0
            return len(self.graph.tier_links(t, self.origin))

        for tier_name in sorted(by_tier, key=lambda t: (hops(t), t)):
            ps = by_tier[tier_name]
            bw = sum(p.bw_GBps for p in ps) / len(ps)
            lats = [p.latency_ns for p in ps if p.latency_ns is not None]
            lat = sum(lats) / len(lats) if lats else None
            self._fit_one(tier_name, bw, lat)
            self.probes_fit += len(ps)
        self.fitted = True
        return sum(len(v) for v in by_tier.values())

    def _fit_one(self, name: str, bw: float,
                 lat: Optional[float]) -> None:
        tier = self.base_tiers[name]
        path = (self.graph.tier_links(name, self.origin)
                if self.graph is not None else [])
        if not path:
            # local / unmapped tier: calibrate the descriptor itself
            corr = self.tier_corr.setdefault(name, LinkCorrection())
            corr.bw_scale = self._clamp(bw / tier.peak_bw_GBps)
            if lat is not None:
                corr.latency_add_ns = lat - (tier.unloaded_latency_ns
                                             + tier.hop_latency_ns)
            return
        last = path[-1]
        owner = self._link_owner.get(last.key)
        if owner is not None and owner != name:
            # shared terminal link (tier alias): residual on the tier,
            # priced against the already-corrected path
            corr = self.tier_corr.setdefault(name, LinkCorrection())
            corr.bw_scale = self._clamp(bw / tier.peak_bw_GBps)
            if lat is not None:
                exp = tier.unloaded_latency_ns + sum(
                    l.latency_ns + self._link(l.key).latency_add_ns
                    for l in path)
                corr.latency_add_ns = lat - exp
            return
        self._link_owner[last.key] = name
        lcorr = self.link_corr.setdefault(last.key, LinkCorrection())
        lcorr.bw_scale = self._clamp(bw / last.bw_GBps)
        if lat is not None:
            exp = tier.unloaded_latency_ns + sum(
                l.latency_ns + self._link(l.key).latency_add_ns
                for l in path[:-1])
            # additive on top of the base link latency, floored so the
            # corrected link never goes negative
            lcorr.latency_add_ns = max(lat - exp, 0.0) - last.latency_ns
        # un-cap the descriptor when the card measured faster than the
        # builder's peak — effective_tiers mins against tier.peak
        if bw > tier.peak_bw_GBps:
            tcorr = self.tier_corr.setdefault(name, LinkCorrection())
            tcorr.bw_scale = self._clamp(bw / tier.peak_bw_GBps)

    def set_tier_bandwidth(self, tier: str, bw_GBps: float) -> None:
        """Direct bandwidth override from one measured probe (keeps the
        tier's current calibrated latency)."""
        if tier not in self.base_tiers or bw_GBps <= 0.0:
            return
        self._fit_one(tier, float(bw_GBps), None)
        self.fitted = True
        self.probes_fit += 1

    def _link(self, key) -> LinkCorrection:
        return self.link_corr.get(key) or LinkCorrection()

    def _clamp(self, scale: float) -> float:
        return min(max(float(scale), self.min_scale), self.max_scale)

    # ------------------------------------------------------------------ #
    # online loop                                                        #
    # ------------------------------------------------------------------ #
    def observe_time_ratio(self, ratio: float,
                           tiers: Optional[Iterable[str]] = None,
                           alpha: Optional[float] = None) -> None:
        """Feed one realized/predicted time ratio from the audit plane.

        ``ratio > 1`` means the move ran slower than the calibrated
        model promised: the involved tiers' bandwidth scales shrink
        toward ``s/ratio`` (the fixed point where predictions match).
        With no tier attribution the global ``"*"`` scale absorbs it."""
        r = float(ratio)
        if not (r > 0.0) or r != r or r == float("inf"):
            return
        a = self.alpha if alpha is None else float(alpha)
        keys = [t for t in (tiers or []) if t in self.base_tiers] \
            or ["*"]
        for k in keys:
            s = self.online_scale.get(k, 1.0)
            self.online_scale[k] = self._clamp(
                (1.0 - a) * s + a * (s / r))
        self.observations += 1

    def _online(self, tier: str) -> float:
        return self._clamp(self.online_scale.get(tier, 1.0)
                           * self.online_scale.get("*", 1.0))

    def observe_interference(self, link_kind: str, victim_cls: str,
                             aggressor_cls: str, ratio: float,
                             alpha: Optional[float] = None) -> None:
        """Feed one realized/predicted slowdown ratio for a victim/
        aggressor class pair on a link kind.

        ``ratio > 1`` means contention hit harder than the interference
        matrix modeled: the pair's scale grows toward ``s * ratio`` so
        the class-aware ``contended_flows`` prices the pair hotter next
        time.  Scales are clamped like bandwidth scales."""
        r = float(ratio)
        if not (r > 0.0) or r != r or r == float("inf"):
            return
        a = self.alpha if alpha is None else float(alpha)
        key = (str(link_kind), str(victim_cls), str(aggressor_cls))
        s = self.interference_scale.get(key, 1.0)
        self.interference_scale[key] = self._clamp(
            (1.0 - a) * s + a * (s * r))
        self.observations += 1

    def calibrated_interference(self, base=None):
        """Interference matrix with the online pair scales applied on
        top of ``base`` (default: the graph's matrix, or the stock
        defaults)."""
        from ..topology.graph import InterferenceMatrix

        if base is None:
            base = (self.graph.interference if self.graph is not None
                    else InterferenceMatrix())
        if not self.interference_scale:
            return base
        return base.with_pair_scales(dict(self.interference_scale))

    # ------------------------------------------------------------------ #
    # calibrated views                                                   #
    # ------------------------------------------------------------------ #
    def calibrated_graph(self):
        """Corrected copy of the topology graph (None without one).

        Fitted per-link corrections apply first; each link owned by a
        probed tier additionally carries that tier's online EWMA scale
        (the link is the tier's path bottleneck after the fit, so the
        scale must land there to move the effective minimum), and the
        global ``"*"`` scale applies to every link."""
        if self.graph is None:
            return None
        overrides = {}
        g_scale = self._clamp(self.online_scale.get("*", 1.0))
        for key, link in self.graph.links.items():
            corr = self.link_corr.get(key)
            scale = (corr.bw_scale if corr else 1.0) * g_scale
            owner = self._link_owner.get(key)
            if owner is not None:
                scale *= self._clamp(self.online_scale.get(owner, 1.0))
            lat_add = corr.latency_add_ns if corr else 0.0
            if scale == 1.0 and lat_add == 0.0:
                continue
            overrides[key] = (
                max(link.latency_ns + lat_add, 0.0),
                max(link.bw_GBps * scale, 1e-9))
        g = self.graph.rebuilt(overrides)
        if self.interference_scale:
            g.interference = self.calibrated_interference()
        return g

    def _corrected_descriptor(self, name: str,
                              tier: MemoryTier) -> MemoryTier:
        corr = self.tier_corr.get(name)
        scale = self._clamp(corr.bw_scale) if corr else 1.0
        scale *= self._online(name)
        lat_add = corr.latency_add_ns if corr else 0.0
        if scale == 1.0 and lat_add == 0.0:
            return tier
        return dataclasses.replace(
            tier,
            unloaded_latency_ns=max(
                tier.unloaded_latency_ns + lat_add, 1.0),
            peak_bw_GBps=tier.peak_bw_GBps * scale,
            stream_bw_GBps=tier.stream_bw_GBps * scale)

    def calibrated_view(self, tiers: Optional[Mapping[str, MemoryTier]]
                        = None, topology=None
                        ) -> Tuple[Dict[str, MemoryTier], object]:
        """(corrected device-local descriptors, corrected graph) — the
        drop-in replacement for a consumer's ``(tiers, topology)`` pair
        so path-aware pricing (per-link serialization, contention) runs
        on measured numbers.  ``topology`` is the consumer's own graph,
        returned unchanged when the calibrator has none."""
        base = dict(tiers) if tiers is not None else self.base_tiers
        corrected = {n: self._corrected_descriptor(n, t)
                     for n, t in base.items()}
        g = self.calibrated_graph()
        return corrected, (g if g is not None else topology)

    def calibrated_tiers(self, tiers: Optional[Mapping[str, MemoryTier]]
                         = None, origin: Optional[str] = None
                         ) -> Dict[str, MemoryTier]:
        """Effective tier descriptors on measured numbers: probe-fitted
        link/tier corrections and online EWMA scales folded through the
        corrected graph as seen from ``origin``."""
        corrected, g = self.calibrated_view(tiers)
        if g is None:
            return corrected
        return g.effective_tiers(corrected, origin or self.origin)

    # ------------------------------------------------------------------ #
    # export                                                             #
    # ------------------------------------------------------------------ #
    def summary(self) -> Dict[str, float]:
        out: Dict[str, float] = {
            "calibration.fitted": 1.0 if self.fitted else 0.0,
            "calibration.probes": float(self.probes_fit),
            "calibration.observations": float(self.observations),
        }
        for key, corr in sorted(self.link_corr.items()):
            tag = f"{key[0]}-{key[1]}"
            out[f"calibration.link.{tag}.bw_scale"] = corr.bw_scale
            out[f"calibration.link.{tag}.latency_add_ns"] = \
                corr.latency_add_ns
        for name, corr in sorted(self.tier_corr.items()):
            out[f"calibration.tier.{name}.bw_scale"] = corr.bw_scale
            out[f"calibration.tier.{name}.latency_add_ns"] = \
                corr.latency_add_ns
        for key, s in sorted(self.online_scale.items(),
                             key=lambda kv: str(kv[0])):
            out[f"calibration.online.{key}.bw_scale"] = s
        for (kind, vc, ac), s in sorted(self.interference_scale.items()):
            out[f"calibration.interference.{kind}.{vc}-{ac}.scale"] = s
        return out

    def publish(self, registry) -> None:
        if registry is not None:
            registry.set_gauges(self.summary())
