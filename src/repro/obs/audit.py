"""Prediction audit plane: every planner forecast meets its outcome.

The control plane is full of predictions — ``MigrationExecutor`` prices
a delta before moving a byte, ``plan_step_cost`` promises a step time,
the predictive ``TierBudgetArbiter`` grants fast capacity for demand it
expects next epoch, and ``PhaseDetector.expected_signature`` names the
phase about to run.  "Dissecting CXL Memory Performance at Scale"
(arxiv 2409.14317) argues the measure->model->optimize loop is what
makes such models trustworthy off-simulator; this module is the
*measure* half of that loop for the repro's own models:

- :class:`PredictionLedger` records each predicted quantity under a
  ``(model, join key)`` pair and later joins the realized outcome,
  emitting the signed relative-error residual into a DDSketch histogram
  (``prediction.residual.<model>``) in the shared ``MetricsRegistry``
  and a ``prediction.audit`` trace event per join;
- a rolling-window :class:`DriftDetector` per model fires (counter +
  ``prediction.drift`` trace event) when the window's p95 *absolute*
  relative error exceeds a bound — the signal that a cost model has
  drifted from the hardware and needs recalibration;
- residuals are optionally *attributed* to the resources (links/tiers)
  the predicted quantity crossed, in the spirit of CXL-Interference
  (arxiv 2411.18308): a shared UPI hop that consistently runs slower
  than modeled shows up as that link's residual bias, which the
  ``CostModelCalibrator`` consumes.

Everything is zero-dependency, bounded-memory, and clock-injected.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import (Any, Deque, Dict, Hashable, Iterable, List, Mapping,
                    Optional, Tuple, Union)

__all__ = ["PredictionRecord", "DriftDetector", "PredictionLedger"]

ResourceKey = Hashable
Resources = Union[Iterable[ResourceKey], Mapping[ResourceKey, float]]


@dataclasses.dataclass
class PredictionRecord:
    """One audited prediction (realized fields filled at the join)."""

    model: str                      # e.g. "migration.move_time"
    key: Hashable                   # join key within the model
    predicted: float
    epoch: Optional[int] = None
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    realized: Optional[float] = None
    rel_err: Optional[float] = None   # signed (realized-predicted)/|pred|

    @property
    def matched(self) -> bool:
        return self.realized is not None

    @property
    def abs_rel_err(self) -> Optional[float]:
        return None if self.rel_err is None else abs(self.rel_err)


class DriftDetector:
    """Rolling-window p95 absolute-relative-error bound check.

    ``observe`` returns True exactly when the window (once it holds
    ``min_samples``) crosses from compliant to drifting — edge-
    triggered, so one sustained drift fires once, not once per sample;
    ``drifting`` stays True until the window recovers.
    """

    def __init__(self, bound: float = 0.5, window: int = 64,
                 min_samples: int = 8):
        if bound <= 0.0:
            raise ValueError("drift bound must be positive")
        self.bound = float(bound)
        self.window: Deque[float] = deque(maxlen=int(window))
        self.min_samples = int(min_samples)
        self.drifting = False
        self.fires = 0

    def p95(self) -> Optional[float]:
        if not self.window:
            return None
        vals = sorted(self.window)
        rank = 0.95 * (len(vals) - 1)
        lo = int(rank)
        hi = min(lo + 1, len(vals) - 1)
        frac = rank - lo
        return vals[lo] * (1.0 - frac) + vals[hi] * frac

    def observe(self, abs_rel_err: float) -> bool:
        self.window.append(abs(float(abs_rel_err)))
        if len(self.window) < self.min_samples:
            return False
        over = self.p95() > self.bound
        fired = over and not self.drifting
        self.drifting = over
        if fired:
            self.fires += 1
        return fired


class PredictionLedger:
    """Join predicted quantities with realized outcomes, per model.

    ``predict(model, key, value)`` files a pending prediction;
    ``realize(model, key, value)`` joins it, computes the signed
    relative-error residual, and feeds the registry histograms, the
    accuracy gauges, the drift detector, and (when ``resources`` are
    given) the per-link/tier residual attribution.

    Edge cases are first-class observables, not errors:

    - a realized outcome with no pending prediction counts as
      ``unmatched`` (and returns None) — the producer side lost it;
    - a duplicate join key *overwrites* the stale pending prediction
      and counts as ``duplicate`` — latest forecast wins;
    - a prediction of exactly zero cannot define a relative error: the
      join is recorded with ``rel_err=None`` and counted as
      ``zero_predicted`` instead of dividing by zero.
    """

    def __init__(self, registry=None, tracer=None,
                 tolerance: float = 0.25,
                 model_tolerance: Optional[Mapping[str, float]] = None,
                 drift_bound: float = 0.5, drift_window: int = 64,
                 drift_min_samples: int = 8,
                 max_pending: int = 4096, max_records: int = 4096):
        if not 0.0 < tolerance:
            raise ValueError("tolerance must be positive")
        self.registry = registry
        self.tracer = tracer
        self.tolerance = float(tolerance)
        # per-model accuracy tolerances: a tail-latency predictor is
        # judged looser than a byte-counting move-time model
        self.model_tolerance: Dict[str, float] = {
            str(m): float(t) for m, t in (model_tolerance or {}).items()}
        for t in self.model_tolerance.values():
            if not t > 0.0:
                raise ValueError("model tolerance must be positive")
        self._drift_bound = float(drift_bound)
        self._drift_window = int(drift_window)
        self._drift_min = int(drift_min_samples)
        self.max_pending = int(max_pending)
        # pending predictions by (model, key); insertion-ordered so the
        # oldest forecast expires first when the bound is hit
        self._pending: Dict[Tuple[str, Hashable], PredictionRecord] = {}
        self._records: Dict[str, Deque[PredictionRecord]] = {}
        self._max_records = int(max_records)
        self._drift: Dict[str, DriftDetector] = {}
        # per-resource residual attribution: key -> [mean signed err, n]
        self._resource_err: Dict[ResourceKey, List[float]] = {}
        self.predictions = 0
        self.matched = 0
        self.unmatched = 0
        self.duplicates = 0
        self.zero_predicted = 0
        self.expired = 0

    # ------------------------------------------------------------------ #
    # record / join                                                      #
    # ------------------------------------------------------------------ #
    def predict(self, model: str, key: Hashable, value: float,
                epoch: Optional[int] = None,
                **meta: Any) -> PredictionRecord:
        rec = PredictionRecord(str(model), key, float(value), epoch,
                               dict(meta))
        pkey = (rec.model, key)
        if pkey in self._pending:
            self.duplicates += 1
            self._count(f"prediction.duplicate.{rec.model}",
                        "stale pending prediction overwritten")
        self._pending[pkey] = rec
        self.predictions += 1
        self._count(f"prediction.predicted.{rec.model}",
                    "predictions filed for audit")
        if len(self._pending) > self.max_pending:
            oldest = next(iter(self._pending))
            del self._pending[oldest]
            self.expired += 1
            self._count("prediction.expired",
                        "pending predictions evicted unjoined")
        return rec

    def has_pending(self, model: str, key: Hashable) -> bool:
        return (str(model), key) in self._pending

    def pending_count(self, model: Optional[str] = None) -> int:
        if model is None:
            return len(self._pending)
        return sum(1 for m, _ in self._pending if m == model)

    def realize(self, model: str, key: Hashable, value: float,
                resources: Optional[Resources] = None
                ) -> Optional[PredictionRecord]:
        """Join one realized outcome; returns the completed record, or
        None when no prediction was pending under ``(model, key)``."""
        model = str(model)
        rec = self._pending.pop((model, key), None)
        if rec is None:
            self.unmatched += 1
            self._count(f"prediction.unmatched.{model}",
                        "realized outcomes with no pending prediction")
            self._event(model, key, None, float(value), None)
            return None
        rec.realized = float(value)
        if rec.predicted != 0.0:
            rec.rel_err = (rec.realized - rec.predicted) \
                / abs(rec.predicted)
        else:
            self.zero_predicted += 1
            self._count(f"prediction.zero_predicted.{model}",
                        "joins whose predicted value was zero")
        self.matched += 1
        self._count(f"prediction.matched.{model}",
                    "prediction/outcome joins completed")
        recs = self._records.get(model)
        if recs is None:
            recs = self._records[model] = deque(maxlen=self._max_records)
        recs.append(rec)
        if rec.rel_err is not None:
            if self.registry is not None:
                self.registry.histogram(
                    f"prediction.residual.{model}",
                    help="absolute relative error of audited "
                         "predictions").observe(abs(rec.rel_err))
                acc = self.accuracy(model)
                if acc is not None:
                    tol = self.model_tolerance.get(model, self.tolerance)
                    self.registry.gauge(
                        f"prediction.accuracy.{model}",
                        help=f"fraction of joins within "
                             f"{tol:.0%} relative error"
                    ).set(acc)
            det = self._drift.get(model)
            if det is None:
                det = self._drift[model] = DriftDetector(
                    self._drift_bound, self._drift_window,
                    self._drift_min)
            if det.observe(abs(rec.rel_err)):
                self._count(f"prediction.drift.{model}",
                            "rolling p95 relative error crossed the "
                            "drift bound")
                if self.tracer is not None:
                    self.tracer.event(
                        "prediction.drift", cat="audit", model=model,
                        p95_rel_err=det.p95(), bound=det.bound,
                        window=len(det.window))
            if resources is not None:
                self._attribute(resources, rec.rel_err)
        self._event(model, key, rec.predicted, rec.realized, rec.rel_err)
        return rec

    def _attribute(self, resources: Resources, rel_err: float) -> None:
        """Spread one residual over the resources the prediction
        crossed, weighted by each resource's modeled occupancy share —
        the per-link bias the calibrator reads."""
        if isinstance(resources, Mapping):
            items = [(k, float(w)) for k, w in resources.items()
                     if w > 0.0]
            total = sum(w for _, w in items)
            if total <= 0.0:
                return
            weighted = [(k, w / total) for k, w in items]
        else:
            keys = list(resources)
            if not keys:
                return
            weighted = [(k, 1.0 / len(keys)) for k in keys]
        for k, w in weighted:
            ent = self._resource_err.setdefault(k, [0.0, 0.0])
            ent[1] += w
            ent[0] += w * (rel_err - ent[0]) / ent[1]

    def _count(self, name: str, help: str = "") -> None:
        if self.registry is not None:
            self.registry.counter(name, help=help).inc()

    def _event(self, model, key, predicted, realized, rel_err) -> None:
        if self.tracer is not None:
            self.tracer.event(
                "prediction.audit", cat="audit", model=model,
                key=str(key), predicted=predicted, realized=realized,
                rel_err=rel_err, matched=predicted is not None)

    # ------------------------------------------------------------------ #
    # queries                                                            #
    # ------------------------------------------------------------------ #
    def models(self) -> List[str]:
        return sorted(self._records)

    def records(self, model: str) -> List[PredictionRecord]:
        return list(self._records.get(str(model), ()))

    def rel_errors(self, model: str,
                   last: Optional[int] = None) -> List[float]:
        errs = [r.rel_err for r in self._records.get(str(model), ())
                if r.rel_err is not None]
        return errs[-last:] if last else errs

    def p95_abs_rel_err(self, model: str,
                        last: Optional[int] = None) -> Optional[float]:
        errs = sorted(abs(e) for e in self.rel_errors(model, last))
        if not errs:
            return None
        rank = 0.95 * (len(errs) - 1)
        lo = int(rank)
        hi = min(lo + 1, len(errs) - 1)
        frac = rank - lo
        return errs[lo] * (1.0 - frac) + errs[hi] * frac

    def set_model_tolerance(self, model: str, tolerance: float) -> None:
        if not tolerance > 0.0:
            raise ValueError("model tolerance must be positive")
        self.model_tolerance[str(model)] = float(tolerance)

    def accuracy(self, model: str,
                 tolerance: Optional[float] = None) -> Optional[float]:
        """Fraction of joined predictions within ``tolerance`` relative
        error (None before the first joinable residual).  The tolerance
        defaults to the model's registered override, then the global."""
        if tolerance is None:
            tol = self.model_tolerance.get(str(model), self.tolerance)
        else:
            tol = float(tolerance)
        errs = self.rel_errors(model)
        if not errs:
            return None
        return sum(1 for e in errs if abs(e) <= tol) / len(errs)

    def resource_bias(self) -> Dict[ResourceKey, float]:
        """Mean signed relative error attributed per resource."""
        return {k: v[0] for k, v in self._resource_err.items()
                if v[1] > 0.0}

    def drifting(self) -> List[str]:
        return sorted(m for m, d in self._drift.items() if d.drifting)

    # ------------------------------------------------------------------ #
    # export                                                             #
    # ------------------------------------------------------------------ #
    def summary(self) -> Dict[str, float]:
        """Flat numeric summary (telemetry / gauge publication)."""
        out: Dict[str, float] = {
            "audit.predictions": float(self.predictions),
            "audit.matched": float(self.matched),
            "audit.unmatched": float(self.unmatched),
            "audit.pending": float(len(self._pending)),
            "audit.duplicates": float(self.duplicates),
            "audit.zero_predicted": float(self.zero_predicted),
        }
        for model in self.models():
            errs = self.rel_errors(model)
            if errs:
                p95 = self.p95_abs_rel_err(model)
                out[f"audit.{model}.p95_rel_err"] = float(p95)
                out[f"audit.{model}.joins"] = float(len(errs))
                acc = self.accuracy(model)
                if acc is not None:
                    out[f"prediction.accuracy.{model}"] = float(acc)
            det = self._drift.get(model)
            if det is not None:
                out[f"audit.{model}.drift_fires"] = float(det.fires)
        return out

    def report(self) -> Dict[str, Any]:
        """JSON-able residual report (the ``--audit-out`` artifact)."""
        models: Dict[str, Any] = {}
        for model in self.models():
            errs = self.rel_errors(model)
            det = self._drift.get(model)
            models[model] = {
                "joins": len(self._records.get(model, ())),
                "residuals": len(errs),
                "p50_rel_err": self._quantile(errs, 0.50),
                "p95_rel_err": self.p95_abs_rel_err(model),
                "mean_rel_err": (sum(errs) / len(errs)) if errs else None,
                "accuracy": self.accuracy(model),
                "drifting": bool(det.drifting) if det else False,
                "drift_fires": det.fires if det else 0,
            }
        return {
            "tolerance": self.tolerance,
            "model_tolerance": dict(self.model_tolerance),
            "drift_bound": self._drift_bound,
            "totals": {
                "predictions": self.predictions,
                "matched": self.matched,
                "unmatched": self.unmatched,
                "pending": len(self._pending),
                "duplicates": self.duplicates,
                "zero_predicted": self.zero_predicted,
                "expired": self.expired,
            },
            "models": models,
            "resource_bias": {str(k): v for k, v
                              in sorted(self.resource_bias().items(),
                                        key=lambda kv: str(kv[0]))},
        }

    @staticmethod
    def _quantile(errs: List[float], q: float) -> Optional[float]:
        vals = sorted(abs(e) for e in errs)
        if not vals:
            return None
        rank = q * (len(vals) - 1)
        lo = int(rank)
        hi = min(lo + 1, len(vals) - 1)
        frac = rank - lo
        return vals[lo] * (1.0 - frac) + vals[hi] * frac
