"""Central metrics registry: counters, gauges, histograms.

Histograms use a DDSketch-style log-bucketed percentile sketch with a
bounded *relative* error guarantee: for relative accuracy ``alpha``,
``quantile(q)`` is within ``alpha * true_value`` of the exact sample
quantile, at O(log(range)) memory independent of sample count. That is
the right trade for latency tails — the paper's tail-latency findings
(and CXL-Interference's co-location effects) live in p95/p99 where
fixed-width histogram buckets lose exactly the resolution that matters.

Everything here is zero-dependency and snapshot-friendly; the registry
exports both a flat dict (for bench JSON artifacts) and Prometheus-style
text exposition (for ``--metrics-out``).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Tuple

__all__ = ["PercentileSketch", "Counter", "Gauge", "Histogram",
           "MetricsRegistry"]


class PercentileSketch:
    """DDSketch-style streaming quantile sketch (relative-error bound).

    Values ``v > 0`` land in log bucket ``k = ceil(log_gamma(v))`` with
    ``gamma = (1 + alpha) / (1 - alpha)``; the representative value of
    bucket ``k`` is ``2 * gamma^k / (gamma + 1)`` (the geometric bucket
    midpoint), which keeps the relative error below ``alpha``. Values
    ``<= 0`` are collapsed into a zero bucket (latencies are positive;
    this keeps the sketch total-count correct if a zero slips in). When
    the bucket map exceeds ``max_buckets`` the lowest buckets collapse
    together — tails (high quantiles) keep their guarantee.
    """

    def __init__(self, rel_err: float = 0.01, max_buckets: int = 2048) -> None:
        if not 0.0 < rel_err < 1.0:
            raise ValueError("rel_err must be in (0, 1)")
        self.rel_err = float(rel_err)
        self.gamma = (1.0 + rel_err) / (1.0 - rel_err)
        self._log_gamma = math.log(self.gamma)
        self.max_buckets = int(max_buckets)
        self.buckets: Dict[int, int] = {}
        self.zero_count = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def add(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.sum += value
        self.min = min(self.min, value)
        self.max = max(self.max, value)
        if value <= 0.0:
            self.zero_count += 1
            return
        k = math.ceil(math.log(value) / self._log_gamma)
        self.buckets[k] = self.buckets.get(k, 0) + 1
        if len(self.buckets) > self.max_buckets:
            self._collapse_lowest()

    def _collapse_lowest(self) -> None:
        keys = sorted(self.buckets)
        lo, nxt = keys[0], keys[1]
        self.buckets[nxt] += self.buckets.pop(lo)

    def _bucket_value(self, k: int) -> float:
        return 2.0 * self.gamma ** k / (self.gamma + 1.0)

    def quantile(self, q: float) -> float:
        """Return the ``q``-quantile (q in [0, 1]) of observed values."""
        if not 0.0 <= q <= 1.0:
            raise ValueError("q must be in [0, 1]")
        if self.count == 0:
            return 0.0
        rank = q * (self.count - 1)
        if rank < self.zero_count:
            return 0.0
        seen = float(self.zero_count)
        for k in sorted(self.buckets):
            seen += self.buckets[k]
            if seen > rank:
                return self._bucket_value(k)
        return self._bucket_value(max(self.buckets)) if self.buckets else 0.0

    def summary(self) -> Dict[str, float]:
        if self.count == 0:
            return {"count": 0.0, "sum": 0.0}
        return {
            "count": float(self.count),
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
            "mean": self.sum / self.count,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


@dataclass
class Counter:
    """Monotonically increasing counter."""

    name: str
    help: str = ""
    value: float = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only increase")
        self.value += float(amount)


@dataclass
class Gauge:
    """Set-to-current-value metric."""

    name: str
    help: str = ""
    value: float = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += float(amount)


@dataclass
class Histogram:
    """Distribution metric backed by a :class:`PercentileSketch`."""

    name: str
    help: str = ""
    rel_err: float = 0.01
    sketch: PercentileSketch = field(default=None)  # type: ignore[assignment]

    def __post_init__(self) -> None:
        if self.sketch is None:
            self.sketch = PercentileSketch(rel_err=self.rel_err)

    def observe(self, value: float) -> None:
        self.sketch.add(value)


_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    safe = _NAME_RE.sub("_", name)
    if safe and safe[0].isdigit():
        safe = "_" + safe
    return safe


class MetricsRegistry:
    """Get-or-create registry of named metrics.

    One shared namespace: asking for an existing name with a different
    metric type is an error (the same guard Prometheus client libraries
    apply), so publishers can't silently shadow each other.
    """

    def __init__(self) -> None:
        self._metrics: Dict[str, Any] = {}

    def _get_or_create(self, name: str, cls, **kwargs):
        existing = self._metrics.get(name)
        if existing is not None:
            if not isinstance(existing, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(existing).__name__}, not {cls.__name__}")
            return existing
        metric = cls(name=name, **kwargs)
        self._metrics[name] = metric
        return metric

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(name, Gauge, help=help)

    def histogram(self, name: str, help: str = "",
                  rel_err: float = 0.01) -> Histogram:
        return self._get_or_create(name, Histogram, help=help,
                                   rel_err=rel_err)

    def set_gauges(self, mapping: Mapping[str, Any],
                   prefix: str = "") -> int:
        """Bulk-publish numeric values from a dict as gauges.

        Non-numeric values are skipped; returns how many were set. This
        is how ledger summaries and engine telemetry dicts flow in
        without per-key plumbing.
        """
        n = 0
        for key, value in mapping.items():
            if isinstance(value, bool) or not isinstance(value, (int, float)):
                continue
            name = f"{prefix}.{key}" if prefix else str(key)
            self.gauge(name).set(float(value))
            n += 1
        return n

    def names(self) -> List[str]:
        return sorted(self._metrics)

    # ---------------------------------------------------------- export
    def snapshot(self) -> Dict[str, float]:
        """Flat {name: value} dict; histograms expand to sub-keys."""
        out: Dict[str, float] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Histogram):
                for k, v in m.sketch.summary().items():
                    out[f"{name}.{k}"] = v
            else:
                out[name] = m.value
        return out

    def to_prometheus_text(self) -> str:
        """Prometheus text exposition (histograms as summary quantiles)."""
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            pname = _prom_name(name)
            if m.help:
                lines.append(f"# HELP {pname} {m.help}")
            if isinstance(m, Counter):
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {m.value}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {pname} gauge")
                lines.append(f"{pname} {m.value}")
            else:
                s = m.sketch.summary()
                lines.append(f"# TYPE {pname} summary")
                for q in (0.5, 0.95, 0.99):
                    val = m.sketch.quantile(q) if m.sketch.count else 0.0
                    lines.append(f'{pname}{{quantile="{q}"}} {val}')
                lines.append(f"{pname}_sum {s.get('sum', 0.0)}")
                lines.append(f"{pname}_count {int(s.get('count', 0.0))}")
        return "\n".join(lines) + "\n"
