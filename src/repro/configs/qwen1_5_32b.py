"""qwen1.5-32b [dense]: 64L d_model=5120 40H (kv=40, MHA) d_ff=27392
vocab=152064 — QKV bias [hf:Qwen/Qwen1.5-0.5B family]."""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv=40, d_ff=27392,
    vocab=152064, head_dim=128,
    pattern=(LayerSpec(kind="attn"),),
    qkv_bias=True, norm="rms", act="silu", pos_emb="rope",
    rope_theta=1000000.0,
)
