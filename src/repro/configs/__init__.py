from .base import (LayerSpec, ModelConfig, ShapeConfig, SHAPES,
                   smoke_variant)
from .registry import (ARCH_IDS, ASSIGNED_ARCHS, get_config,
                       get_smoke_config, assigned_cells)
