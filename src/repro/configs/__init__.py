from .base import (LayerSpec, ModelConfig, ShapeConfig, SHAPES,
                   smoke_variant)
from .registry import (ARCH_IDS, ASSIGNED_ARCHS, assigned_cells,
                       get_config, get_smoke_config)

__all__ = [
    "ARCH_IDS", "ASSIGNED_ARCHS", "assigned_cells", "get_config",
    "get_smoke_config", "LayerSpec", "ModelConfig", "SHAPES",
    "ShapeConfig", "smoke_variant",
]
