"""BERT-large-style encoder for the paper's ZeRO-Offload study (Sec. IV-A).
Trained here as a causal LM stand-in at matching size (the offload engine
exercises the same objects: params/grads/moments)."""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="bert-large-offload", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv=16, d_ff=4096,
    vocab=30522, head_dim=64,
    pattern=(LayerSpec(kind="attn"),),
    norm="ln", act="gelu", pos_emb="learned", max_pos=4096,
    tie_embeddings=True,
)
