"""stablelm-1.6b [dense]: 24L d_model=2048 32H (kv=32) d_ff=5632
vocab=100352 — LayerNorm, partial rotary 25% [hf:stabilityai/stablelm-2-1_6b]."""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv=32, d_ff=5632,
    vocab=100352, head_dim=64,
    pattern=(LayerSpec(kind="attn"),),
    norm="ln", act="silu", pos_emb="rope", rope_theta=10000.0,
    rotary_pct=0.25,
)
