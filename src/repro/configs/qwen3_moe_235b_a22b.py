"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-235B-A22B family]."""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv=4, d_ff=1536,
    vocab=151936, head_dim=128,
    pattern=(LayerSpec(kind="attn", moe=True),),
    n_experts=128, top_k=8, capacity_factor=1.25, moe_groups=32,
    norm="rms", act="silu", pos_emb="rope", rope_theta=1000000.0,
)
