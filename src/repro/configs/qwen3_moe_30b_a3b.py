"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]."""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv=4, d_ff=768,
    vocab=151936, head_dim=128,
    pattern=(LayerSpec(kind="attn", moe=True),),
    n_experts=128, top_k=8, capacity_factor=1.25, moe_groups=32,
    norm="rms", act="silu", pos_emb="rope", rope_theta=1000000.0,
)
