"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE
every other layer [arXiv:2403.19887].

Unit of 8 layers: 7 Mamba + 1 attention (index 4), MoE on odd layers.
Subquadratic (runs long_500k): attention layers are 1/8 and long-context
decode shards their KV over the data axis (SP flash-decode)."""
from .base import LayerSpec, ModelConfig

_M = LayerSpec(kind="mamba")
_MM = LayerSpec(kind="mamba", moe=True)
_A = LayerSpec(kind="attn")

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv=8, d_ff=24576,
    vocab=65536, head_dim=128,
    pattern=(_M, _MM, _M, _MM, _A, _MM, _M, _MM),
    n_experts=16, top_k=2, capacity_factor=1.25, moe_groups=32,
    norm="rms", act="silu", pos_emb="rope", rope_theta=1000000.0,
    mamba_expand=2, mamba_d_state=16, mamba_head_dim=64, ssd_chunk=128,
    subquadratic=True,
)
