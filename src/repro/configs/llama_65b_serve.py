"""LLaMA-65B for the paper's FlexGen inference study (Sec. IV-B)."""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama-65b-serve", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv=64, d_ff=22016,
    vocab=32000, head_dim=128,
    pattern=(LayerSpec(kind="attn"),),
    norm="rms", act="silu", pos_emb="rope", rope_theta=10000.0,
)
