"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import importlib
from typing import List

from .base import ModelConfig, smoke_variant

ARCH_IDS: List[str] = [
    "llama-3.2-vision-11b",
    "jamba-1.5-large-398b",
    "qwen3-moe-235b-a22b",
    "qwen3-moe-30b-a3b",
    "codeqwen1.5-7b",
    "qwen1.5-32b",
    "stablelm-1.6b",
    "llama3-8b",
    "whisper-large-v3",
    "rwkv6-7b",
    # paper's own evaluation models (Sec. IV)
    "gpt2-xl-offload",
    "bert-large-offload",
    "llama-65b-serve",
    "opt-66b-serve",
]

_MODULES = {i: "repro.configs." + i.replace("-", "_").replace(".", "_")
            for i in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(_MODULES[arch])
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return smoke_variant(get_config(arch))


def assigned_cells(arch: str) -> List[str]:
    """Shape cells that are valid for this arch (DESIGN.md §5 skip list)."""
    cfg = get_config(arch)
    cells = ["train_4k", "prefill_32k"]
    if cfg.supports_decode:
        cells.append("decode_32k")
    if cfg.subquadratic:
        cells.append("long_500k")
    return cells


ASSIGNED_ARCHS = ARCH_IDS[:10]
