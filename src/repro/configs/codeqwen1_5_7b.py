"""codeqwen1.5-7b [dense]: 32L d_model=4096 32H (kv=32, MHA) d_ff=13440
vocab=92416 — qwen1.5 arch (QKV bias) [hf:Qwen/CodeQwen1.5-7B]."""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=32, d_ff=13440,
    vocab=92416, head_dim=128,
    pattern=(LayerSpec(kind="attn"),),
    qkv_bias=True, norm="rms", act="silu", pos_emb="rope",
    rope_theta=1000000.0,
)
