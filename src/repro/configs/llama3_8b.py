"""llama3-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — GQA, 128k vocab [arXiv:2407.21783]."""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336,
    vocab=128256, head_dim=128,
    pattern=(LayerSpec(kind="attn"),),
    norm="rms", act="silu", pos_emb="rope", rope_theta=500000.0,
)
