"""rwkv6-7b [ssm]: 32L d_model=4096 (attention-free) d_ff=14336
vocab=65536 — Finch, data-dependent decay [arXiv:2404.05892].

Subquadratic: decode state is O(1) in context length (wkv matrix state),
so long_500k runs trivially."""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv=64, d_ff=14336,
    vocab=65536, head_dim=64,
    pattern=(LayerSpec(kind="rwkv"),),
    norm="ln", act="silu", pos_emb="none",
    rwkv_head_dim=64, rwkv_chunk=64,
    subquadratic=True,
)
