"""whisper-large-v3 [audio]: 32L enc + 32L dec, d_model=1280 20H (kv=20)
d_ff=5120 vocab=51866 — enc-dec; conv frontend STUBBED (input_specs
provides precomputed frame embeddings (B, 1500, d_model))
[arXiv:2212.04356]."""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv=20, d_ff=5120,
    vocab=51866, head_dim=64,
    pattern=(LayerSpec(kind="attn", cross_attn=True),),
    norm="ln", act="gelu", pos_emb="learned", max_pos=40960,
    encoder_layers=32, n_frontend_tokens=1500,
)
