"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attn image layers every 5th layer; vision encoder
STUBBED (input_specs provides patch embeddings (B, 1600, d_model))
[hf:meta-llama/Llama-3.2-11B-Vision]."""
from .base import LayerSpec, ModelConfig

_A = LayerSpec(kind="attn")
_X = LayerSpec(kind="cross")

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv=8, d_ff=14336,
    vocab=128256, head_dim=128,
    pattern=(_A, _A, _A, _A, _X),
    norm="rms", act="silu", pos_emb="rope", rope_theta=500000.0,
    n_frontend_tokens=1600,
)
