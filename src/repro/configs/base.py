"""Config schema: architectures, shapes, and execution knobs.

A ModelConfig fully determines parameter shapes, the layer pattern
(dense / MoE / Mamba / RWKV / cross-attn units), and the step functions the
launcher lowers.  Configs are static pytrees (frozen dataclasses) so they
can be closed over by jitted functions.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating unit."""

    kind: str = "attn"          # attn | mamba | rwkv
    moe: bool = False           # MLP replaced by MoE
    cross_attn: bool = False    # adds a cross-attention sublayer


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 32        # dispatch groups (≈ DP degree)

    # --- attention / embedding details ---
    qkv_bias: bool = False
    norm: str = "rms"           # rms | ln
    act: str = "silu"           # silu (SwiGLU) | gelu
    pos_emb: str = "rope"       # rope | learned | sinusoidal | none
    rope_theta: float = 500000.0
    rotary_pct: float = 1.0
    tie_embeddings: bool = False
    max_pos: int = 32768        # learned-pos table size (if pos_emb=learned)

    # --- SSM / RWKV ---
    mamba_expand: int = 2
    mamba_d_state: int = 16
    mamba_head_dim: int = 64
    mamba_d_conv: int = 4
    ssd_chunk: int = 128
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 64

    # --- encoder (whisper) / frontend stubs ---
    encoder_layers: int = 0     # >0: enc-dec; encoder is bidirectional
    n_frontend_tokens: int = 0  # stubbed modality tokens (audio frames /
                                # image patches), fed as embeddings
    # --- execution ---
    attn_chunk: int = 1024
    remat: bool = True
    loss_chunk: int = 512
    kv_cache_dtype: str = "bf16"    # bf16 | int8 (quantized decode cache)
    # capability flags
    subquadratic: bool = False  # can run long_500k
    supports_decode: bool = True

    # ------------------------------------------------------------------ #
    @property
    def n_units(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, \
            f"{self.name}: {self.n_layers} layers not divisible by " \
            f"pattern of {len(self.pattern)}"
        return self.n_layers // len(self.pattern)

    @property
    def unit_attn_layers(self) -> Tuple[int, ...]:
        return tuple(i for i, s in enumerate(self.pattern)
                     if s.kind == "attn")

    @property
    def unit_mamba_layers(self) -> Tuple[int, ...]:
        return tuple(i for i, s in enumerate(self.pattern)
                     if s.kind == "mamba")

    @property
    def unit_rwkv_layers(self) -> Tuple[int, ...]:
        return tuple(i for i, s in enumerate(self.pattern)
                     if s.kind == "rwkv")

    @property
    def unit_cross_layers(self) -> Tuple[int, ...]:
        return tuple(i for i, s in enumerate(self.pattern) if s.cross_attn)

    @property
    def d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + all units + head)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        H, KV, hd = self.n_heads, self.n_kv, self.head_dim
        n = V * D                       # embed
        if not self.tie_embeddings:
            n += V * D                  # lm head
        if self.pos_emb == "learned":
            n += self.max_pos * D
        per_unit = 0
        for spec in self.pattern:
            if spec.kind == "attn":
                per_unit += D * H * hd + 2 * D * KV * hd + H * hd * D
            elif spec.kind == "mamba":
                di = self.d_inner
                nh = di // self.mamba_head_dim
                per_unit += D * (2 * di + 2 * nh * self.mamba_d_state + nh)
                per_unit += di * D + self.mamba_d_conv * di
            elif spec.kind == "rwkv":
                per_unit += 5 * D * D + D * max(32, D // 64) * 2
                per_unit += D * F + F * D   # channel mix
            if spec.cross_attn:
                per_unit += D * H * hd + 2 * D * KV * hd + H * hd * D
            if spec.kind != "rwkv":
                if spec.moe:
                    mats = 3 if self.act == "silu" else 2
                    per_unit += D * self.n_experts + \
                        self.n_experts * mats * D * F
                else:
                    mats = 3 if self.act == "silu" else 2
                    per_unit += mats * D * F
        n += per_unit * self.n_units
        if self.encoder_layers:
            enc = self.encoder_layers * (
                D * H * hd + 2 * D * KV * hd + H * hd * D + 2 * D * F)
            n += enc
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        D, F = self.d_model, self.d_ff
        mats = 3 if self.act == "silu" else 2
        moe_layers = sum(1 for s in self.pattern if s.moe) * self.n_units
        dense_equiv = self.param_count() - \
            moe_layers * (self.n_experts * mats * D * F)
        return dense_equiv + moe_layers * (self.top_k * mats * D * F)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str                   # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    step: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced config of the same family for CPU smoke tests."""
    n_units = 2
    return dataclasses.replace(
        cfg,
        n_layers=n_units * len(cfg.pattern),
        d_model=64,
        n_heads=4,
        n_kv=max(1, min(cfg.n_kv, 2)) if cfg.n_kv < cfg.n_heads else 4,
        d_ff=128,
        head_dim=16,
        vocab=512,
        n_experts=8 if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.n_experts else 0,
        capacity_factor=8.0,   # drop-free at smoke scale (determinism)
        moe_groups=4,
        max_pos=256,
        mamba_head_dim=16,
        mamba_d_state=8,
        ssd_chunk=8,
        rwkv_head_dim=16,
        rwkv_chunk=8,
        attn_chunk=32,
        loss_chunk=32,
        encoder_layers=2 if cfg.encoder_layers else 0,
        n_frontend_tokens=16 if cfg.n_frontend_tokens else 0,
        remat=False,
    )
