"""GPT2-style model for the paper's ZeRO-Offload training study (Sec. IV-A).
Sized ~1.5B (the paper uses 4-8B GPT2 variants; this is the example-scale
config — scale n_layers/d_model up for the full study)."""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gpt2-xl-offload", family="dense",
    n_layers=48, d_model=1600, n_heads=25, n_kv=25, d_ff=6400,
    vocab=50257, head_dim=64,
    pattern=(LayerSpec(kind="attn"),),
    norm="ln", act="gelu", pos_emb="learned", max_pos=4096,
    tie_embeddings=True,
)
