"""OPT-66B for the paper's FlexGen inference study (Sec. IV-B)."""
from .base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="opt-66b-serve", family="dense",
    n_layers=64, d_model=9216, n_heads=72, n_kv=72, d_ff=36864,
    vocab=50272, head_dim=128,
    pattern=(LayerSpec(kind="attn"),),
    norm="ln", act="gelu", pos_emb="learned", max_pos=34816,
)
