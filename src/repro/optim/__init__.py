from .adam import AdamConfig, apply_update, init_state, init_state_shapes

__all__ = [
    "AdamConfig", "apply_update", "init_state", "init_state_shapes",
]
