from .adam import AdamConfig, init_state, init_state_shapes, apply_update
