"""AdamW with mixed precision, ZeRO-style sharding and offload hooks.

The paper's ZeRO-Offload use case (Sec. IV-A) keeps fp32 master params and
Adam moments on the slow tier and updates them there.  Here:

  * opt state = {master (fp32), m (fp32), v (fp32), step} — shaped like the
    params, so it inherits the params' (FSDP x TP) sharding = ZeRO-3-style
    partitioning of both params and optimizer state;
  * on TPU the state can additionally carry memory_kind="pinned_host"
    shardings (launch/shardings.py) — the host-offload placement;
  * gradient compression (bf16 + error feedback) halves cross-pod
    all-reduce bytes — the paper's "computation offloaded to the slow side
    benefits from extra bandwidth" translated to the wire.

The update is fully jittable; the fused Pallas kernel in repro.kernels
implements the same math for the host-side hot loop.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # gradient compression (cross-pod all-reduce in bf16 + error feedback)
    compress_grads: bool = False
    # use the fused Pallas kernel for the update (host-side hot loop)
    use_fused_kernel: bool = False


def init_state(params: Params, cfg: AdamConfig) -> Dict[str, Any]:
    # every leaf must be a DISTINCT buffer: astype(f32) is a no-op view
    # for already-f32 params (norm scales) and jnp.zeros dedupes constants
    # — either aliasing breaks donation ("donate the same buffer twice").
    f32 = lambda p: p.astype(jnp.float32) * 0.0
    state = {
        "master": jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params),
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.compress_grads:
        state["err"] = jax.tree.map(f32, params)
    return state


def init_state_shapes(param_shapes: Params, cfg: AdamConfig):
    """eval_shape twin of init_state (for dry-run input specs)."""
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    state = {
        "master": jax.tree.map(f32, param_shapes),
        "m": jax.tree.map(f32, param_shapes),
        "v": jax.tree.map(f32, param_shapes),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if cfg.compress_grads:
        state["err"] = jax.tree.map(f32, param_shapes)
    return state


def _global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_update(params: Params, state: Dict[str, Any], grads: Params,
                 cfg: AdamConfig) -> Tuple[Params, Dict[str, Any]]:
    """One AdamW step.  Returns (new bf16 params, new state)."""
    step = state["step"] + 1
    if cfg.compress_grads:
        # error-feedback compression: quantize (grad + residual) to bf16,
        # keep the quantization error for the next step.
        comp = jax.tree.map(
            lambda g, e: (g.astype(jnp.float32) + e).astype(jnp.bfloat16),
            grads, state["err"])
        new_err = jax.tree.map(
            lambda g, e, c: g.astype(jnp.float32) + e
            - c.astype(jnp.float32),
            grads, state["err"], comp)
        grads = comp
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    if cfg.use_fused_kernel:
        from ..kernels import ops as kops

        def upd(master, m, v, g):
            g = g.astype(jnp.float32) * scale
            return kops.fused_adam(
                master, m, v, g, lr=cfg.lr, b1=cfg.b1, b2=cfg.b2,
                eps=cfg.eps, wd=cfg.weight_decay, b1c=b1c, b2c=b2c)
    else:
        def upd(master, m, v, g):
            g = g.astype(jnp.float32) * scale
            m2 = cfg.b1 * m + (1.0 - cfg.b1) * g
            v2 = cfg.b2 * v + (1.0 - cfg.b2) * g * g
            mh = m2 / b1c
            vh = v2 / b2c
            new = master - cfg.lr * (
                mh / (jnp.sqrt(vh) + cfg.eps)
                + cfg.weight_decay * master)
            return new, m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_mast = tdef.flatten_up_to(state["master"])
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    flat_g = tdef.flatten_up_to(grads)
    new_mast, new_m, new_v, new_p = [], [], [], []
    for p, ma, m, v, g in zip(flat_p, flat_mast, flat_m, flat_v, flat_g):
        if p.ndim >= 2 and p.shape[0] >= 16:
            # layer-stacked tensor: stream the update over the unit axis
            # so fp32 temporaries are bounded to one layer's slice
            # (keeps sharding — slices preserve the non-leading axes).
            nm_, m2_, v2_ = jax.lax.map(
                lambda args: upd(*args), (ma, m, v, g))
        else:
            nm_, m2_, v2_ = upd(ma, m, v, g)
        new_mast.append(nm_)
        new_m.append(m2_)
        new_v.append(v2_)
        new_p.append(nm_.astype(p.dtype))
    out_state = dict(state)
    out_state["master"] = jax.tree.unflatten(tdef, new_mast)
    out_state["m"] = jax.tree.unflatten(tdef, new_m)
    out_state["v"] = jax.tree.unflatten(tdef, new_v)
    out_state["step"] = step
    if cfg.compress_grads:
        out_state["err"] = new_err
    return jax.tree.unflatten(tdef, new_p), out_state
