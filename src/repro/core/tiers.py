"""Memory-tier descriptors and performance models.

This module is the quantitative backbone of the reproduction: it encodes the
paper's measured characteristics of LDRAM / RDRAM / CXL (three vendors,
Table I + Figs. 2-4) and the TPU-side tiers we adapt the technique to
(HBM / host DRAM over PCIe / peer HBM over ICI).

Two analytic models are provided, both directly mirroring the paper's
methodology:

* ``bandwidth(streams)`` — a saturating concurrency curve reproducing Fig. 3
  ("CXL saturates at ~4-8 threads, DRAM at 20-28").  On TPU the concurrency
  axis is outstanding DMA streams rather than CPU threads (DESIGN.md §2).

* ``loaded_latency(offered_bw)`` — latency as a function of offered load
  reproducing Fig. 4 (latency skyrockets near peak bandwidth because of
  queueing in the memory controller / CXL controller).  We use an
  M/M/1-shaped queueing term which matches the paper's curves well.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional, Sequence, Tuple

GiB = 1024**3
GB = 1e9


@dataclasses.dataclass(frozen=True)
class MemoryTier:
    """A memory tier with the measured parameters the planner needs.

    Attributes mirror the paper's characterization axes:
      unloaded_latency_ns : pointer-chase latency at zero load (Fig. 2).
      peak_bw_GBps        : peak sequential bandwidth (Fig. 3 plateau).
      stream_bw_GBps      : bandwidth contributed by one access stream
                            ("thread" in the paper, DMA stream on TPU).
      saturation_streams  : streams at which bandwidth saturates (Fig. 3 knee).
      capacity_GiB        : capacity used by placement planners.
      hop_latency_ns      : extra latency if reached through another hop
                            (e.g. CXL attached to the *other* socket, or a
                            peer host on TPU).
    """

    name: str
    unloaded_latency_ns: float
    peak_bw_GBps: float
    stream_bw_GBps: float
    capacity_GiB: float
    hop_latency_ns: float = 0.0
    kind: str = "dram"  # dram | cxl | hbm | host | ici | nvme

    @property
    def saturation_streams(self) -> float:
        return self.peak_bw_GBps / self.stream_bw_GBps

    # ------------------------------------------------------------------ #
    # Fig. 3: bandwidth vs. concurrency (saturating curve)                #
    # ------------------------------------------------------------------ #
    def bandwidth(self, streams: float) -> float:
        """Aggregate bandwidth (GB/s) achieved with `streams` access streams.

        Smooth saturating model:  bw = peak * (1 - exp(-s / knee)).
        Calibrated so that the knee sits at the paper's measured saturation
        point; for CXL that is ~4 streams (Sec. I: "saturation occurring when
        the number of threads reaches just four").
        """
        if streams <= 0:
            return 0.0
        knee = max(self.saturation_streams / 2.0, 1e-6)
        return self.peak_bw_GBps * (1.0 - math.exp(-streams / knee))

    # ------------------------------------------------------------------ #
    # Fig. 4: loaded latency (queueing)                                   #
    # ------------------------------------------------------------------ #
    def loaded_latency(self, offered_bw_GBps: float) -> float:
        """Latency (ns) under an offered load (M/M/1-shaped queueing blowup).

        latency = base / (1 - rho)  capped at 20x base, with rho the
        utilization.  Reproduces the paper's observation that LDRAM/RDRAM
        near peak load reach CXL-like latency (543/600 ns vs CXL 400-550 ns).
        """
        base = self.unloaded_latency_ns + self.hop_latency_ns
        rho = min(max(offered_bw_GBps, 0.0) / self.peak_bw_GBps, 0.999)
        lat = base / (1.0 - rho)
        return min(lat, 20.0 * base)

    def access_time_s(self, nbytes: int, streams: float = 8.0,
                      random: bool = False) -> float:
        """Time to touch `nbytes` from this tier with given concurrency.

        Streaming access pays the bandwidth term; random access pays a
        latency-per-cacheline term amortized over `streams` parallel misses
        (MLC-style), which is how the paper distinguishes bandwidth-hungry
        from latency-sensitive objects.
        """
        if nbytes <= 0:
            return 0.0
        bw = self.bandwidth(streams) * GB
        stream_t = nbytes / bw
        if not random:
            return stream_t
        line = 64.0
        lat = (self.unloaded_latency_ns + self.hop_latency_ns) * 1e-9
        rand_t = (nbytes / line) * lat / max(streams, 1.0)
        return max(stream_t, rand_t)


# ---------------------------------------------------------------------- #
# Paper-measured tiers (Table I, Figs. 2-4).                              #
# Latencies: Fig. 2 sequential-access values; CXL deltas +153 ns (sys A)  #
# and +211 ns (sys B) over LDRAM.  Bandwidths: Table I / Fig. 3.          #
# ---------------------------------------------------------------------- #
def paper_system(name: str) -> Dict[str, MemoryTier]:
    """Tier sets for the paper's systems A, B, C."""
    if name == "A":  # 2x AMD EPYC 9354, CXL-A single ch DDR5-4800
        ldram = MemoryTier("LDRAM", 118, 460.8, 22.0, 768, kind="dram")
        rdram = MemoryTier("RDRAM", 205, 460.8, 22.0, 768, hop_latency_ns=0,
                           kind="dram")
        cxl = MemoryTier("CXL", 271, 38.4, 9.0, 128, kind="cxl")
    elif name == "B":  # 2x SPR 8470, CXL-B DDR5-8000
        ldram = MemoryTier("LDRAM", 112, 307.2, 11.0, 1024, kind="dram")
        rdram = MemoryTier("RDRAM", 190, 307.2, 11.0, 1024, kind="dram")
        cxl = MemoryTier("CXL", 323, 64.0, 10.5, 64, kind="cxl")
    elif name == "C":  # 2x Xeon Gold 6438V+, CXL-C dual ch DDR5-6200
        ldram = MemoryTier("LDRAM", 114, 307.2, 11.0, 512, kind="dram")
        rdram = MemoryTier("RDRAM", 195, 307.2, 11.0, 512, kind="dram")
        cxl = MemoryTier("CXL", 290, 96.8, 13.0, 128, kind="cxl")
    else:
        raise ValueError(f"unknown paper system {name!r}")
    nvme = MemoryTier("NVMe", 80_000, 7.0, 3.5, 128, kind="nvme")
    return {"LDRAM": ldram, "RDRAM": rdram, "CXL": cxl, "NVMe": nvme}


# ---------------------------------------------------------------------- #
# TPU v5e tiers — the adaptation target (DESIGN.md §2).                   #
# HBM 819 GB/s, PCIe host link ~24 GB/s effective, ICI ~50 GB/s/link.     #
# ---------------------------------------------------------------------- #
def tpu_v5e_tiers(hbm_GiB: float = 16.0, host_GiB: float = 512.0
                  ) -> Dict[str, MemoryTier]:
    hbm = MemoryTier("HBM", 390, 819.0, 120.0, hbm_GiB, kind="hbm")
    # pinned host over PCIe: the "CXL expander" analogue — big, slow,
    # early-saturating (few DMA engines).
    host = MemoryTier("HOST", 900, 24.0, 8.0, host_GiB, kind="host")
    # peer-chip HBM over one ICI link: the "RDRAM" analogue.
    ici = MemoryTier("ICI_PEER", 600, 50.0, 25.0, hbm_GiB, kind="ici")
    # paged host memory: the "NVMe" analogue (page faults throttle it).
    unpinned = MemoryTier("HOST_UNPINNED", 1500, 8.0, 4.0, host_GiB,
                          kind="nvme")
    return {"HBM": hbm, "HOST": host, "ICI_PEER": ici,
            "HOST_UNPINNED": unpinned}


# ---------------------------------------------------------------------- #
# Sec. III bandwidth-packing: assign streams across tiers to maximize     #
# aggregate bandwidth ("6/23/23 threads to CXL/LDRAM/RDRAM -> 420 GB/s"). #
# ---------------------------------------------------------------------- #
def _delivered_bandwidth(tiers: Mapping[str, MemoryTier],
                         alloc: Mapping[str, int],
                         tier_links: Mapping[str, Sequence],
                         passes: int = 4) -> Dict[str, float]:
    """Per-tier bandwidth actually delivered to the compute origin.

    Each tier produces its concurrency-curve bandwidth, then every
    interconnect link on its path caps the *sum* of the flows crossing
    it: when tiers share a bottleneck hop (two DIMM sets behind one UPI
    link, CXL + DRAM behind one socket), their flows fair-share the
    link (proportional scale-down, iterated to a fixed point).
    """
    flow = {k: tiers[k].bandwidth(alloc[k]) for k in tiers}
    links = {}
    for k, ls in tier_links.items():
        for link in ls:
            links.setdefault(link.key, (link, []))[1].append(k)
    for _ in range(passes):
        changed = False
        for link, crossing in links.values():
            load = sum(flow[k] for k in crossing)
            if load > link.bw_GBps * (1 + 1e-9):
                s = link.bw_GBps / load
                for k in crossing:
                    flow[k] *= s
                changed = True
        if not changed:
            break
    return flow


def assign_streams(tiers: Mapping[str, MemoryTier], total_streams: int,
                   topology=None, origin: Optional[str] = None
                   ) -> Tuple[Dict[str, int], float]:
    """Greedy water-filling of access streams over tiers.

    Iteratively grants the next stream to the tier with the largest
    marginal bandwidth gain.  Returns ({tier: streams}, aggregate_GBps).
    Reproduces the paper's Sec. III thread-assignment observation.

    With a ``topology`` (repro.topology.TopologyGraph), the marginal
    gain is measured on the bandwidth *delivered through the path from
    the compute origin*: tiers whose paths share a bottleneck link
    fair-share it, so adding streams to a second tier behind an already
    saturated hop gains nothing and the water-filling routes those
    streams to tiers with independent paths instead (closing the
    ROADMAP stream-assignment item).
    """
    if topology is None:
        alloc = {k: 0 for k in tiers}
        for _ in range(total_streams):
            best_k, best_gain = None, 0.0
            for k, t in tiers.items():
                gain = t.bandwidth(alloc[k] + 1) - t.bandwidth(alloc[k])
                if gain > best_gain:
                    best_k, best_gain = k, gain
            if best_k is None:  # everything saturated
                break
            alloc[best_k] += 1
        agg = sum(tiers[k].bandwidth(n) for k, n in alloc.items())
        return alloc, agg

    eff = topology.effective_tiers(tiers, origin)
    tier_links = {k: topology.tier_links(k, origin) for k in tiers}
    alloc = {k: 0 for k in tiers}
    agg = 0.0
    for _ in range(total_streams):
        best_k, best_agg = None, agg
        for k in tiers:
            trial = dict(alloc)
            trial[k] += 1
            cand = sum(_delivered_bandwidth(eff, trial,
                                            tier_links).values())
            if cand > best_agg + 1e-9:
                best_k, best_agg = k, cand
        if best_k is None:      # every path saturated: no stream helps
            break
        alloc[best_k] += 1
        agg = best_agg
    return alloc, agg


def interleave_bandwidth(tiers: Mapping[str, MemoryTier],
                         weights: Optional[Mapping[str, float]] = None,
                         streams: float = 16.0) -> float:
    """Effective bandwidth of round-robin interleaving across `tiers`.

    With uniform page interleave, each tier serves a `weight` fraction of the
    traffic; the slowest tier *relative to its share* gates throughput
    (harmonic composition) — this is why the paper finds uniform interleave
    can *undermine* performance (Sec. V takeaway): a 38 GB/s CXL card serving
    1/3 of the traffic caps the aggregate at ~3x38 = 115 GB/s even next to a
    460 GB/s LDRAM.
    """
    names = list(tiers)
    if weights is None:
        weights = {k: 1.0 / len(names) for k in names}
    per_tier_streams = {k: streams * weights[k] for k in names}
    # aggregate limited by the tier that finishes its share last
    t_norm = max(
        weights[k] / max(tiers[k].bandwidth(per_tier_streams[k]), 1e-9)
        for k in names if weights[k] > 0
    )
    return 1.0 / t_norm
