"""TieredArray: block-granular array placement across JAX memory kinds.

This realizes the paper's page-interleaving mechanics with the TPU-native
mechanism: an array is split into blocks along its leading axis and each
block is placed in a memory kind ("device" = HBM/fast tier,
"pinned_host"/"unpinned_host" = the CXL-analogue capacity tiers).

API:
  ta = TieredArray.place(x, shares=[("device", .5), ("pinned_host", .5)])
  y  = ta.gather()                # materialize on device (blocking)
  it = ta.prefetch_blocks()       # double-buffered async block stream
  ta2 = ta.update(new_x)          # write back preserving placement

`gather` issues all device transfers up front (jax.device_put is
asynchronous) so host->device DMA of later blocks overlaps the concat of
earlier ones — the block-granular analogue of the paper's "distribute
memory accesses between tiers" guidance.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Share = Tuple[str, float]  # (memory kind, fraction)

# Map tier names (core.tiers) to JAX memory kinds on the accelerator host.
TIER_TO_MEMORY_KIND = {
    "HBM": "device",
    "LDRAM": "device",          # in paper-system replays the fast tier
    "HOST": "pinned_host",
    "RDRAM": "pinned_host",
    "CXL": "unpinned_host",
    "ICI_PEER": "device",
    "HOST_UNPINNED": "unpinned_host",
    "NVMe": "unpinned_host",
}


# Logical kinds the placement layer accepts.  On an accelerator host all
# three are distinct physical memories; on a single-memory host (CPU CI)
# they are *logical* tiers all backed by the device's default memory, so
# placement bookkeeping (shares, bytes_on, fast_fraction) still works and
# the same code places physically on TPU.
LOGICAL_KINDS = ("device", "pinned_host", "unpinned_host")


def physical_memory_kinds(device: Optional[jax.Device] = None) -> List[str]:
    device = device or jax.devices()[0]
    return [m.kind for m in device.addressable_memories()]


def sharding_for_kind(memory_kind: str,
                      device: Optional[jax.Device] = None):
    """SingleDeviceSharding on `memory_kind`, degrading to the device's
    default memory when the platform doesn't expose that kind."""
    device = device or jax.devices()[0]
    if memory_kind not in physical_memory_kinds(device):
        memory_kind = device.default_memory().kind
    return jax.sharding.SingleDeviceSharding(device, memory_kind=memory_kind)


_device_sharding = sharding_for_kind


def available_memory_kinds() -> List[str]:
    """Kinds accepted for placement: the logical tier set plus anything
    extra the platform physically exposes."""
    return sorted(set(LOGICAL_KINDS) | set(physical_memory_kinds()))


@dataclasses.dataclass
class TieredArray:
    """An array split into per-memory-kind blocks along axis 0."""

    blocks: List[jax.Array]       # in order, concat along axis 0 == array
    kinds: List[str]              # memory kind of each block
    shape: Tuple[int, ...]
    dtype: jnp.dtype

    # ------------------------------------------------------------------ #
    @staticmethod
    def plan_blocks(n_rows: int, shares: Sequence[Share],
                    block_rows: Optional[int] = None
                    ) -> List[Tuple[int, int, str]]:
        """Compute (start, stop, kind) block spans for the share list.

        With `block_rows` set, shares are realized round-robin at block
        granularity (true interleaving); otherwise each share is one
        contiguous span (numactl membind-style).
        """
        shares = [(k, f) for k, f in shares if f > 0]
        if not shares:
            raise ValueError("empty share list")
        total_f = sum(f for _, f in shares)
        shares = [(k, f / total_f) for k, f in shares]
        if block_rows is None:
            spans = []
            start = 0
            for i, (k, f) in enumerate(shares):
                stop = n_rows if i == len(shares) - 1 else min(
                    n_rows, start + max(1, int(round(f * n_rows))))
                if stop > start:
                    spans.append((start, stop, k))
                start = stop
            return spans
        # round-robin interleave at block_rows granularity, weighted by f
        n_blocks = math.ceil(n_rows / block_rows)
        seq: List[str] = []
        counts = {k: 0.0 for k, _ in shares}
        for _ in range(n_blocks):
            # pick kind with largest deficit vs target fraction
            k = max(shares, key=lambda kf: kf[1] * (len(seq) + 1)
                    - counts[kf[0]])[0]
            seq.append(k)
            counts[k] += 1.0
        spans = []
        for i, k in enumerate(seq):
            a, b = i * block_rows, min((i + 1) * block_rows, n_rows)
            spans.append((a, b, k))
        return spans

    @classmethod
    def place(cls, x: jax.Array, shares: Sequence[Share],
              block_rows: Optional[int] = None) -> "TieredArray":
        x = jnp.asarray(x)
        if x.ndim == 0:
            x = x[None]
        kinds_avail = set(available_memory_kinds())
        spans = cls.plan_blocks(x.shape[0], shares, block_rows)
        blocks, kinds = [], []
        for a, b, kind in spans:
            if kind not in kinds_avail:  # degrade gracefully off-host
                kind = "device"
            blk = jax.device_put(x[a:b], _device_sharding(kind))
            blocks.append(blk)
            kinds.append(kind)
        return cls(blocks, kinds, tuple(x.shape), x.dtype)

    @classmethod
    def from_plan(cls, x: jax.Array, tier_shares: Sequence[Tuple[str, float]],
                  block_rows: Optional[int] = None) -> "TieredArray":
        """Place using core.tiers tier *names* (mapped to memory kinds)."""
        shares = [(TIER_TO_MEMORY_KIND.get(t, "device"), f)
                  for t, f in tier_shares]
        # merge duplicate kinds
        merged: Dict[str, float] = {}
        for k, f in shares:
            merged[k] = merged.get(k, 0.0) + f
        return cls.place(x, list(merged.items()), block_rows)

    # ------------------------------------------------------------------ #
    def gather(self) -> jax.Array:
        """Materialize the full array in device memory.

        All block transfers are dispatched first (async), then concatenated:
        later DMAs overlap earlier concat work.
        """
        dev = _device_sharding("device")
        moved = [jax.device_put(b, dev) for b in self.blocks]  # async batch
        if len(moved) == 1:
            return moved[0].reshape(self.shape)
        return jnp.concatenate(moved, axis=0).reshape(self.shape)

    def prefetch_blocks(self) -> Iterator[jax.Array]:
        """Double-buffered block stream: block i+1's DMA is in flight while
        block i is consumed (the ZeRO-Offload bucket pipeline)."""
        dev = _device_sharding("device")
        nxt = jax.device_put(self.blocks[0], dev)
        for i in range(len(self.blocks)):
            cur = nxt
            if i + 1 < len(self.blocks):
                nxt = jax.device_put(self.blocks[i + 1], dev)
            yield cur

    def move_block(self, i: int, kind: str) -> int:
        """Re-place block ``i`` onto ``kind`` in place (a real
        jax.device_put between memory kinds); returns the bytes moved
        (0 when the block already lives there)."""
        if self.kinds[i] == kind:
            return 0
        self.blocks[i] = jax.device_put(self.blocks[i],
                                        _device_sharding(kind))
        self.kinds[i] = kind
        per_row = self.nbytes // max(self.shape[0], 1)
        return self.blocks[i].shape[0] * per_row

    def update(self, x: jax.Array) -> "TieredArray":
        """Write a new value back, preserving the block placement."""
        x = jnp.asarray(x, dtype=self.dtype).reshape(self.shape)
        out_blocks = []
        start = 0
        for b, kind in zip(self.blocks, self.kinds):
            stop = start + b.shape[0]
            out_blocks.append(
                jax.device_put(x[start:stop], _device_sharding(kind)))
            start = stop
        return TieredArray(out_blocks, list(self.kinds), self.shape,
                           self.dtype)

    # ------------------------------------------------------------------ #
    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * jnp.dtype(self.dtype).itemsize

    def bytes_on(self, kind: str) -> int:
        per_row = self.nbytes // max(self.shape[0], 1)
        return sum(b.shape[0] * per_row
                   for b, k in zip(self.blocks, self.kinds) if k == kind)

    def fast_fraction(self) -> float:
        return self.bytes_on("device") / max(self.nbytes, 1)


def place_pytree(tree, shares_fn, block_rows: Optional[int] = None):
    """Place every leaf of a pytree: shares_fn(path, leaf) -> share list."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    placed = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        placed.append(TieredArray.place(leaf, shares_fn(name, leaf),
                                        block_rows))
    return jax.tree_util.tree_unflatten(treedef, placed)


def gather_pytree(tree):
    return jax.tree.map(
        lambda t: t.gather() if isinstance(t, TieredArray) else t, tree,
        is_leaf=lambda t: isinstance(t, TieredArray))
