"""Data-object descriptors — the unit of placement in the paper's OLI policy.

A *data object* is a named array (or logical group of arrays, e.g. "all
optimizer moments") together with the information the paper's §V-B selection
criteria need:

  * footprint          (bytes)
  * bytes touched per step, split into streaming vs random access
  * latency sensitivity (random/pointer-chasing access => latency-bound)

The per-step access volumes are *exact* for our workloads: a training or
serving step has a static dataflow, so unlike the paper (which instruments
with profiling) we derive them analytically from the model config.  That is
the "application semantics" §V-B says should guide interleaving.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Tuple


@dataclasses.dataclass
class DataObject:
    """One placeable object."""

    name: str
    nbytes: int
    # Per-step traffic generated against this object's home tier(s).
    read_bytes_per_step: int = 0
    write_bytes_per_step: int = 0
    # Fraction of accesses that are random/indirect (CG-style) rather than
    # streaming (MG-style).  Drives latency- vs bandwidth-sensitivity.
    random_fraction: float = 0.0
    # Pinning: some objects must live on the fast tier (e.g. SSM decode
    # state: tiny and on the critical path every token).
    pin_fast: bool = False
    # group tag, e.g. "params" / "opt_state" / "kv_cache" / "activations"
    group: str = "misc"

    @property
    def bytes_per_step(self) -> int:
        return self.read_bytes_per_step + self.write_bytes_per_step

    @property
    def intensity(self) -> float:
        """Accesses per resident byte per step — the paper's 'intensive' axis."""
        if self.nbytes == 0:
            return 0.0
        return self.bytes_per_step / self.nbytes

    @property
    def latency_sensitive(self) -> bool:
        return self.random_fraction > 0.5

    @property
    def bandwidth_hungry(self) -> bool:
        return (not self.latency_sensitive) and self.bytes_per_step > 0


def total_footprint(objs: Iterable[DataObject]) -> int:
    return sum(o.nbytes for o in objs)


def select_interleave_candidates(objs: List[DataObject],
                                 footprint_threshold: float = 0.10,
                                 top_k: Optional[int] = None
                                 ) -> List[DataObject]:
    """The paper's §V-B two-criteria selection.

    1. footprint >= `footprint_threshold` of total memory consumption;
    2. among those, the most access-intensive (largest per-step traffic);
       multiple objects may be selected (paper: Table III last column).
    Latency-sensitive (random-access) and pinned objects are excluded — they
    are exactly the objects §V-A observation 3 says should be *gathered* in
    one node, not spread.
    """
    total = max(total_footprint(objs), 1)
    big = [o for o in objs
           if o.nbytes / total >= footprint_threshold
           and not o.pin_fast and not o.latency_sensitive
           and o.bytes_per_step > 0]
    big.sort(key=lambda o: o.bytes_per_step, reverse=True)
    if top_k is not None:
        big = big[:top_k]
    return big


# ---------------------------------------------------------------------- #
# Object inventories for the paper's workload families.                   #
# ---------------------------------------------------------------------- #
def hpc_workload_objects(name: str) -> List[DataObject]:
    """Table III: the seven HPC dwarfs with their bandwidth-hungry objects.

    Footprints are the paper's (Class E / D); per-step traffic is modeled as
    `sweeps` full passes over each hungry object per iteration; the rest of
    the footprint gets background traffic.
    """
    G = 1024**3

    def mk(total_G, hungry: List[Tuple[str, float]], rand=0.0, sweeps=1.0):
        objs = []
        hungry_total = 0.0
        for nm, sz in hungry:
            objs.append(DataObject(
                name=nm, nbytes=int(sz * G),
                read_bytes_per_step=int(sz * G * sweeps),
                write_bytes_per_step=int(sz * G * sweeps * 0.5),
                random_fraction=rand, group="hpc"))
            hungry_total += sz
        rest = max(total_G - hungry_total, 0.0)
        if rest > 0:
            # the non-hungry residue (index arrays, metadata, temporaries)
            # is latency-sensitive and ALLOCATED LAST — under LDRAM
            # pressure 'preferred' pushes exactly this onto CXL (the
            # paper's OLI-observation-2 reason 1).
            objs.append(DataObject(
                name="rest", nbytes=int(rest * G),
                read_bytes_per_step=int(rest * G * 0.5),
                random_fraction=max(rand, 0.6), group="hpc"))
        return objs

    table = {
        # unit-strided dense accesses
        "BT": mk(166, [("u", 39.6), ("rsh", 39.6), ("forcing", 39.6)]),
        # indexed loads/stores, compressed matrices: mostly streaming
        # within compressed rows, light indirection
        "LU": mk(134, [("u", 39.6), ("rsd", 39.6)], rand=0.15),
        # irregular indirect indexing -> latency-sensitive
        "CG": mk(134, [("a", 48.9)], rand=0.9),
        # structured grid sweeps, bandwidth-hungry
        "MG": mk(210, [("v", 64.2), ("r", 73.4)], sweeps=2.0),
        "SP": mk(174, [("u", 39.6), ("rsh", 39.6), ("forcing", 39.6)]),
        # bandwidth-consuming transpose
        "FT": mk(80, [("u0", 32.0), ("u1", 32.0)], sweeps=2.0),
        # Monte Carlo random trials over nuclide grids
        "XSBench": mk(116, [("nuclide_grids", 60.0)], rand=0.95),
    }
    if name not in table:
        raise ValueError(f"unknown HPC workload {name!r}")
    return table[name]


def llm_train_objects(n_params: int, batch_tokens: int, d_model: int,
                      n_layers: int, optimizer_on_host: bool = True
                      ) -> List[DataObject]:
    """ZeRO-Offload object inventory (Fig. 7): fp16 params/grads on device,
    fp32 master params + moments on the slow tier, activations on device."""
    act_bytes = 2 * batch_tokens * d_model * n_layers * 12  # rough, w/ remat
    return [
        DataObject("params_bf16", 2 * n_params,
                   read_bytes_per_step=2 * n_params * 2,  # fwd+bwd
                   group="params"),
        DataObject("grads_bf16", 2 * n_params,
                   read_bytes_per_step=2 * n_params,
                   write_bytes_per_step=2 * n_params, group="grads"),
        DataObject("master_params_fp32", 4 * n_params,
                   read_bytes_per_step=4 * n_params,
                   write_bytes_per_step=4 * n_params, group="opt_state"),
        DataObject("adam_m_fp32", 4 * n_params,
                   read_bytes_per_step=4 * n_params,
                   write_bytes_per_step=4 * n_params, group="opt_state"),
        DataObject("adam_v_fp32", 4 * n_params,
                   read_bytes_per_step=4 * n_params,
                   write_bytes_per_step=4 * n_params, group="opt_state"),
        DataObject("activations", act_bytes,
                   read_bytes_per_step=act_bytes,
                   write_bytes_per_step=act_bytes,
                   pin_fast=True, group="activations"),
    ]


def llm_serve_objects(n_params: int, kv_bytes: int, act_bytes: int
                      ) -> List[DataObject]:
    """FlexGen object inventory (Fig. 10): weights, KV cache, activations."""
    return [
        DataObject("weights", 2 * n_params,
                   read_bytes_per_step=2 * n_params, group="params"),
        DataObject("kv_cache", kv_bytes,
                   read_bytes_per_step=kv_bytes,
                   write_bytes_per_step=kv_bytes // 64, group="kv_cache"),
        DataObject("activations", act_bytes,
                   read_bytes_per_step=2 * act_bytes,
                   write_bytes_per_step=act_bytes,
                   pin_fast=True, group="activations"),
    ]
