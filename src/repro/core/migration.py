"""Dynamic memory-tiering runtimes (§VI): AutoNUMA / Tiering-0.8 / TPP analogues.

The paper studies hint-fault-driven page migration between fast and slow
tiers and finds (PMO 1-5) that: no single policy wins; Tiering-0.8's
throttling + adaptive promotion threshold wins under first-touch; migration
integrates badly with interleaving (interleaved pages live in unmigratable
regions → hint faults vanish); and migration can *hurt* OLI.

We reproduce that dynamics at block granularity.  A `MigrationSim` holds a
set of blocks with per-tier residency and replays an access trace (block
touch counts per epoch).  Policies decide promotions/demotions per epoch:

  * ``AutoNUMA``    — promote any block touched this epoch (hint fault) with
    probability ∝ sampling rate; no throttle; demote coldest on pressure.
  * ``Tiering08``   — promote only blocks whose re-touch interval < adaptive
    threshold; migration-rate throttle (pages/epoch cap); threshold adapts
    to keep promotion traffic near the target (the patch's dynamic knob).
  * ``TPP``         — promote on touch if block is on the (simulated) active
    LRU list (touched in the previous epoch too); aggressive, higher
    profiling overhead per hint fault.

Faithful quirk (PMO 3): blocks whose placement came from *interleaving* are
flagged `unmigratable` and never produce hint faults — matching the kernel
behaviour the paper uncovered.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .tiers import GB, MemoryTier

# Per-page kernel cost of a migration (unmap, copy setup, TLB shootdown) —
# the stall that makes migration hurt OLI by up to -88% in the paper (PMO 4).
PAGE_BYTES = 4096
PAGE_COST_S = 1.5e-6
# Object-level moves go through huge mappings (THP-sized), so the replanner's
# executor defaults to 2 MiB pages instead of base pages.
HUGE_PAGE_BYTES = 2 * 1024**2


def migration_time_s(nbytes: int, tier: MemoryTier, streams: float = 4.0,
                     page_bytes: int = PAGE_BYTES,
                     page_cost_s: float = PAGE_COST_S) -> float:
    """Time to move `nbytes` through `tier` (bandwidth + per-page kernel work).

    The charging MigrationSim applies per epoch, factored out so the
    telemetry replanner and the serving tierer price moves identically.
    """
    if nbytes <= 0:
        return 0.0
    return (nbytes / (tier.bandwidth(streams) * GB)
            + (nbytes / page_bytes) * page_cost_s)


def coldest_first(blocks: Sequence, last_touch: Callable,
                  touches: Optional[Callable] = None) -> List:
    """Victim order for capacity pressure: least-recently-touched first.

    Shared by MigrationSim's demotion loop and serving.KVBlockTierer;
    accessors bridge the two block dataclasses (last_touch_epoch vs
    last_touch_step)."""
    if touches is None:
        return sorted(blocks, key=last_touch)
    return sorted(blocks, key=lambda b: (last_touch(b), touches(b)))


@dataclasses.dataclass
class Block:
    obj: str
    idx: int
    nbytes: int
    tier: str
    unmigratable: bool = False  # interleaved placement => no hint faults
    last_touch_epoch: int = -(10**9)
    touch_count: int = 0


@dataclasses.dataclass
class MigrationStats:
    hint_faults: int = 0
    promoted: int = 0
    demoted: int = 0
    migrated_bytes: int = 0
    profiling_overhead_s: float = 0.0


class MigrationPolicy:
    name = "no_balance"
    # per-hint-fault CPU cost (s); TPP pays more (paper PMO 2: profiling
    # overhead differentiates the policies).
    fault_cost_s = 2e-6

    def promote_set(self, touched: Sequence[Block], epoch: int,
                    stats: MigrationStats) -> List[Block]:
        return []


class NoBalance(MigrationPolicy):
    name = "no_balance"


class AutoNUMA(MigrationPolicy):
    """Default Linux numa_balancing=1: promote on hint fault, no throttle."""

    name = "autonuma"
    fault_cost_s = 2e-6

    def promote_set(self, touched, epoch, stats):
        stats.hint_faults += len(touched)
        return list(touched)


class Tiering08(MigrationPolicy):
    """Linux tiering-0.8 patch: recency (re-fault interval) + adaptive
    threshold + migration throttle.  59x fewer hint faults than TPP in the
    paper because only slow-tier candidate pages are sampled."""

    name = "tiering08"
    fault_cost_s = 1.5e-6

    def __init__(self, throttle_blocks: int = 64,
                 target_promotions: int = 32):
        self.threshold_epochs = 2
        self.throttle_blocks = throttle_blocks
        self.target = target_promotions

    def promote_set(self, touched, epoch, stats):
        # rate-limited scanning: sample a strided slice of touched blocks,
        # capped per epoch (this is where the paper's 59x hint-fault
        # reduction vs TPP comes from)
        sampled = touched[::3][: self.target]
        stats.hint_faults += len(sampled)
        hot = [b for b in sampled
               if epoch - b.last_touch_epoch <= self.threshold_epochs]
        hot = hot[: self.throttle_blocks]
        # adapt threshold toward the promotion target
        if len(hot) > self.target:
            self.threshold_epochs = max(1, self.threshold_epochs - 1)
        elif len(hot) < self.target // 2:
            self.threshold_epochs = min(8, self.threshold_epochs + 1)
        return hot


class TPP(MigrationPolicy):
    """Meta's TPP: promote on touch if on active list (touched last epoch);
    every touch is a hint fault -> large profiling overhead (PMO 2)."""

    name = "tpp"
    fault_cost_s = 4e-6

    def promote_set(self, touched, epoch, stats):
        stats.hint_faults += len(touched)
        return [b for b in touched if epoch - b.last_touch_epoch <= 1]


@dataclasses.dataclass
class SimResult:
    exec_time_s: float
    stats: MigrationStats
    fast_hit_fraction: float


class MigrationSim:
    """Replays an access trace over blocks under a migration policy.

    access_trace: per epoch, a dict {block_id: touches}.  Block ids are
    (obj, idx).  Execution time per epoch = time to serve the touched bytes
    from their current tiers (parallel-tier composition, as costmodel) plus
    migration traffic plus per-fault profiling overhead.
    """

    def __init__(self, blocks: Sequence[Block],
                 tiers: Mapping[str, MemoryTier], fast: str,
                 policy: MigrationPolicy,
                 fast_capacity_bytes: Optional[int] = None,
                 slow_tier: Optional[str] = None):
        self.blocks = {(b.obj, b.idx): b for b in blocks}
        self.tiers = dict(tiers)
        self.fast = fast
        self.policy = policy
        cap = (fast_capacity_bytes if fast_capacity_bytes is not None
               else int(tiers[fast].capacity_GiB * (1024**3)))
        self.fast_capacity = cap
        # demotion target: the slow tier blocks actually came from (CXL in
        # the paper's two-tier setup), not an arbitrary other node
        if slow_tier is None:
            slow_counts: Dict[str, int] = {}
            for b in blocks:
                if b.tier != fast:
                    slow_counts[b.tier] = slow_counts.get(b.tier, 0) + 1
            slow_tier = max(slow_counts, key=slow_counts.get) \
                if slow_counts else fast
        self.slow_tier = slow_tier
        self.stats = MigrationStats()

    def _fast_usage(self) -> int:
        return sum(b.nbytes for b in self.blocks.values()
                   if b.tier == self.fast)

    def run(self, access_trace: Sequence[Mapping[Tuple[str, int], int]],
            streams: int = 32) -> SimResult:
        total_time = 0.0
        fast_bytes_served = 0
        total_bytes_served = 0

        for epoch, trace in enumerate(access_trace):
            # --- serve accesses from current residency --------------------
            per_tier = {t: 0.0 for t in self.tiers}
            for bid, touches in trace.items():
                b = self.blocks[bid]
                served = b.nbytes * touches
                per_tier[b.tier] += served
                total_bytes_served += served
                if b.tier == self.fast:
                    fast_bytes_served += served
            epoch_t = 0.0
            for t, by in per_tier.items():
                if by > 0:
                    bw = self.tiers[t].bandwidth(
                        min(streams, self.tiers[t].saturation_streams * 1.5)
                    ) * GB
                    epoch_t = max(epoch_t, by / bw)

            # --- hint faults & promotion decision -------------------------
            touched_slow = [self.blocks[bid] for bid in trace
                            if self.blocks[bid].tier != self.fast
                            and not self.blocks[bid].unmigratable]
            promoted = self.policy.promote_set(touched_slow, epoch,
                                               self.stats)
            # capacity pressure: demote coldest fast blocks to make room
            mig_bytes = 0
            for b in promoted:
                need = b.nbytes
                usage = self._fast_usage()
                if usage + need > self.fast_capacity:
                    victims = coldest_first(
                        [v for v in self.blocks.values()
                         if v.tier == self.fast and not v.unmigratable],
                        last_touch=lambda v: v.last_touch_epoch)
                    freed = 0
                    for v in victims:
                        if usage + need - freed <= self.fast_capacity:
                            break
                        v.tier = self.slow_tier
                        freed += v.nbytes
                        mig_bytes += v.nbytes
                        self.stats.demoted += 1
                    if usage + need - freed > self.fast_capacity:
                        continue  # cannot promote
                b.tier = self.fast
                mig_bytes += b.nbytes
                self.stats.promoted += 1

            # --- update recency AFTER decisions (re-fault interval) -------
            for bid, touches in trace.items():
                b = self.blocks[bid]
                b.last_touch_epoch = epoch
                b.touch_count += touches

            # migration traffic rides the slow tier's bandwidth, and each
            # migrated 4 KiB page pays ~1.5us of kernel work (unmap, copy
            # setup, TLB shootdown) — this stall is why the paper sees up
            # to -88% from migration under OLI (PMO 4).
            if mig_bytes:
                epoch_t += migration_time_s(mig_bytes,
                                            self.tiers[self.slow_tier])
            epoch_t += (self.stats.hint_faults * self.policy.fault_cost_s
                        ) / max(epoch + 1, 1) * 0.1
            self.stats.migrated_bytes += mig_bytes
            total_time += epoch_t

        self.stats.profiling_overhead_s = (
            self.stats.hint_faults * self.policy.fault_cost_s)
        total_time += self.stats.profiling_overhead_s
        frac = fast_bytes_served / max(total_bytes_served, 1)
        return SimResult(total_time, self.stats, frac)


# ---------------------------------------------------------------------- #
# Trace generators matching the paper's §VI workload taxonomy.            #
# ---------------------------------------------------------------------- #
def make_blocks_from_plan(plan_shares: Mapping[str, List[Tuple[str, float]]],
                          obj_bytes: Mapping[str, int],
                          block_bytes: int = 64 * 1024**2,
                          interleaved_objs: Sequence[str] = ()
                          ) -> List[Block]:
    """Blocks with initial residency from a PlacementPlan's shares.

    Blocks of objects placed by *interleaving* are marked unmigratable
    (PMO 3: interleaved pages never fault).
    """
    blocks: List[Block] = []
    for obj, shares in plan_shares.items():
        total = obj_bytes[obj]
        n = max(1, total // block_bytes)
        # expand shares into per-block tier assignment round-robin
        tier_seq: List[str] = []
        for t, frac in shares:
            tier_seq.extend([t] * max(1, int(round(frac * n))))
        interleaved = obj in interleaved_objs and len(
            {t for t, _ in shares}) > 1
        for i in range(n):
            tier = tier_seq[i % len(tier_seq)] if tier_seq else shares[0][0]
            blocks.append(Block(obj, i, total // n, tier,
                                unmigratable=interleaved))
    return blocks


def trace_stable_hotset(block_ids: Sequence[Tuple[str, int]], epochs: int,
                        hot_fraction: float = 0.1, seed: int = 0
                        ) -> List[Dict[Tuple[str, int], int]]:
    """PageRank-like: small, stable hot set (first-touch wins, PMO 1)."""
    rng = np.random.default_rng(seed)
    ids = list(block_ids)
    hot = ids[: max(1, int(len(ids) * hot_fraction))]
    out = []
    for _ in range(epochs):
        tr = {b: 8 for b in hot}
        for b in rng.choice(len(ids), size=max(1, len(ids) // 20),
                            replace=False):
            tr[ids[int(b)]] = tr.get(ids[int(b)], 0) + 1
        out.append(tr)
    return out


def trace_scattered_hotset(block_ids: Sequence[Tuple[str, int]], epochs: int,
                           hot_fraction: float = 0.2, seed: int = 0,
                           drift: float = 0.3
                           ) -> List[Dict[Tuple[str, int], int]]:
    """Graph500-like: scattered hot set drifting across tiers (interleave+
    migration wins)."""
    rng = np.random.default_rng(seed)
    ids = list(block_ids)
    k = max(1, int(len(ids) * hot_fraction))
    hot = set(rng.choice(len(ids), size=k, replace=False).tolist())
    out = []
    for _ in range(epochs):
        tr = {ids[i]: 4 for i in hot}
        out.append(tr)
        moved = set(rng.choice(len(ids), size=max(1, int(k * drift)),
                               replace=False).tolist())
        hot = set(list(hot)[: k - len(moved)]) | moved
    return out


def trace_uniform(block_ids: Sequence[Tuple[str, int]], epochs: int,
                  seed: int = 0) -> List[Dict[Tuple[str, int], int]]:
    """FT/SP-like: uniformly touched working set (migration only hurts)."""
    return [{b: 2 for b in block_ids} for _ in range(epochs)]


# ---------------------------------------------------------------------- #
# Reusable placement-delta executor.                                      #
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class BlockMove:
    """One object-level byte move between tiers."""

    obj: str
    src: str
    dst: str
    nbytes: int


@dataclasses.dataclass
class PlacementDelta:
    """The byte moves that turn one placement into another."""

    moves: List[BlockMove]

    @property
    def total_bytes(self) -> int:
        return sum(m.nbytes for m in self.moves)

    def bytes_into(self, tier: str) -> int:
        return sum(m.nbytes for m in self.moves if m.dst == tier)

    def bytes_out_of(self, tier: str) -> int:
        return sum(m.nbytes for m in self.moves if m.src == tier)


class MigrationExecutor:
    """Computes, prices, and applies placement deltas between plans.

    Extracted from the move/price mechanics MigrationSim and
    serving.KVBlockTierer each grew privately, so the telemetry
    replanner, the KV pool, and the simulators share one executor:

      * ``delta(old, new, nbytes)``  — per-object byte moves between two
        ``PlacementPlan.shares``-style mappings (greedy surplus->deficit
        matching; objects absent from either side produce no moves —
        allocation is not migration);
      * ``cost_s(delta)``            — migration_time_s charging, each
        move priced at the *slower* endpoint tier (the copy rides the
        slow link, exactly how MigrationSim charges demotions); with a
        ``topology`` the move is priced over its actual path — every
        per-page setup pays the path's round-trip latency, and moves
        whose paths cross one link (or endpoint tier) *serialize* on it
        while moves on disjoint paths proceed in parallel;
      * ``execute(delta)``           — applies moves through ``move_fn``
        (e.g. PagedKVPool.migrate, or a TieredArray re-place); without
        one it only accounts.  ``move_fn(obj, src, dst, nbytes)`` returns
        the bytes actually moved (capacity may deny part of a move); the
        per-move outcome is kept in ``last_moves`` so a planner can feed
        the *realized* placement back into its next costing pass.
    """

    def __init__(self, tiers: Mapping[str, MemoryTier],
                 streams: float = 4.0,
                 page_bytes: int = HUGE_PAGE_BYTES,
                 page_cost_s: float = PAGE_COST_S,
                 move_fn: Optional[Callable[[str, str, str, int], int]]
                 = None,
                 topology=None):
        self.tiers = dict(tiers)
        self.streams = streams
        self.page_bytes = page_bytes
        self.page_cost_s = page_cost_s
        self.move_fn = move_fn
        self.topology = topology   # repro.topology.TopologyGraph or None
        self.tracer = None         # optional repro.obs.TraceRecorder
        self.audit = None          # optional repro.obs.PredictionLedger
        self.calibrator = None     # optional obs.CostModelCalibrator
        # True when move_fn performs real transfers whose wall time is
        # comparable to the model's seconds (e.g. TieredStateStore's
        # device_put re-placements) — gates wall-clock audit joins and
        # the online calibration feed; bookkeeping move_fns leave it off
        self.physical_moves = False
        # the un-calibrated parameters recalibrate() corrects from
        self._base_tiers = dict(tiers)
        self._base_topology = topology
        self._executions = 0
        self.stats = MigrationStats()
        # (move, bytes actually moved) for the most recent execute()
        self.last_moves: List[Tuple[BlockMove, int]] = []

    def recalibrate(self) -> None:
        """Swap pricing parameters for the calibrator's corrected view.

        Idempotent and cheap; the owner calls it after a probe fit or
        whenever online scales moved (e.g. each replan epoch), so
        ``cost_s`` / ``move_cost_s`` / fluid schedules price with
        measured numbers.  Without a calibrator it is a no-op."""
        if self.calibrator is None:
            return
        self.tiers, self.topology = self.calibrator.calibrated_view(
            self._base_tiers, self._base_topology)

    # ------------------------------------------------------------------ #
    @staticmethod
    def _tier_bytes(shares: Sequence[Tuple[str, float]],
                    total: int) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for t, frac in shares:
            out[t] = out.get(t, 0) + int(round(frac * total))
        return out

    def delta(self, old_shares: Mapping[str, Sequence[Tuple[str, float]]],
              new_shares: Mapping[str, Sequence[Tuple[str, float]]],
              nbytes_by_obj: Mapping[str, int]) -> PlacementDelta:
        moves: List[BlockMove] = []
        for obj, total in nbytes_by_obj.items():
            if obj not in old_shares or obj not in new_shares:
                continue
            old = self._tier_bytes(old_shares[obj], total)
            new = self._tier_bytes(new_shares[obj], total)
            surplus = {t: old.get(t, 0) - new.get(t, 0)
                       for t in set(old) | set(new)
                       if old.get(t, 0) > new.get(t, 0)}
            deficit = {t: new.get(t, 0) - old.get(t, 0)
                       for t in set(old) | set(new)
                       if new.get(t, 0) > old.get(t, 0)}
            for src in sorted(surplus):
                for dst in sorted(deficit):
                    if surplus[src] <= 0:
                        break
                    take = min(surplus[src], deficit[dst])
                    if take > 0:
                        moves.append(BlockMove(obj, src, dst, take))
                        surplus[src] -= take
                        deficit[dst] -= take
        return PlacementDelta(moves)

    def _slow_endpoint(self, move: BlockMove) -> MemoryTier:
        src, dst = self.tiers.get(move.src), self.tiers.get(move.dst)
        if src is None or dst is None:
            return src or dst
        return src if (src.bandwidth(self.streams)
                       <= dst.bandwidth(self.streams)) else dst

    def cost_s(self, delta: PlacementDelta) -> float:
        if self.topology is None:
            total = 0.0
            for m in delta.moves:
                tier = self._slow_endpoint(m)
                if tier is None:
                    continue
                total += migration_time_s(m.nbytes, tier, self.streams,
                                          self.page_bytes,
                                          self.page_cost_s)
            return total
        return self._path_cost_s(delta)

    def move_resource_times(self, m: BlockMove
                            ) -> Tuple[Dict[object, float], float]:
        """One move's per-resource occupancy seconds plus its fixed
        overhead (per-page kernel work + path round-trip latency).

        The building block ``cost_s`` and the cross-tenant
        ``pool.MoveScheduler`` both price with: a resource is an
        endpoint tier or a traversed link, moves sharing one serialize
        on it, moves on disjoint resources overlap.  Without a
        topology the single resource is the slower endpoint tier (the
        copy rides it), matching the flat-tier charging.
        """
        res_time: Dict[object, float] = {}
        if m.nbytes <= 0:
            return res_time, 0.0
        if self.topology is None:
            tier = self._slow_endpoint(m)
            if tier is None:
                return res_time, 0.0
            res_time[("tier", tier.name)] = \
                m.nbytes / (tier.bandwidth(self.streams) * GB)
            return res_time, (m.nbytes / self.page_bytes) * self.page_cost_s
        links = self.topology.tier_path(m.src, m.dst)
        pages = -(-m.nbytes // self.page_bytes)   # ceil
        lat_ns = sum(l.latency_ns for l in links)
        overhead = pages * (self.page_cost_s + 2.0 * lat_ns * 1e-9)
        for tname in (m.src, m.dst):
            tier = self.tiers.get(tname)
            if tier is None:
                continue
            bw = tier.bandwidth(self.streams) * GB
            key = ("tier", tname)
            res_time[key] = res_time.get(key, 0.0) + m.nbytes / bw
        for link in links:
            key = ("link", link.key)
            res_time[key] = res_time.get(key, 0.0) \
                + m.nbytes / (link.bw_GBps * GB)
        return res_time, overhead

    def move_resources(self, m: BlockMove) -> List[object]:
        """The resource keys one move occupies (for grouping/ordering)."""
        return list(self.move_resource_times(m)[0])

    def move_cost_s(self, m: BlockMove) -> float:
        """One move priced alone (bottleneck resource + overhead)."""
        if self.topology is None:
            tier = self._slow_endpoint(m)
            if tier is None or m.nbytes <= 0:
                return 0.0
            return migration_time_s(m.nbytes, tier, self.streams,
                                    self.page_bytes, self.page_cost_s)
        res_time, overhead = self.move_resource_times(m)
        return (max(res_time.values()) if res_time else 0.0) + overhead

    def _path_cost_s(self, delta: PlacementDelta) -> float:
        """Topology pricing: bandwidth charged per traversed resource
        (endpoint tiers + every link on the path), per-page kernel work
        plus the path's round-trip latency per page.  Resources compose
        like the cost model's tiers: moves sharing a resource serialize
        on it, disjoint moves overlap — so two promotions squeezing
        through one UPI hop take twice as long, while promotions into
        different sockets proceed concurrently."""
        res_time: Dict[object, float] = {}
        overhead = 0.0
        for m in delta.moves:
            r, oh = self.move_resource_times(m)
            overhead += oh
            for key, t in r.items():
                res_time[key] = res_time.get(key, 0.0) + t
        return (max(res_time.values()) if res_time else 0.0) + overhead

    def tier_rank(self) -> Dict[str, int]:
        """Tiers ranked fastest (0) to slowest — the promote/demote
        classification view.  Needs the *distance* view: with
        local-normalized tier descriptors the hop latency lives in the
        topology, and fast/slow would tie without it."""
        rank_tiers = (self.topology.effective_tiers(self.tiers)
                      if self.topology is not None else self.tiers)
        order = sorted(rank_tiers,
                       key=lambda k: (rank_tiers[k].unloaded_latency_ns
                                      + rank_tiers[k].hop_latency_ns,
                                      -rank_tiers[k].peak_bw_GBps))
        return {t: i for i, t in enumerate(order)}

    def execute(self, delta: PlacementDelta,
                stats: Optional[MigrationStats] = None) -> MigrationStats:
        stats = stats if stats is not None else self.stats
        rank = self.tier_rank()
        self.last_moves = []
        # audit the priced move time against the realized wall time —
        # only meaningful when move_fn performs real transfers
        audited = (self.audit is not None and self.physical_moves
                   and self.move_fn is not None and delta.moves)
        if audited:
            self._executions += 1
            key = self._executions
            predicted = self.cost_s(delta)
            self.audit.predict("migration.move_time", key, predicted,
                               moves=len(delta.moves),
                               nbytes=delta.total_bytes)
            t0 = time.perf_counter()
        for m in delta.moves:
            done = (self.move_fn(m.obj, m.src, m.dst, m.nbytes)
                    if self.move_fn is not None else m.nbytes)
            self.last_moves.append((m, max(int(done), 0)))
            if self.tracer is not None:
                self.tracer.event(
                    "migration.move", cat="migration", obj=m.obj,
                    src=m.src, dst=m.dst, nbytes=m.nbytes,
                    done_bytes=max(int(done), 0))
            if done <= 0:
                continue
            stats.migrated_bytes += int(done)
            if rank.get(m.dst, 0) < rank.get(m.src, 0):
                stats.promoted += 1
            elif rank.get(m.dst, 0) > rank.get(m.src, 0):
                stats.demoted += 1
        if audited:
            realized = time.perf_counter() - t0
            touched = sorted({t for m in delta.moves
                              for t in (m.src, m.dst)})
            self.audit.realize("migration.move_time", key, realized,
                               resources=touched)
            if self.calibrator is not None and predicted > 0.0:
                self.calibrator.observe_time_ratio(realized / predicted,
                                                   tiers=touched)
                self.recalibrate()
        return stats

    @staticmethod
    def realized_shares(
            old_shares: Mapping[str, Sequence[Tuple[str, float]]],
            moves_done: Sequence[Tuple[BlockMove, int]],
            nbytes_by_obj: Mapping[str, int]
    ) -> Dict[str, List[Tuple[str, float]]]:
        """The placement that actually resulted from a (possibly
        partially denied) execute: old residency plus the bytes each
        move really transferred.  Feeding this — not the intended plan —
        into the next costing pass keeps the planner honest when the
        fast-block budget rejects part of a delta."""
        out: Dict[str, List[Tuple[str, float]]] = {}
        done_by_obj: Dict[str, List[Tuple[BlockMove, int]]] = {}
        for m, done in moves_done:
            done_by_obj.setdefault(m.obj, []).append((m, done))
        for obj, shares in old_shares.items():
            total = int(nbytes_by_obj.get(obj, 0))
            if total <= 0:
                out[obj] = list(shares)
                continue
            tier_bytes = MigrationExecutor._tier_bytes(shares, total)
            for m, done in done_by_obj.get(obj, ()):
                moved = min(done, max(tier_bytes.get(m.src, 0), 0))
                if moved <= 0:
                    continue
                tier_bytes[m.src] -= moved
                tier_bytes[m.dst] = tier_bytes.get(m.dst, 0) + moved
            out[obj] = [(t, b / total) for t, b in tier_bytes.items()
                        if b > 0]
        return out
