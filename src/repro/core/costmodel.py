"""Analytic step-time cost model over a PlacementPlan.

Evaluates what the paper measures: given data objects, their per-step
traffic, and a placement plan, estimate per-step memory time per tier and
the end-to-end step time.  Used by:

  * the OLI planner's policy comparison (benchmarks/oli_hpc.py → Figs 13-15),
  * the FlexGen-style serving policy search (offload/serve_engine.py),
  * the ZeRO-Offload train-time breakdown (benchmarks/zero_offload_train.py).

Model (deliberately simple, mirrors the paper's reasoning):
  - streaming traffic to tier T takes bytes / bandwidth(streams_T);
  - random traffic pays loaded-latency per cache line, amortized over
    concurrent misses;
  - tiers serve in parallel (each has its own controller/queue), so total
    memory time = max over tiers (bandwidth-bound composition), PLUS a
    serial latency term for dependent (pointer-chasing) access chains;
  - compute can overlap memory up to `compute_time_s`.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from .objects import DataObject
from .policies import PlacementPlan, Policy
from .tiers import GB, MemoryTier


@dataclasses.dataclass
class StepCost:
    """Decomposed per-step cost (seconds)."""

    per_tier_time: Dict[str, float]
    latency_serial_s: float
    compute_s: float
    phased_s: float = 0.0   # sum over object phases of max-tier time
    # per shared interconnect link (topology mode): traffic crossing one
    # link serializes on it even when the endpoint tiers are independent
    link_time: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def memory_s(self) -> float:
        base = max(self.per_tier_time.values()) if self.per_tier_time \
            else 0.0
        if self.link_time:
            base = max(base, max(self.link_time.values()))
        return max(base, self.phased_s) + self.latency_serial_s

    @property
    def step_s(self) -> float:
        # memory and compute overlap; the longer one gates the step
        return max(self.memory_s, self.compute_s) + 0.15 * min(
            self.memory_s, self.compute_s)  # imperfect overlap tax

    @property
    def bound(self) -> str:
        return "memory" if self.memory_s >= self.compute_s else "compute"


def plan_step_cost(objs: Sequence[DataObject], plan: PlacementPlan,
                   tiers: Mapping[str, MemoryTier],
                   total_streams: int = 32,
                   compute_time_s: float = 0.0,
                   topology=None, origin: Optional[str] = None,
                   calibrator=None) -> StepCost:
    """Evaluate a placement plan with PHASED access semantics.

    HPC sweeps touch objects in phases (one array at a time), so the step
    time is the SUM over objects of each object's access time; within one
    object's phase the tiers holding its pages serve in parallel (gated by
    the slowest share — this is why uniform 50/50 interleave with a slow
    CXL card undermines performance, Sec. V takeaway), and random accesses
    pay loaded latency per cache line with `total_streams` outstanding
    misses (CG-style latency sensitivity).

    With a ``topology`` (a ``repro.topology.TopologyGraph``) the tiers
    are first distance-adjusted as seen from ``origin`` (path latency,
    bottleneck bandwidth), and traffic is additionally charged against
    every interconnect link it crosses: tiers behind one UPI/PCIe hop
    *interfere* instead of serving in parallel, within an object's
    phase and across the step.

    With a ``calibrator`` (``repro.obs.calibrate.CostModelCalibrator``)
    the tier descriptors and the graph's link parameters are replaced
    by their probe-fitted / online-corrected versions first, so the
    step price reflects measured hardware instead of builder defaults.
    """
    if calibrator is not None:
        tiers, topology = calibrator.calibrated_view(tiers, topology)
    tier_links = {}
    if topology is not None:
        tiers = topology.effective_tiers(tiers, origin)
        tier_links = {t: topology.tier_links(t, origin) for t in tiers}
    per_tier_time: Dict[str, float] = {k: 0.0 for k in tiers}
    link_time: Dict[str, float] = {}
    lat_serial = 0.0
    phased_total = 0.0
    any_traffic = False
    for o in objs:
        if o.bytes_per_step <= 0:
            continue
        any_traffic = True
        phase_t = 0.0
        phase_link_t: Dict[str, float] = {}
        for t, frac in plan.shares.get(o.name, []):
            tier = tiers[t]
            b = o.bytes_per_step * frac
            if b <= 0:
                continue
            streams = max(1.0, min(float(total_streams),
                                   tier.saturation_streams * 1.5))
            bw = tier.bandwidth(streams) * GB
            t_stream = (b * (1.0 - o.random_fraction)) / bw
            lat_ns = tier.loaded_latency(tier.bandwidth(streams) * 0.6)
            t_rand = (b * o.random_fraction / 64.0) * (lat_ns * 1e-9) \
                / total_streams
            share_t = t_stream + t_rand
            per_tier_time[t] += share_t
            phase_t = max(phase_t, share_t)
            for link in tier_links.get(t, ()):
                key = f"{link.key[0]}--{link.key[1]}"
                lt = b / (link.bw_GBps * GB)
                link_time[key] = link_time.get(key, 0.0) + lt
                phase_link_t[key] = phase_link_t.get(key, 0.0) + lt
            # truly serial pointer-chase slice of the random misses:
            # indirect-index chains have limited MLP, so ~2% of misses
            # serialize on the loaded latency — this is what makes random
            # access on CXL catastrophic (HPC observation 3 / CG).
            lat_serial += (b * o.random_fraction / 64.0) * (
                lat_ns * 1e-9) * 0.02
        if phase_link_t:
            phase_t = max(phase_t, max(phase_link_t.values()))
        phased_total += phase_t

    if not any_traffic:
        return StepCost({k: 0.0 for k in tiers}, 0.0, compute_time_s)
    return StepCost(per_tier_time, lat_serial, compute_time_s,
                    phased_s=phased_total, link_time=link_time)


def compare_policies(objs: Sequence[DataObject],
                     policies: Sequence[Policy],
                     tiers: Mapping[str, MemoryTier],
                     total_streams: int = 32,
                     compute_time_s: float = 0.0,
                     topology=None, origin: Optional[str] = None
                     ) -> Dict[str, StepCost]:
    out = {}
    for p in policies:
        plan = p.plan(objs, tiers)
        out[p.name] = plan_step_cost(objs, plan, tiers, total_streams,
                                     compute_time_s, topology=topology,
                                     origin=origin)
    return out


# ---------------------------------------------------------------------- #
# FlexGen-style placement search (§IV-B): choose per-object tier fractions
# to maximize throughput under capacity constraints.  The paper uses an LP;
# our decision space is small enough for exact search over a fraction grid,
# which is LP-equivalent here (piecewise-linear objective) and dependency-
# free.                                                                    #
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class SearchResult:
    fractions: Dict[str, Dict[str, float]]  # obj -> tier -> fraction
    step_s: float
    plan: PlacementPlan


def policy_search(objs: Sequence[DataObject],
                  tiers: Mapping[str, MemoryTier],
                  fast: str,
                  grid: int = 10,
                  total_streams: int = 32,
                  compute_time_s: float = 0.0,
                  topology=None, origin: Optional[str] = None,
                  calibrator=None) -> SearchResult:
    """Grid search over fast-tier fractions per movable object.

    Mirrors FlexGen's cost-model-driven search: for each non-pinned object,
    try fast-fractions k/grid; spill the remainder across slow tiers in
    NUMA-distance order.  Objective: minimize modeled step time subject to
    capacities.  Complexity grid^n_movable — we cap movable objects at 4 by
    taking the largest (everything else fast-preferred), matching FlexGen's
    weights/KV/activation granularity.

    With a ``topology``, spill order and candidate costing both use the
    distance-adjusted (path-aware) view from ``origin`` — a far-socket
    CXL card spills *after* remote DRAM, and plans that route traffic
    over a shared hop are priced with that hop's serialization.

    A ``calibrator`` swaps both the tiers and the graph for their
    measured-corrected versions before the search, so the chosen plan
    optimizes real numbers (capacities stay the builder's — calibration
    corrects speeds, not sizes).
    """
    from .policies import _tier_order  # local import to avoid cycle

    if calibrator is not None:
        tiers, topology = calibrator.calibrated_view(tiers, topology)
    search_tiers = (topology.effective_tiers(tiers, origin)
                    if topology is not None else tiers)
    order = _tier_order(search_tiers)
    slow_order = [t for t in order if t != fast]
    movable = sorted([o for o in objs if not o.pin_fast],
                     key=lambda o: o.nbytes, reverse=True)[:4]
    fixed = [o for o in objs if o not in movable]
    cap = {k: int(tiers[k].capacity_GiB * (1024**3)) for k in tiers}

    best: Optional[SearchResult] = None
    fracs = [i / grid for i in range(grid + 1)]
    for combo in itertools.product(fracs, repeat=len(movable)):
        free = dict(cap)
        shares: Dict[str, List[Tuple[str, float]]] = {}
        placed = {k: 0 for k in tiers}
        feasible = True

        def put(o: DataObject, fast_frac: float) -> bool:
            nonlocal feasible
            sh = []
            fb = int(o.nbytes * fast_frac)
            if fb > free[fast]:
                return False
            if fb:
                sh.append((fast, fast_frac))
                free[fast] -= fb
                placed[fast] += fb
            rem = o.nbytes - fb
            for t in slow_order:
                if rem <= 0:
                    break
                take = min(rem, free[t])
                if take > 0:
                    sh.append((t, take / max(o.nbytes, 1)))
                    free[t] -= take
                    placed[t] += take
                    rem -= take
            if rem > 0:
                return False
            shares[o.name] = sh
            return True

        for o in fixed:  # pinned/fixed objects first, fully fast
            if not put(o, 1.0):
                feasible = False
                break
        if feasible:
            for o, f in zip(movable, combo):
                if not put(o, f):
                    feasible = False
                    break
        if not feasible:
            continue
        plan = PlacementPlan(shares, "search", placed)
        cost = plan_step_cost(objs, plan, tiers, total_streams,
                              compute_time_s, topology=topology,
                              origin=origin)
        if best is None or cost.step_s < best.step_s:
            best = SearchResult(
                {o.name: dict(shares[o.name]) for o in movable},
                cost.step_s, plan)
    if best is None:
        raise RuntimeError("no feasible placement (capacity too small)")
    return best
