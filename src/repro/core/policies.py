"""Placement policies: preferred / first-touch / uniform interleave / OLI.

A policy maps a list of DataObjects onto tiers, producing a PlacementPlan:
for each object, a list of (tier_name, fraction) shares.  Fractions are
block-granular when realized by `tiered_array.TieredArray`; here they are
exact rationals of the object's footprint.

The paper's policies (§V, §VI):

* ``TierPreferred(fast)``  — numactl --preferred analogue: fill `fast` until
  capacity, spill to the next-closest tier (NUMA-distance order).
* ``FirstTouch``           — allocation-order placement into the fastest tier
  with room (Linux default without numactl).
* ``UniformInterleave``    — Linux round-robin page interleave across a tier
  set: every object spread proportional to nothing — equal page shares.
* ``WeightedInterleave``   — Linux weighted-interleave analogue: per-node
  shares from explicit weights (usually topology path bandwidth; see
  ``interleave.distance_weighted_policy``).
* ``ObjectLevelInterleave``— THE PAPER'S CONTRIBUTION (§V-B): objects passing
  the two selection criteria (≥10% footprint, access-intensive, not
  latency-sensitive) are interleaved across fast+slow with *bandwidth-
  proportional* shares; everything else is fast-preferred.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from .objects import DataObject, select_interleave_candidates
from .tiers import GiB, MemoryTier


Share = Tuple[str, float]  # (tier name, fraction of object)


@dataclasses.dataclass
class PlacementPlan:
    """Result of planning: object name -> shares; plus bookkeeping."""

    shares: Dict[str, List[Share]]
    policy: str
    # bytes placed per tier (for capacity accounting / reporting)
    tier_bytes: Dict[str, int]

    def fraction_on(self, obj_name: str, tier: str) -> float:
        return sum(f for t, f in self.shares.get(obj_name, []) if t == tier)

    def fast_bytes(self, fast: str) -> int:
        return self.tier_bytes.get(fast, 0)


def _tier_order(tiers: Mapping[str, MemoryTier]) -> List[str]:
    """Tiers ordered by NUMA distance (unloaded latency)."""
    return sorted(tiers, key=lambda k: tiers[k].unloaded_latency_ns
                  + tiers[k].hop_latency_ns)


class Policy:
    name = "base"

    def plan(self, objs: Sequence[DataObject],
             tiers: Mapping[str, MemoryTier]) -> PlacementPlan:
        raise NotImplementedError


class TierPreferred(Policy):
    """Fill `preferred` first; on exhaustion spill in NUMA-distance order.

    Matches the paper's 'LDRAM preferred' / 'CXL preferred' runs.  Objects
    are placed in the order given (allocation order), which is exactly why
    the paper finds 'LDRAM preferred' fragile when fast memory is scarce
    (OLI observation 2 reason 1: latency-sensitive objects allocated late
    end up on CXL).
    """

    def __init__(self, preferred: str):
        self.preferred = preferred
        self.name = f"{preferred}_preferred"

    def plan(self, objs, tiers):
        order = [self.preferred] + [t for t in _tier_order(tiers)
                                    if t != self.preferred]
        free = {k: int(tiers[k].capacity_GiB * GiB) for k in tiers}
        shares: Dict[str, List[Share]] = {}
        placed = {k: 0 for k in tiers}
        for o in objs:
            remaining = o.nbytes
            sh: List[Share] = []
            for t in order:
                if remaining <= 0:
                    break
                take = min(remaining, free[t])
                if take > 0:
                    sh.append((t, take / max(o.nbytes, 1)))
                    free[t] -= take
                    placed[t] += take
                    remaining -= take
            if remaining > 0:  # out of memory everywhere: overflow slowest
                t = order[-1]
                sh.append((t, remaining / max(o.nbytes, 1)))
                placed[t] += remaining
            shares[o.name] = sh
        return PlacementPlan(shares, self.name, placed)


class FirstTouch(TierPreferred):
    """Linux default: first touch = local node preferred, allocation order."""

    def __init__(self, fast: str):
        super().__init__(fast)
        self.name = "first_touch"


class UniformInterleave(Policy):
    """Linux round-robin interleave across `tier_set` (equal page shares),
    subject to capacity (a full tier drops out of the rotation, like the
    kernel's interleave falling back when a node is exhausted)."""

    def __init__(self, tier_set: Sequence[str], name: str = None):
        self.tier_set = list(tier_set)
        self.name = name or ("uniform_interleave[" + "+".join(tier_set) + "]")

    def plan(self, objs, tiers):
        free = {k: int(tiers[k].capacity_GiB * GiB) for k in self.tier_set}
        shares: Dict[str, List[Share]] = {}
        placed = {k: 0 for k in tiers}
        for o in objs:
            live = [t for t in self.tier_set if free[t] > 0]
            if not live:
                live = [self.tier_set[-1]]
            per = o.nbytes // len(live)
            sh = []
            for t in live:
                take = min(per, max(free[t], 0)) if free[t] > 0 else per
                sh.append((t, take / max(o.nbytes, 1)))
                free[t] -= take
                placed[t] += take
            # distribute rounding remainder to first live tier
            rem = o.nbytes - sum(int(f * o.nbytes) for _, f in sh)
            if rem > 0:
                t = live[0]
                sh[0] = (t, sh[0][1] + rem / max(o.nbytes, 1))
                placed[t] += rem
            shares[o.name] = sh
        return PlacementPlan(shares, self.name, placed)


class WeightedInterleave(Policy):
    """Linux weighted-interleave analogue: per-node page shares set by
    explicit weights instead of round-robin.

    The kernel's ``/sys/kernel/mm/mempolicy/weighted_interleave`` knobs
    expect an operator to type in per-node weights; here they usually
    come from the topology (``interleave.distance_weighted_policy``
    sets them ∝ each tier's path-capped bandwidth from the compute
    origin), which is what makes interleaving stop *undermining*
    performance when one node is a 38 GB/s far-socket CXL card next to
    a 460 GB/s LDRAM (Sec. V takeaway).
    """

    def __init__(self, weights: Mapping[str, float],
                 name: Optional[str] = None):
        w = {t: float(v) for t, v in weights.items() if v > 0}
        if not w:
            raise ValueError("weighted interleave needs positive weights")
        total = sum(w.values())
        self.weights = {t: v / total for t, v in w.items()}
        self.name = name or ("weighted_interleave[" + "+".join(
            f"{t}:{v:.2f}" for t, v in sorted(self.weights.items())) + "]")

    def plan(self, objs, tiers):
        names = [t for t in self.weights if t in tiers]
        if not names:
            raise ValueError("no weighted tiers present in tier set")
        free = {t: int(tiers[t].capacity_GiB * GiB) for t in names}
        shares: Dict[str, List[Share]] = {}
        placed = {k: 0 for k in tiers}
        for o in objs:
            live = [t for t in names if free[t] > 0]
            if not live:           # everything full: overflow heaviest
                live = [max(names, key=lambda t: self.weights[t])]
            wsum = sum(self.weights[t] for t in live)
            taken: Dict[str, int] = {}
            for t in live:
                want = int(o.nbytes * self.weights[t] / wsum)
                taken[t] = min(want, max(free[t], 0)) if free[t] > 0 \
                    else want
            rem = o.nbytes - sum(taken.values())
            # spill the rounding/capacity remainder by descending weight
            for t in sorted(live, key=lambda t: -self.weights[t]):
                if rem <= 0:
                    break
                extra = min(rem, max(free[t] - taken[t], 0))
                taken[t] += extra
                rem -= extra
            if rem > 0:            # over capacity everywhere: heaviest
                taken[max(live, key=lambda t: self.weights[t])] += rem
            sh = []
            for t, b in taken.items():
                if b <= 0:
                    continue
                sh.append((t, b / max(o.nbytes, 1)))
                free[t] -= b
                placed[t] += b
            shares[o.name] = sh
        return PlacementPlan(shares, self.name, placed)


class ObjectLevelInterleave(Policy):
    """The paper's §V-B object-level interleaving (OLI).

    * Selection: footprint ≥ `footprint_threshold` of total AND access-
      intensive AND not latency-sensitive/pinned (criteria verbatim from the
      paper, plus the latency-sensitivity exclusion its §V-A observation 3
      motivates).
    * Selected objects: interleaved across `fast` + `slow_set` with shares
      **proportional to each tier's achievable bandwidth** (beyond-paper
      refinement; the paper interleaves uniformly across the chosen nodes —
      set ``bandwidth_weighted=False`` for the faithful variant).
    * Everything else: `fast`-preferred.
    """

    def __init__(self, fast: str, slow_set: Sequence[str],
                 footprint_threshold: float = 0.10,
                 bandwidth_weighted: bool = False,
                 fast_reserve_fraction: float = 0.0):
        self.fast = fast
        self.slow_set = list(slow_set)
        self.footprint_threshold = footprint_threshold
        self.bandwidth_weighted = bandwidth_weighted
        self.fast_reserve_fraction = fast_reserve_fraction
        self.name = ("oli_bw" if bandwidth_weighted else "oli") + \
            f"[{fast}+{'+'.join(self.slow_set)}]"

    def _weights(self, tiers) -> Dict[str, float]:
        names = [self.fast] + self.slow_set
        if not self.bandwidth_weighted:
            return {t: 1.0 / len(names) for t in names}
        bows = {t: tiers[t].bandwidth(tiers[t].saturation_streams * 2)
                for t in names}
        s = sum(bows.values())
        return {t: b / s for t, b in bows.items()}

    def plan(self, objs, tiers):
        cand = {o.name for o in select_interleave_candidates(
            list(objs), self.footprint_threshold)}
        free = {k: int(tiers[k].capacity_GiB * GiB) for k in tiers}
        # reserve part of fast tier for the latency-sensitive residue
        reserve = int(free[self.fast] * self.fast_reserve_fraction)
        free[self.fast] -= reserve
        shares: Dict[str, List[Share]] = {}
        placed = {k: 0 for k in tiers}
        w = self._weights(tiers)
        order = _tier_order(tiers)

        # pass 1: latency-sensitive + pinned objects go fast-preferred FIRST
        # (fixes the allocation-order fragility of LDRAM-preferred).
        def place_preferred(o: DataObject):
            remaining = o.nbytes
            sh = []
            for t in [self.fast] + [x for x in order if x != self.fast]:
                if remaining <= 0:
                    break
                take = min(remaining, max(free[t], 0))
                if take > 0:
                    sh.append((t, take / max(o.nbytes, 1)))
                    free[t] -= take
                    placed[t] += take
                    remaining -= take
            if remaining > 0:
                t = order[-1]
                sh.append((t, remaining / max(o.nbytes, 1)))
                placed[t] += remaining
            shares[o.name] = sh

        for o in objs:
            if o.name not in cand and (o.pin_fast or o.latency_sensitive):
                place_preferred(o)
        free[self.fast] += reserve  # release reserve for remaining objects

        # pass 2: interleave the selected bandwidth-hungry objects
        for o in objs:
            if o.name in cand:
                sh = []
                for t, frac in w.items():
                    take = min(int(o.nbytes * frac), max(free[t], 0))
                    sh.append((t, take / max(o.nbytes, 1)))
                    free[t] -= take
                    placed[t] += take
                got = sum(f for _, f in sh)
                if got < 1.0 - 1e-9:  # spill remainder in NUMA order
                    rem = int(o.nbytes * (1.0 - got))
                    for t in order:
                        if rem <= 0:
                            break
                        take = min(rem, max(free[t], 0))
                        if take > 0:
                            sh.append((t, take / max(o.nbytes, 1)))
                            free[t] -= take
                            placed[t] += take
                            rem -= take
                    if rem > 0:
                        sh.append((order[-1], rem / max(o.nbytes, 1)))
                        placed[order[-1]] += rem
                shares[o.name] = sh

        # pass 3: everything else, fast-preferred
        for o in objs:
            if o.name not in shares:
                place_preferred(o)
        return PlacementPlan(shares, self.name, placed)


def make_policy(spec: str, tiers: Mapping[str, MemoryTier],
                fast: Optional[str] = None) -> Policy:
    """Policy factory from a CLI-ish string spec."""
    fast = fast or _tier_order(tiers)[0]
    slow = [t for t in tiers if t != fast and tiers[t].kind != "nvme"]
    if spec == "preferred":
        return TierPreferred(fast)
    if spec.startswith("preferred:"):
        return TierPreferred(spec.split(":", 1)[1])
    if spec == "first_touch":
        return FirstTouch(fast)
    if spec == "uniform":
        return UniformInterleave([fast] + slow)
    if spec.startswith("uniform:"):
        return UniformInterleave(spec.split(":", 1)[1].split("+"))
    if spec.startswith("weighted:"):   # weighted:LDRAM=3+CXL=1
        pairs = [kv.split("=") for kv in spec.split(":", 1)[1].split("+")]
        return WeightedInterleave({k: float(v) for k, v in pairs})
    if spec == "oli":
        return ObjectLevelInterleave(fast, slow)
    if spec == "oli_bw":
        return ObjectLevelInterleave(fast, slow, bandwidth_weighted=True)
    raise ValueError(f"unknown policy spec {spec!r}")
