"""Core library: the paper's contribution as composable pieces.

- tiers: memory-tier descriptors + measured performance models (Figs. 2-4)
- objects: data-object metadata (the unit of OLI placement)
- policies: preferred / first-touch / uniform interleave / OLI (§V-B)
- costmodel: analytic step-time model + FlexGen-style policy search
- migration: AutoNUMA / Tiering-0.8 / TPP tiering runtimes (§VI)
- tiered_array: block-granular placement over JAX memory kinds
- interleave: policy -> placement orchestration
"""
from .costmodel import (compare_policies, plan_step_cost, policy_search,
                        SearchResult, StepCost)
from .interleave import (distance_weighted_policy, distance_weights,
                         objects_from_pytree, plan_and_place, realize_plan,
                         recommend_streams)
from .migration import (AutoNUMA, Block, BlockMove, make_blocks_from_plan,
                        MigrationExecutor, MigrationSim, MigrationStats,
                        NoBalance, PlacementDelta, SimResult, Tiering08,
                        TPP, trace_scattered_hotset, trace_stable_hotset,
                        trace_uniform)
from .objects import (DataObject, hpc_workload_objects, llm_serve_objects,
                      llm_train_objects, select_interleave_candidates,
                      total_footprint)
from .policies import (FirstTouch, make_policy, ObjectLevelInterleave,
                       PlacementPlan, Policy, TierPreferred,
                       UniformInterleave, WeightedInterleave)
from .tiered_array import (available_memory_kinds, gather_pytree,
                           place_pytree, TIER_TO_MEMORY_KIND, TieredArray)
from .tiers import (assign_streams, GB, GiB, interleave_bandwidth,
                    MemoryTier, paper_system, tpu_v5e_tiers)

__all__ = [
    "assign_streams", "AutoNUMA", "available_memory_kinds", "Block",
    "BlockMove", "compare_policies", "DataObject",
    "distance_weighted_policy", "distance_weights", "FirstTouch",
    "gather_pytree", "GB", "GiB", "hpc_workload_objects",
    "interleave_bandwidth", "llm_serve_objects", "llm_train_objects",
    "make_blocks_from_plan", "make_policy", "MemoryTier",
    "MigrationExecutor", "MigrationSim", "MigrationStats", "NoBalance",
    "ObjectLevelInterleave", "objects_from_pytree", "paper_system",
    "place_pytree", "PlacementDelta", "PlacementPlan", "plan_and_place",
    "plan_step_cost", "Policy", "policy_search", "realize_plan",
    "recommend_streams", "SearchResult", "select_interleave_candidates",
    "SimResult", "StepCost", "TIER_TO_MEMORY_KIND", "TieredArray",
    "Tiering08", "TierPreferred", "total_footprint",
    "TPP", "trace_scattered_hotset", "trace_stable_hotset",
    "trace_uniform", "tpu_v5e_tiers", "UniformInterleave",
    "WeightedInterleave",
]
