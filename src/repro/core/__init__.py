"""Core library: the paper's contribution as composable pieces.

- tiers: memory-tier descriptors + measured performance models (Figs. 2-4)
- objects: data-object metadata (the unit of OLI placement)
- policies: preferred / first-touch / uniform interleave / OLI (§V-B)
- costmodel: analytic step-time model + FlexGen-style policy search
- migration: AutoNUMA / Tiering-0.8 / TPP tiering runtimes (§VI)
- tiered_array: block-granular placement over JAX memory kinds
- interleave: policy -> placement orchestration
"""
from .tiers import (MemoryTier, paper_system, tpu_v5e_tiers, assign_streams,
                    interleave_bandwidth, GiB, GB)
from .objects import (DataObject, total_footprint,
                      select_interleave_candidates, hpc_workload_objects,
                      llm_train_objects, llm_serve_objects)
from .policies import (Policy, PlacementPlan, TierPreferred, FirstTouch,
                       UniformInterleave, WeightedInterleave,
                       ObjectLevelInterleave, make_policy)
from .costmodel import (StepCost, plan_step_cost, compare_policies,
                        policy_search, SearchResult)
from .migration import (Block, BlockMove, MigrationExecutor, MigrationSim,
                        MigrationStats, NoBalance, PlacementDelta,
                        AutoNUMA, Tiering08, TPP, make_blocks_from_plan,
                        trace_stable_hotset, trace_scattered_hotset,
                        trace_uniform, SimResult)
from .tiered_array import (TieredArray, place_pytree, gather_pytree,
                           available_memory_kinds, TIER_TO_MEMORY_KIND)
from .interleave import (objects_from_pytree, realize_plan, plan_and_place,
                         recommend_streams, distance_weights,
                         distance_weighted_policy)
