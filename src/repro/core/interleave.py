"""Interleaving orchestration: policy -> concrete TieredArray placements.

Bridges the analytic layer (objects/policies/costmodel) and the JAX layer
(tiered_array): given a pytree of arrays with object metadata, plan with a
policy and realize per-leaf block placements, with the Sec. III stream-
assignment used to size the block granularity.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np

from .objects import DataObject
from .policies import PlacementPlan, Policy, WeightedInterleave
from .tiered_array import TIER_TO_MEMORY_KIND, TieredArray
from .tiers import assign_streams, MemoryTier


def objects_from_pytree(tree, traffic_fn=None,
                        group: str = "params") -> List[DataObject]:
    """Derive DataObjects from pytree leaves.

    traffic_fn(name, leaf) -> (read_bytes, write_bytes, random_fraction);
    default: one streaming read per step (weights-like).
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    objs = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        if traffic_fn is None:
            r, w, rf = nbytes, 0, 0.0
        else:
            r, w, rf = traffic_fn(name, leaf)
        objs.append(DataObject(name, nbytes, r, w, rf, group=group))
    return objs


def realize_plan(tree, plan: PlacementPlan,
                 block_rows: Optional[int] = 64) -> Dict[str, TieredArray]:
    """Place each pytree leaf according to the plan's shares."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out: Dict[str, TieredArray] = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        shares = plan.shares.get(name, [("HBM", 1.0)])
        n_kinds = len({TIER_TO_MEMORY_KIND.get(t, "device")
                       for t, _ in shares})
        br = block_rows if n_kinds > 1 else None
        out[name] = TieredArray.from_plan(leaf, shares, block_rows=br)
    return out


def plan_and_place(tree, policy: Policy, tiers: Mapping[str, MemoryTier],
                   traffic_fn=None, block_rows: Optional[int] = 64
                   ) -> Tuple[PlacementPlan, Dict[str, TieredArray]]:
    objs = objects_from_pytree(tree, traffic_fn)
    plan = policy.plan(objs, tiers)
    return plan, realize_plan(tree, plan, block_rows)


def recommend_streams(tiers: Mapping[str, MemoryTier],
                      total_streams: int = 32) -> Dict[str, int]:
    """Sec. III bandwidth packing: DMA streams per tier (the 6/23/23 trick)."""
    alloc, _ = assign_streams(tiers, total_streams)
    return alloc


# ---------------------------------------------------------------------- #
# Distance-weighted interleaving (Linux weighted-interleave analogue).    #
# ---------------------------------------------------------------------- #
def distance_weights(topology, tiers: Mapping[str, MemoryTier],
                     origin: Optional[str] = None,
                     tier_set: Optional[Sequence[str]] = None
                     ) -> Dict[str, float]:
    """Per-tier interleave weights ∝ path-capped bandwidth from ``origin``.

    ``topology`` is a ``repro.topology.TopologyGraph``; a tier reached
    through a UPI hop weighs in at the hop's bottleneck bandwidth, not
    its DIMM bandwidth, so a far-socket node stops receiving traffic it
    cannot serve.  NVMe-class tiers are excluded by the graph.
    """
    w = topology.tier_weights(tiers, origin)
    if tier_set is not None:
        w = {t: w[t] for t in tier_set if t in w}
        total = sum(w.values())
        if total <= 0:
            raise ValueError(f"tier_set {list(tier_set)} has no "
                             "interleavable bandwidth")
        w = {t: v / total for t, v in w.items()}
    return w


def distance_weighted_policy(topology, tiers: Mapping[str, MemoryTier],
                             origin: Optional[str] = None,
                             tier_set: Optional[Sequence[str]] = None,
                             name: Optional[str] = None
                             ) -> WeightedInterleave:
    """A ``WeightedInterleave`` whose weights come from the topology.

    This is the distance-aware counterpart of ``UniformInterleave``:
    equal capacity, but per-node shares follow ``path_bw_GBps`` so the
    slowest-reachable node no longer gates the aggregate (the Sec. V
    uniform-interleave failure mode).
    """
    w = distance_weights(topology, tiers, origin, tier_set)
    return WeightedInterleave(
        w, name=name or f"distance_weighted[{topology.name}]")
