"""Interleaving orchestration: policy -> concrete TieredArray placements.

Bridges the analytic layer (objects/policies/costmodel) and the JAX layer
(tiered_array): given a pytree of arrays with object metadata, plan with a
policy and realize per-leaf block placements, with the Sec. III stream-
assignment used to size the block granularity.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np

from .objects import DataObject
from .policies import PlacementPlan, Policy
from .tiers import MemoryTier, assign_streams
from .tiered_array import TieredArray, TIER_TO_MEMORY_KIND


def objects_from_pytree(tree, traffic_fn=None,
                        group: str = "params") -> List[DataObject]:
    """Derive DataObjects from pytree leaves.

    traffic_fn(name, leaf) -> (read_bytes, write_bytes, random_fraction);
    default: one streaming read per step (weights-like).
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    objs = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        if traffic_fn is None:
            r, w, rf = nbytes, 0, 0.0
        else:
            r, w, rf = traffic_fn(name, leaf)
        objs.append(DataObject(name, nbytes, r, w, rf, group=group))
    return objs


def realize_plan(tree, plan: PlacementPlan,
                 block_rows: Optional[int] = 64) -> Dict[str, TieredArray]:
    """Place each pytree leaf according to the plan's shares."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out: Dict[str, TieredArray] = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        shares = plan.shares.get(name, [("HBM", 1.0)])
        n_kinds = len({TIER_TO_MEMORY_KIND.get(t, "device")
                       for t, _ in shares})
        br = block_rows if n_kinds > 1 else None
        out[name] = TieredArray.from_plan(leaf, shares, block_rows=br)
    return out


def plan_and_place(tree, policy: Policy, tiers: Mapping[str, MemoryTier],
                   traffic_fn=None, block_rows: Optional[int] = 64
                   ) -> Tuple[PlacementPlan, Dict[str, TieredArray]]:
    objs = objects_from_pytree(tree, traffic_fn)
    plan = policy.plan(objs, tiers)
    return plan, realize_plan(tree, plan, block_rows)


def recommend_streams(tiers: Mapping[str, MemoryTier],
                      total_streams: int = 32) -> Dict[str, int]:
    """Sec. III bandwidth packing: DMA streams per tier (the 6/23/23 trick)."""
    alloc, _ = assign_streams(tiers, total_streams)
    return alloc
