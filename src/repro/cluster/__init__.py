"""repro.cluster: sharded multi-host serving plane.

``Namespace`` (hierarchical ``replica/tenant/obj`` ledger keys) is
imported eagerly — it is dependency-free and the pool/obs planes key on
it.  The heavier members (replica meshes, the session router, the
cluster plane) load lazily so ``repro.pool`` can import the namespace
module without dragging JAX/serving into every ledger user.
"""
from __future__ import annotations

from .namespace import (
    DEFAULT_REPLICA,
    Namespace,
    is_pattern,
    reset_bare_key_warning,
)

__all__ = [
    "AxisMapping",
    "ClusterPlane",
    "ClusterReport",
    "DEFAULT_REPLICA",
    "Namespace",
    "Replica",
    "ReplicaView",
    "SessionRequest",
    "SessionRouter",
    "axis_mapping",
    "current_axis_mapping",
    "is_pattern",
    "replica_meshes",
    "replica_shard_map",
    "reset_bare_key_warning",
    "shard_lm_params",
]

_LAZY = {
    "AxisMapping": "sharding",
    "axis_mapping": "sharding",
    "current_axis_mapping": "sharding",
    "replica_meshes": "sharding",
    "replica_shard_map": "sharding",
    "shard_lm_params": "sharding",
    "Replica": "replica",
    "ClusterPlane": "plane",
    "ClusterReport": "plane",
    "ReplicaView": "router",
    "SessionRequest": "router",
    "SessionRouter": "router",
}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(f".{mod}", __name__), name)
