"""The multi-host serving plane: replicas + shared ledger + router.

:class:`ClusterPlane` composes the pieces the cluster PR introduces:

* a :func:`~repro.topology.multi_host_pod` testbed — one global
  inter-host graph for routing, one local graph per replica;
* ``n`` :class:`~repro.cluster.replica.Replica`\\ s, each a
  mesh-sharded serving engine whose pool registers in ONE **shared**
  :class:`~repro.pool.ResidencyLedger` under its
  ``<replica>/<tenant>`` namespace;
* a :class:`~repro.cluster.router.SessionRouter` placing sessions by
  fast-tier headroom and front-end ICI distance;
* a plane-level :class:`~repro.pool.TierBudgetArbiter` carrying
  ``replica_capacity`` — budget splits water-fill across replica
  groups first (a tenant on host A can never be granted host B's
  DRAM), then per-tenant within each group.

The invariant tests pin: per-replica ledger namespaces sum exactly to
the ``replica/*`` global aggregate — occupancy is conserved across the
namespace scheme, there is no double counting and no leakage.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..obs import MetricsRegistry, TraceRecorder
from ..pool import TierBudgetArbiter
from ..serving import ServingConfig
from ..serving.engine import FAST_KIND
from ..topology import ROUTER_NODE, ClusterTestbed, multi_host_pod
from .replica import Replica
from .router import SessionRequest, SessionRouter
from .sharding import replica_meshes

__all__ = ["ClusterPlane", "ClusterReport"]


@dataclasses.dataclass
class ClusterReport:
    """Aggregate + per-replica outcome of one plane run."""

    summary: Dict[str, float]
    per_replica: Dict[str, object]        # replica -> ServingReport
    routed: Dict[str, int]                # replica -> sessions routed

    def aggregate_throughput(self) -> float:
        return self.summary.get("throughput_tok_s", 0.0)


class ClusterPlane:
    """Front-end + replicas over one shared, namespaced ledger."""

    def __init__(self, cfg, params,
                 serving: Optional[ServingConfig] = None,
                 n_replicas: int = 2,
                 router_policy: str = "headroom-distance",
                 testbed: Optional[ClusterTestbed] = None,
                 shard_model: bool = True, seed: int = 0,
                 ledger=None, clock=None):
        from ..pool import ResidencyLedger
        if testbed is None:
            testbed = multi_host_pod(n_replicas)
        if len(testbed.hosts) < n_replicas:
            raise ValueError(
                f"testbed has {len(testbed.hosts)} hosts for "
                f"{n_replicas} replicas")
        self.testbed = testbed
        self.ledger = ledger if ledger is not None else ResidencyLedger()
        self.registry = MetricsRegistry()
        self.tracer = TraceRecorder()
        meshes = replica_meshes(n_replicas)
        self.replicas: Dict[str, Replica] = {}
        for host, mesh in zip(testbed.hosts, meshes):
            self.replicas[host] = Replica(
                host, cfg, params, serving=serving, mesh=mesh,
                ledger=self.ledger, host=host,
                testbed=testbed.replicas.get(host),
                shard_model=shard_model, clock=clock)
        self.router = SessionRouter(router_policy, seed=seed)
        for host, rep in self.replicas.items():
            self.router.register(
                host,
                distance_ns=testbed.distance_ns(ROUTER_NODE, host),
                headroom_fn=rep.fast_headroom_bytes,
                load_fn=rep.active_sessions)
        # plane arbiter: global fast capacity split across replica
        # groups first, then per tenant — per-replica physical limits
        # are what make the hierarchical water-fill non-degenerate
        cap = {h: r.engine.pool.fast_block_budget
               * r.engine.pool.block_nbytes()
               for h, r in self.replicas.items()}
        self.replica_fast_bytes = cap
        self.arbiter = TierBudgetArbiter(
            self.ledger, FAST_KIND,
            capacity_bytes=sum(cap.values()),
            replica_capacity=cap, tracer=self.tracer)
        self._next_sid = 0

    # -- session intake ----------------------------------------------- #
    def _kv_bytes_hint(self, replica: Replica, total_tokens: int) -> int:
        pool = replica.engine.pool
        return pool.blocks_for_tokens(total_tokens) * pool.block_nbytes()

    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               arrival_s: float = 0.0, priority: float = 0.0,
               tenant: str = "serving",
               session_id: Optional[str] = None) -> str:
        """Route one session and queue it on the chosen replica.
        Returns ``"<replica>:<rid>"`` so callers can find it again."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        sid = session_id or f"s{self._next_sid}"
        self._next_sid += 1
        any_rep = next(iter(self.replicas.values()))
        req = SessionRequest(
            session_id=sid, tenant=tenant,
            prompt_tokens=int(prompt.shape[0]),
            new_tokens=int(max_new_tokens),
            kv_bytes_hint=self._kv_bytes_hint(
                any_rep, prompt.shape[0] + max_new_tokens))
        target = self.router.route(req)
        rid = self.replicas[target].submit(
            prompt, max_new_tokens, arrival_s=arrival_s,
            priority=priority)
        self.tracer.event("cluster.route", cat="cluster", tid=target,
                          session=sid, replica=target,
                          prompt_tokens=req.prompt_tokens,
                          kv_bytes_hint=req.kv_bytes_hint)
        return f"{target}:{rid}"

    # -- execution ----------------------------------------------------- #
    def run(self, max_iterations: int = 10_000) -> ClusterReport:
        """Drive every replica's trace to completion.

        Replicas are simulated hosts in one process, so they run
        sequentially here; their engines keep independent virtual
        clocks, so per-replica latency statistics are unaffected by
        the serialization.
        """
        self.router.drain_pending()
        reports = {}
        for host in self.testbed.hosts:
            rep = self.replicas[host]
            if rep.engine.sched.active:
                reports[host] = rep.run(max_iterations=max_iterations)
        agg: Dict[str, float] = {
            "replicas": float(len(self.replicas)),
            "throughput_tok_s": 0.0, "decode_tokens": 0.0,
            "requests": 0.0, "finished": 0.0, "preemptions": 0.0,
        }
        worst_p95 = 0.0
        for host, rp in reports.items():
            s = rp.summary
            for k in ("throughput_tok_s", "decode_tokens", "requests",
                      "finished", "preemptions"):
                agg[k] += s.get(k, 0.0)
            worst_p95 = max(worst_p95, s.get("p95_latency_s", 0.0))
        agg["worst_p95_latency_s"] = worst_p95
        self.publish()
        return ClusterReport(summary=agg, per_replica=reports,
                             routed=self.router.routed_counts())

    # -- observability ------------------------------------------------- #
    def publish(self, registry: Optional[MetricsRegistry] = None) -> int:
        """Publish plane state: per-replica gauges under
        ``cluster.<replica>.*`` plus the shared ledger (whose tenant
        gauges already carry ``<replica>/<tenant>`` names)."""
        reg = registry or self.registry
        n = 0
        for host, rep in self.replicas.items():
            n += reg.set_gauges(
                {"fast_headroom_bytes": rep.fast_headroom_bytes(),
                 "active_sessions": rep.active_sessions(),
                 "routed_sessions": self.router.routed_counts()[host],
                 "distance_ns": self.testbed.distance_ns(
                     ROUTER_NODE, host)},
                prefix=f"cluster.{host}")
        n += self.ledger.publish(reg)
        return n

    def merged_trace(self) -> List:
        """All replica control-plane events plus the plane's own, as
        one list: plane events first, then each replica's events in
        host order with ``tid`` prefixed ``<replica>/``.

        Events are concatenated per replica, NOT interleaved by
        timestamp: :func:`repro.obs.qos_chains` pairs a violation with
        the blame event that *follows it in sequence*, so per-replica
        ordering must survive the merge for chains to reconstruct.
        """
        out = list(self.tracer.events)
        for host in self.testbed.hosts:
            rep = self.replicas[host]
            for ev in rep.engine.tracer.events:
                out.append(dataclasses.replace(
                    ev, tid=f"{host}/{ev.tid}"))
        return out

    # -- namespace invariant ------------------------------------------ #
    def namespace_conservation(self, tier: str = FAST_KIND
                               ) -> Dict[str, int]:
        """Per-replica ledger bytes plus the global aggregate — the
        acceptance invariant: values sum exactly to ``replica/*``."""
        per = {h: self.ledger.bytes_on(tier, f"{h}/*")
               for h in self.replicas}
        per["total"] = self.ledger.bytes_on(tier, "*/*")
        return per
