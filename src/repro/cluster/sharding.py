"""Named-axis sharding for the multi-host serving plane.

Two idioms from the ecosystem, adapted to the repo's plain-pytree
models:

* **axis mapping** (haliax): model code names *logical* axes
  ("embed", "vocab", "experts"); a thread-local :class:`AxisMapping`
  resolves them to *physical* mesh axes at placement time, so the
  same model runs replicated, tensor-sharded, or expert-sharded by
  swapping one context, never editing model code.
* **shard_map adapter** (equinox ``filter_shard_map``): a thin
  wrapper that partitions array args over the mesh and leaves
  non-arrays alone, version-adaptive across the
  ``jax.experimental.shard_map`` -> ``jax.shard_map`` migration.

The meshes themselves come from :func:`replica_meshes`, which
partitions the process's devices into per-replica groups.  Under the
tier-1 test environment (one CPU device) every replica degrades to a
1-device mesh sharing that device — placement semantics are exercised,
parallel speed is not.  CI's cluster-smoke step forces 8 host-platform
devices to exercise real multi-device placement.
"""
from __future__ import annotations

import dataclasses
import threading
from contextlib import contextmanager
from typing import List, Mapping, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

try:                                      # jax >= 0.4.35 path
    from jax.experimental.shard_map import shard_map as _shard_map
except ImportError:                       # pragma: no cover - newer jax
    _shard_map = getattr(jax, "shard_map", None)

__all__ = ["AxisMapping", "axis_mapping", "current_axis_mapping",
           "replica_meshes", "replica_shard_map", "shard_lm_params"]

# logical axis names the LM param tree exposes, by leaf dimension:
# embed/lm_head are (vocab, d_model); per-unit stacks lead with "unit"
MODEL_AXIS = "model"


@dataclasses.dataclass(frozen=True)
class AxisMapping:
    """Logical-axis -> physical-mesh-axis resolution table.

    ``mapping["vocab"] == "model"`` means "partition logical axis
    *vocab* over mesh axis *model*"; a logical axis absent from the
    table (or mapped to None) is replicated.  Immutable so it can be
    stacked on the thread-local context without aliasing surprises.
    """

    mapping: Mapping[str, Optional[str]] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "mapping", dict(self.mapping))

    def physical(self, logical: str) -> Optional[str]:
        return self.mapping.get(logical)

    def spec(self, *logical: Optional[str]) -> PartitionSpec:
        """PartitionSpec for a leaf whose dims carry these logical
        names (None = unnamed dim, always replicated)."""
        return PartitionSpec(*(self.physical(ax) if ax else None
                               for ax in logical))

    def merged(self, other: "AxisMapping") -> "AxisMapping":
        out = dict(self.mapping)
        out.update(other.mapping)
        return AxisMapping(out)


# replicate-everything default: correctness-first, matches the paper's
# observation that capacity (tiering) not FLOPs is the serving binder
_DEFAULT = AxisMapping({})
_tls = threading.local()


def current_axis_mapping() -> AxisMapping:
    stack = getattr(_tls, "stack", None)
    return stack[-1] if stack else _DEFAULT


@contextmanager
def axis_mapping(mapping: "AxisMapping | Mapping[str, Optional[str]]"):
    """Install an axis mapping for the dynamic extent, haliax-style.

    Nested contexts merge (inner wins per logical axis), so a replica
    can overlay ``{"experts": "model"}`` on a plane-wide base.
    """
    if not isinstance(mapping, AxisMapping):
        mapping = AxisMapping(mapping)
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    merged = (stack[-1].merged(mapping) if stack else
              _DEFAULT.merged(mapping))
    stack.append(merged)
    try:
        yield merged
    finally:
        stack.pop()


def replica_meshes(n_replicas: int,
                   axis_name: str = MODEL_AXIS,
                   devices: Optional[List] = None) -> List[Mesh]:
    """Partition the process's devices into ``n_replicas`` 1-D meshes.

    With ``d`` devices and ``n`` replicas each mesh gets ``d // n``
    devices (remainder unused, keeping replicas symmetric).  With
    fewer devices than replicas, replicas *share* devices round-robin
    — 1-device meshes that keep every placement code path alive on the
    single-CPU tier-1 environment.
    """
    if n_replicas < 1:
        raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
    devs = list(devices if devices is not None else jax.devices())
    per = len(devs) // n_replicas
    meshes = []
    for r in range(n_replicas):
        if per >= 1:
            group = devs[r * per:(r + 1) * per]
        else:
            group = [devs[r % len(devs)]]
        meshes.append(Mesh(np.array(group), (axis_name,)))
    return meshes


def _leaf_logical_axes(path: Tuple[str, ...], ndim: int) -> List[Optional[str]]:
    """Logical axis names for an LM param leaf, by its tree path.

    Only axes we ever shard get names; everything else is None
    (replicated).  ``embed``/``lm_head`` are (vocab, d_model) and
    vocab is the one big, cleanly-partitionable dimension of the
    decode path; MoE expert stacks lead with an ``experts`` dim.
    """
    axes: List[Optional[str]] = [None] * ndim
    if path and path[-1] in ("embed", "lm_head") and ndim >= 1:
        axes[0] = "vocab"
    if "moe" in path and ndim >= 2:
        # unit-stacked MoE leaves are (n_units, n_experts, ...)
        axes[1 if "units" in path else 0] = "experts"
    return axes


def _iter_with_path(tree, path=()):
    if isinstance(tree, Mapping):
        for k in tree:
            yield from _iter_with_path(tree[k], path + (k,))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _iter_with_path(v, path + (str(i),))
    else:
        yield path, tree


def shard_lm_params(params, mesh: Mesh,
                    mapping: Optional[AxisMapping] = None):
    """Place an LM param pytree on ``mesh`` under the axis mapping.

    Each leaf gets a :class:`NamedSharding`: dims whose logical axis
    the mapping routes to a mesh axis are partitioned *when evenly
    divisible* (otherwise silently replicated — a 50k vocab on a
    3-device mesh should not crash serving), all other dims
    replicated.  With the default empty mapping this is pure
    replication: every leaf committed to the mesh's device set, which
    is exactly what makes replica params and pool blocks jit-compatible.
    """
    mapping = mapping or current_axis_mapping()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def place(path, leaf):
        if not hasattr(leaf, "ndim"):
            return leaf
        spec_axes: List[Optional[str]] = []
        for dim, logical in zip(
                leaf.shape, _leaf_logical_axes(path, leaf.ndim)):
            phys = mapping.physical(logical) if logical else None
            ok = phys in sizes and dim % sizes[phys] == 0
            spec_axes.append(phys if ok else None)
        sh = NamedSharding(mesh, PartitionSpec(*spec_axes))
        return jax.device_put(leaf, sh)

    flat = {path: place(path, leaf)
            for path, leaf in _iter_with_path(params)}

    def rebuild(tree, path=()):
        if isinstance(tree, Mapping):
            return {k: rebuild(tree[k], path + (k,)) for k in tree}
        if isinstance(tree, tuple):
            return tuple(rebuild(v, path + (str(i),))
                         for i, v in enumerate(tree))
        if isinstance(tree, list):
            return [rebuild(v, path + (str(i),))
                    for i, v in enumerate(tree)]
        return flat[path]

    return rebuild(params)


def replica_shard_map(fn, mesh: Mesh, in_specs, out_specs,
                      check_rep: bool = False):
    """``shard_map`` adapter: partition ``fn`` over a replica mesh.

    Wraps whichever shard_map this jax exposes; ``check_rep=False``
    because the serving kernels freely mix replicated scalars with
    partitioned blocks.  Mirrors equinox's ``filter_shard_map`` shape:
    specs may be prefix pytrees.
    """
    if _shard_map is None:           # pragma: no cover - ancient jax
        raise RuntimeError("this jax exposes no shard_map")
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_rep)
