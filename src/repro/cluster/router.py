"""Topology-aware session router for the multi-host serving plane.

The front-end decision the paper's capacity story implies: a session's
KV cache must *live* somewhere for its whole lifetime, so placement is
a memory-capacity bet, not a load-balancing round-robin.  The router
prices each replica by

* **fast-tier headroom** — how much of the session's KV footprint the
  replica can keep in its fast tier (the dominant term: a session
  spilled to the CXL-class tier pays the Fig.-2 latency delta on every
  decode step), and
* **topology distance** — unloaded ICI path latency from the
  front-end :data:`~repro.topology.builders.ROUTER_NODE` to the
  replica's host, normalized against the farthest replica (the
  tiebreak: prefer close hosts when headroom is comparable).

Baseline policies (``round-robin``, ``random``, ``least-loaded``) ride
the same interface so the bench compares them on equal footing.
"""
from __future__ import annotations

import dataclasses
import random as _random
from typing import Callable, Dict, List, Optional

from ..serving.config import ROUTER_POLICIES, ConfigError

__all__ = ["ReplicaView", "SessionRequest", "SessionRouter"]

# distance weight in fast-tier-fractions: a replica one full
# normalized-distance unit farther must offer 25 points more headroom
# fraction to win — headroom dominates, distance breaks ties
_DISTANCE_WEIGHT = 0.25


@dataclasses.dataclass(frozen=True)
class SessionRequest:
    """What the router knows about a session before placing it."""

    session_id: str
    tenant: str = "serving"
    prompt_tokens: int = 0
    new_tokens: int = 0
    kv_bytes_hint: Optional[int] = None   # est. KV footprint, if known

    @property
    def total_tokens(self) -> int:
        return self.prompt_tokens + self.new_tokens


@dataclasses.dataclass
class ReplicaView:
    """The router's handle on one replica: live headroom + static
    distance.  ``headroom_fn``/``load_fn`` are polled at each routing
    decision so the view never goes stale."""

    name: str
    distance_ns: float = 0.0
    headroom_fn: Callable[[], int] = lambda: 0
    load_fn: Callable[[], int] = lambda: 0
    routed: int = 0               # sessions this router sent here
    # KV bytes routed here but not yet materialized in the pool: the
    # engine only allocates at admission, so batch submissions would
    # all see identical headroom and pile onto one host without this
    pending_bytes: int = 0


class SessionRouter:
    """Places sessions onto replicas under a pluggable policy."""

    def __init__(self, policy: str = "headroom-distance", seed: int = 0):
        if policy not in ROUTER_POLICIES:
            raise ConfigError(
                f"unknown router policy {policy!r}; choose from "
                f"{', '.join(ROUTER_POLICIES)}")
        self.policy = policy
        self._rng = _random.Random(seed)
        self._views: Dict[str, ReplicaView] = {}
        self._rr = 0              # round-robin cursor

    # -- registry ----------------------------------------------------- #
    def register(self, name: str, *, distance_ns: float = 0.0,
                 headroom_fn: Optional[Callable[[], int]] = None,
                 load_fn: Optional[Callable[[], int]] = None) -> None:
        self._views[name] = ReplicaView(
            name, distance_ns=distance_ns,
            headroom_fn=headroom_fn or (lambda: 0),
            load_fn=load_fn or (lambda: 0))

    @property
    def replicas(self) -> List[str]:
        return list(self._views)

    def routed_counts(self) -> Dict[str, int]:
        return {n: v.routed for n, v in self._views.items()}

    # -- policies ----------------------------------------------------- #
    def route(self, req: SessionRequest) -> str:
        """Pick a replica for ``req``.  Never raises for lack of
        headroom: a full cluster still has to put the session
        *somewhere* (the replica's own admission control queues it),
        so zero-headroom falls back to the least-bad replica."""
        if not self._views:
            raise ConfigError("router has no registered replicas")
        views = list(self._views.values())
        if len(views) == 1:
            views[0].routed += 1
            return views[0].name
        pick = {
            "round-robin": self._round_robin,
            "random": self._random_pick,
            "least-loaded": self._least_loaded,
            "headroom-distance": self._headroom_distance,
        }[self.policy](views, req)
        pick.routed += 1
        pick.pending_bytes += req.kv_bytes_hint or 0
        return pick.name

    def drain_pending(self) -> None:
        """Forget in-flight reservations.  Call when routed sessions
        have materialized in their pools (e.g. at plane ``run()``):
        from then on live pool headroom carries the signal and keeping
        the reservation would double-count it."""
        for v in self._views.values():
            v.pending_bytes = 0

    def _round_robin(self, views, req) -> ReplicaView:
        pick = views[self._rr % len(views)]
        self._rr += 1
        return pick

    def _random_pick(self, views, req) -> ReplicaView:
        return self._rng.choice(views)

    def _least_loaded(self, views, req) -> ReplicaView:
        return min(views, key=lambda v: (v.load_fn(), v.distance_ns))

    def _headroom_distance(self, views, req) -> ReplicaView:
        need = req.kv_bytes_hint or 0
        headroom = {v.name: max(0, int(v.headroom_fn())
                                - v.pending_bytes) for v in views}
        max_head = max(headroom.values())
        max_dist = max(v.distance_ns for v in views)
        if max_head <= 0:
            # zero headroom everywhere: degrade to least-loaded so the
            # overload spreads instead of piling onto one replica
            return self._least_loaded(views, req)

        def score(v: ReplicaView) -> float:
            frac = headroom[v.name] / max_head
            dist = (v.distance_ns / max_dist) if max_dist > 0 else 0.0
            s = frac - _DISTANCE_WEIGHT * dist
            if need and headroom[v.name] < need:
                # can't hold the whole session fast: rank below any
                # replica that can, by how much of it would spill
                s -= 1.0 + (need - headroom[v.name]) / need
            return s

        return max(views, key=lambda v: (score(v), -v.distance_ns,
                                         v.name))
