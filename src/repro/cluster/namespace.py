"""Hierarchical ``replica/tenant/object`` keys for the multi-host plane.

Every accounting plane built so far (``ResidencyLedger`` budgets,
``TierBudgetArbiter`` grants, ``BlameLedger`` attribution) keyed state
by a flat tenant string — fine for one engine on one host, but the
multi-host serving plane multiplies the same tenant across replicas,
and "Dissecting CXL Memory Performance at Scale" (arXiv:2409.14317)
scales its measure→model→place loop exactly along that axis: per-host
pools that must still roll up to one fleet view.  ``Namespace`` is the
structured key that makes both views exact:

  * ``Namespace(replica, tenant, obj)`` — ordered, hashable, and
    round-trippable through ``parse``/``str`` (``parse(str(ns)) == ns``);
  * tenant-level keys render in a **short form** that omits the
    ``default`` replica (``str(Namespace(tenant="a")) == "a"``), so
    single-host callers keep reading the names they always wrote;
  * glob-style patterns (``replica0/*``, ``*/serving``) aggregate
    across the hierarchy — per-replica ledger views sum exactly to the
    global ``*/*`` view because both are reductions over the same keys;
  * bare strings keep working everywhere via :meth:`Namespace.of`,
    which maps ``"t"`` to ``default/t`` and warns once per process
    (the deprecation shim for pre-cluster callers).
"""
from __future__ import annotations

import dataclasses
import warnings
from fnmatch import fnmatchcase
from typing import Dict, Union

DEFAULT_REPLICA = "default"

_GLOB_CHARS = ("*", "?", "[")

# the bare-string deprecation fires once per process, not once per call
# site: pre-cluster code paths touch the ledger thousands of times per
# run and a warning storm would bury the signal
_warned_bare = False
# parse results are memoized — ledger accounting normalizes on every
# record_alloc/record_free, and the distinct key population is tiny
_parse_cache: Dict[str, "Namespace"] = {}


def reset_bare_key_warning() -> None:
    """Re-arm the once-per-process bare-string deprecation (tests)."""
    global _warned_bare
    _warned_bare = False


def is_pattern(s: str) -> bool:
    """True when ``s`` contains glob metacharacters (``* ? [``)."""
    return any(c in s for c in _GLOB_CHARS)


@dataclasses.dataclass(frozen=True, order=True)
class Namespace:
    """Structured ``replica/tenant/obj`` key.

    Ordering is lexicographic over ``(replica, tenant, obj)``, so a
    sorted iteration groups each replica's tenants together — the
    arbiter's per-replica split and the ledger's publish loop both rely
    on that.
    """

    replica: str = DEFAULT_REPLICA
    tenant: str = ""
    obj: str = ""

    def __post_init__(self):
        for part, val in (("replica", self.replica),
                          ("tenant", self.tenant), ("obj", self.obj)):
            if "/" in val:
                raise ValueError(
                    f"namespace {part} component {val!r} may not "
                    f"contain '/'")

    # -------------------------------------------------------------- #
    # parse / format                                                 #
    # -------------------------------------------------------------- #
    @classmethod
    def parse(cls, s: str) -> "Namespace":
        """Parse ``"t"`` | ``"replica/t"`` | ``"replica/t/obj"``."""
        ns = _parse_cache.get(s)
        if ns is not None:
            return ns
        parts = s.split("/")
        if len(parts) == 1:
            ns = cls(tenant=parts[0])
        elif len(parts) == 2:
            ns = cls(replica=parts[0], tenant=parts[1])
        elif len(parts) == 3:
            ns = cls(replica=parts[0], tenant=parts[1], obj=parts[2])
        else:
            raise ValueError(f"namespace {s!r} has more than "
                             f"replica/tenant/obj components")
        _parse_cache[s] = ns
        return ns

    @classmethod
    def of(cls, key: Union[str, "Namespace"]) -> "Namespace":
        """Normalize a ledger key: Namespace passes through; strings
        are parsed, with a **bare** tenant string (no ``/``) mapped to
        ``default/<tenant>`` under a once-per-process
        ``DeprecationWarning`` — the compatibility shim for callers
        written before the cluster plane existed."""
        if isinstance(key, Namespace):
            return key
        if "/" not in key:
            global _warned_bare
            if not _warned_bare and not is_pattern(key):
                _warned_bare = True
                warnings.warn(
                    f"bare tenant key {key!r} interpreted as "
                    f"'{DEFAULT_REPLICA}/{key}'; pass a "
                    f"'replica/tenant' namespace (repro.cluster."
                    f"Namespace) instead", DeprecationWarning,
                    stacklevel=3)
        return cls.parse(key)

    def __str__(self) -> str:
        # short display form: tenant-level keys in the default replica
        # render as the bare tenant name, so every pre-cluster mapping
        # key ("a", "serving", "noisy") is unchanged; parse() of every
        # form round-trips back to self
        if self.obj:
            return f"{self.replica}/{self.tenant}/{self.obj}"
        if self.replica == DEFAULT_REPLICA:
            return self.tenant
        return f"{self.replica}/{self.tenant}"

    @property
    def key(self) -> str:
        """Canonical long form — always ``replica/tenant[/obj]``."""
        base = f"{self.replica}/{self.tenant}"
        return f"{base}/{self.obj}" if self.obj else base

    # -------------------------------------------------------------- #
    # derivation                                                     #
    # -------------------------------------------------------------- #
    def with_obj(self, obj: str) -> "Namespace":
        return dataclasses.replace(self, obj=obj)

    def tenant_key(self) -> "Namespace":
        """This key with the object component dropped."""
        return self if not self.obj else dataclasses.replace(self, obj="")

    def in_replica(self, replica: str) -> "Namespace":
        return dataclasses.replace(self, replica=replica)

    # -------------------------------------------------------------- #
    # glob matching                                                  #
    # -------------------------------------------------------------- #
    def matches(self, pattern: str) -> bool:
        """Component-wise glob match.  A bare pattern addresses the
        default replica (mirroring :meth:`of`); a pattern without an
        object component matches any object."""
        parts = pattern.split("/")
        if len(parts) == 1:
            parts = [DEFAULT_REPLICA, parts[0]]
        if len(parts) > 3:
            raise ValueError(f"pattern {pattern!r} has more than "
                             f"replica/tenant/obj components")
        if not fnmatchcase(self.replica, parts[0]):
            return False
        if not fnmatchcase(self.tenant, parts[1]):
            return False
        if len(parts) == 3 and not fnmatchcase(self.obj, parts[2]):
            return False
        return True
