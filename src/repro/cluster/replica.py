"""One replica of the multi-host serving plane.

A :class:`Replica` owns the full single-host serving stack — sharded
params on its own device mesh, a :class:`~repro.serving.ServingEngine`
whose paged KV pool is *mesh-placed* (so pool blocks and params share
one jit device set), and a local topology testbed its tiering plane
prices promotions against.

The ownership boundary the namespace scheme encodes: everything the
replica allocates registers in the **shared** residency ledger under
``<replica>/<tenant>`` keys, so the cluster arbiter and the blame
plane see per-replica occupancy without the replica knowing it has
siblings.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..serving import ServingConfig, ServingEngine
from .namespace import Namespace
from .sharding import current_axis_mapping, shard_lm_params

__all__ = ["Replica"]


def _mesh_pool_sharding(mesh: Mesh) -> Callable[[str], object]:
    """Pool-block placement on the replica mesh: replicated over its
    devices, on the requested memory kind when the platform exposes it
    (same degradation rule as ``sharding_for_kind``)."""
    dev = mesh.devices.flat[0]
    kinds = {m.kind for m in dev.addressable_memories()}
    default = dev.default_memory().kind

    def fn(kind: str):
        mk = kind if kind in kinds else default
        return NamedSharding(mesh, PartitionSpec(), memory_kind=mk)

    return fn


class Replica:
    """A mesh-sharded serving engine registered under its namespace."""

    def __init__(self, name: str, cfg, params,
                 serving: Optional[ServingConfig] = None,
                 mesh: Optional[Mesh] = None, ledger=None,
                 host: Optional[str] = None, testbed=None,
                 shard_model: bool = True,
                 clock: Optional[Callable[[], float]] = None):
        import dataclasses as _dc
        import time as _time
        self.name = name
        self.host = host or name
        self.mesh = mesh
        sv = _dc.replace(serving) if serving is not None \
            else ServingConfig()
        # the one rename that makes multi-replica ledgers work: this
        # engine's tenant becomes "<replica>/<tenant>" in the shared
        # ledger, short-form-printable and glob-aggregatable
        base = Namespace.of(sv.tenant or "serving")
        self.ns = Namespace(replica=name, tenant=base.tenant)
        sv.tenant = str(self.ns)
        if testbed is not None and sv.topology is None:
            # replicas plan over their own local testbed, not a name
            # the engine would rebuild; wired below after construction
            pass
        pool_sharding = None
        if mesh is not None:
            pool_sharding = _mesh_pool_sharding(mesh)
            if shard_model:
                params = shard_lm_params(params, mesh,
                                         current_axis_mapping())
            else:
                params = jax.device_put(
                    params, NamedSharding(mesh, PartitionSpec()))
        self.params = params
        self.engine = ServingEngine(
            cfg, params, serving=sv,
            clock=clock or _time.perf_counter,
            ledger=ledger, pool_sharding=pool_sharding)
        if testbed is not None and self.engine.topo is None:
            # adopt the cluster's per-replica local graph so the
            # migration executor / replanner price over real links
            from ..serving.engine import FAST_KIND
            topo = testbed.graph
            topo.alias_tier(testbed.fast, FAST_KIND)
            topo.alias_tier(testbed.capacity_tier,
                            self.engine.pool.slow_kind)
            self.engine.topo = topo
        self.testbed = testbed

    # -- the router's live signals ------------------------------------ #
    def fast_headroom_bytes(self) -> int:
        """Unused fast-tier capacity — the router's dominant term."""
        pool = self.engine.pool
        free = max(0, pool.fast_block_budget - pool.fast_used())
        return free * pool.block_nbytes()

    def active_sessions(self) -> int:
        sched = self.engine.sched
        return len(sched.running) + len(sched.waiting)

    # -- serving pass-throughs ---------------------------------------- #
    def submit(self, prompt, max_new_tokens: int,
               arrival_s: float = 0.0, priority: float = 0.0) -> int:
        return self.engine.submit(prompt, max_new_tokens,
                                  arrival_s=arrival_s, priority=priority)

    def run(self, max_iterations: int = 10_000):
        return self.engine.run(max_iterations=max_iterations)

    def __repr__(self) -> str:
        nd = self.mesh.devices.size if self.mesh is not None else 0
        return (f"Replica({self.name!r}, ns={str(self.ns)!r}, "
                f"mesh_devices={nd})")
