"""Step builders: train_step / prefill_step / serve_step from a config."""
from __future__ import annotations

from typing import Callable, Optional

import jax

from ..configs.base import ModelConfig
from ..models import lm
from ..optim import adam


def make_loss_fn(cfg: ModelConfig) -> Callable:
    def loss_fn(params, batch):
        return lm.forward_loss(params, cfg, batch["tokens"],
                               batch["labels"], batch.get("frames"))
    return loss_fn


def make_train_step(cfg: ModelConfig,
                    adam_cfg: Optional[adam.AdamConfig] = None) -> Callable:
    adam_cfg = adam_cfg or adam.AdamConfig()
    loss_fn = make_loss_fn(cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_state = adam.apply_update(params, opt_state, grads,
                                                  adam_cfg)
        return new_params, new_state, loss

    return train_step


def make_grad_step(cfg: ModelConfig) -> Callable:
    """Forward+backward only (the offload engine applies the update)."""
    loss_fn = make_loss_fn(cfg)

    def grad_step(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    return grad_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        return lm.prefill(params, cfg, batch["tokens"],
                          batch.get("frames"))
    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, cache, tokens):
        return lm.decode_step(params, cfg, cache, tokens)
    return serve_step
