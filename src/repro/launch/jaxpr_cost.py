"""Exact-trip-count cost walker over jaxprs.

XLA's HloCostAnalysis counts while-loop bodies ONCE (scan trip counts are
ignored) and, on the CPU backend, loses dot FLOPs inside custom-calls.
For the roofline we need honest numbers, so we walk the traced jaxpr of
each step function with a read/write HBM-traffic model:

  FLOPs   dot_general = 2*M*N*K (x batch), conv analogous, elementwise and
          reductions = 1/elem; scan bodies multiplied by their length.

  Bytes   *reads*: every op charges operands NOT produced by a fused
          (elementwise/cast/reshape) chain in the same scope — fused
          producers stay in registers/VMEM, exactly what XLA fusion and
          our Pallas kernels deliver.  *writes*: materialization points
          (dot/conv/reduce/gather/sort outputs), in-place update regions
          (dynamic_update_slice/scatter charge the update, not the
          buffer — donation is verified via alias_size in the compiled
          module), and jaxpr outputs of fused chains (e.g. the new
          optimizer state).  The model prices the fused-Adam update at
          its ideal 7 fp32 words/param and flash attention at q/k/v/o
          traffic when ``vmem_bytes`` marks block-resident tensors.

  VMEM    with ``vmem_bytes`` > 0, tensors whose PER-DEVICE size fits the
          budget are kernel-block-resident: their reads/writes don't hit
          HBM (the Pallas flash/decode kernels realize this).

All numbers are GLOBAL (whole-mesh) — divide by chips for per-device.
"""
from __future__ import annotations

import math
from typing import Dict, Set

import jax
import numpy as np

FLOPS = "flops"
BYTES = "bytes"


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:
        return 1


def _bytes(aval) -> int:
    try:
        return _size(aval) * aval.dtype.itemsize
    except Exception:
        return 0


def _dot_flops(eqn) -> int:
    dn = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dn
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = 1
    for d in lb:
        batch *= lhs.shape[d]
    k = 1
    for d in lc:
        k *= lhs.shape[d]
    m = _size(lhs) // max(batch * k, 1)
    n = _size(rhs) // max(batch * k, 1)
    return 2 * batch * m * n * k


def _conv_flops(eqn) -> int:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    return 2 * _size(out) * _size(rhs) // max(rhs.shape[-1], 1)


_SUBJAXPR_PRIMS = {
    "pjit", "closed_call", "custom_jvp_call", "custom_vjp_call",
    "custom_vjp_call_jaxpr", "remat", "checkpoint", "remat2",
    "custom_transpose_call", "core_call", "xla_call",
}

_REDUCE_PRIMS = {
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "argmax", "argmin", "cumsum", "cumlogsumexp", "cummax",
    "cumprod", "sort", "top_k", "reduce_window", "select_and_scatter_add",
}

_INPLACE_PRIMS = {"dynamic_update_slice", "scatter", "scatter-add",
                  "scatter_add"}

# fused: stay in registers/VMEM, value charged at a materializing consumer
_FUSED_SHAPE_PRIMS = {
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "slice",
    "iota", "stop_gradient", "copy", "dynamic_slice", "concatenate",
    "pad", "rev", "sharding_constraint", "device_put", "split",
    "expand_dims", "convert_element_type",
}


class _Walker:
    def __init__(self, vmem_bytes: float = 0.0, n_chips: int = 1):
        self.vmem = vmem_bytes
        self.chips = max(n_chips, 1)
        self.flops = 0.0
        self.bytes = 0.0
        self.fused: Set[int] = set()   # ids of vars held in VMEM/registers

    # ------------------------------------------------------------------ #
    def _resident(self, aval) -> bool:
        b = _bytes(aval)
        return self.vmem > 0 and b / self.chips <= self.vmem

    def _read(self, v):
        # persistent values (params, caches, carries entering the scope)
        # always charge; only values PRODUCED inside the fused region
        # (tracked in self.fused) are VMEM/register-resident.
        aval = getattr(v, "aval", None)
        if aval is None:           # literal
            return
        if id(v) in self.fused:
            return
        self.bytes += _bytes(aval)

    def _write(self, v):
        aval = getattr(v, "aval", v)
        if self._resident(aval):
            self.fused.add(id(v))
            return
        self.bytes += _bytes(aval)

    def _mark_fused(self, eqn):
        for v in eqn.outvars:
            self.fused.add(id(v))

    # ------------------------------------------------------------------ #
    def eqn(self, eqn):
        name = eqn.primitive.name

        if name == "scan":
            sub = _Walker(self.vmem, self.chips)
            # body invars are fresh reads per trip; outvars fresh writes
            sub.jaxpr(eqn.params["jaxpr"].jaxpr, charge_outvars=True)
            length = eqn.params["length"]
            self.flops += sub.flops * length
            self.bytes += sub.bytes * length
            return

        if name == "while":
            sub = _Walker(self.vmem, self.chips)
            sub.jaxpr(eqn.params["body_jaxpr"].jaxpr, charge_outvars=True)
            self.flops += sub.flops   # unknown trips: count once
            self.bytes += sub.bytes
            return

        if name == "cond":
            worst = None
            for br in eqn.params["branches"]:
                sub = _Walker(self.vmem, self.chips)
                sub.jaxpr(br.jaxpr, charge_outvars=True)
                if worst is None or sub.flops > worst.flops:
                    worst = sub
            if worst:
                self.flops += worst.flops
                self.bytes += worst.bytes
            return

        if name in _SUBJAXPR_PRIMS:
            sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr") \
                or eqn.params.get("fun_jaxpr")
            if sub is not None:
                self.jaxpr(getattr(sub, "jaxpr", sub))
            return

        if name == "dot_general" or name.startswith("conv_general") \
                or name == "conv":
            self.flops += (_dot_flops(eqn) if name == "dot_general"
                           else _conv_flops(eqn))
            for v in eqn.invars:
                self._read(v)
            for v in eqn.outvars:
                self._write(v)
            return

        if name == "gather":
            # only gathered rows move: out-sized read + out write + idx
            out = eqn.outvars[0]
            if id(eqn.invars[0]) not in self.fused:
                self.bytes += _bytes(out.aval)
            if len(eqn.invars) > 1:
                self._read(eqn.invars[1])
            self._write(out)
            self.flops += _size(out.aval)
            return

        if name in _INPLACE_PRIMS:
            # charge the update region, not the buffer (in-place / donated)
            # dus: (operand, update, *idx); scatter: (operand, idx, upd)
            upd = eqn.invars[1] if name == "dynamic_update_slice" \
                else eqn.invars[2 if len(eqn.invars) > 2 else -1]
            self._read(upd)
            self.bytes += _bytes(upd.aval)   # the HBM write of the region
            self.flops += _size(upd.aval)
            return

        if name in _REDUCE_PRIMS:
            mult = max(math.log2(max(_size(eqn.invars[0].aval), 2)), 1.0) \
                if name == "sort" else 1.0
            self.flops += sum(_size(v.aval) for v in eqn.invars
                              if hasattr(v, "aval")) * mult
            for v in eqn.invars:
                self._read(v)
            for v in eqn.outvars:
                self._write(v)
            return

        if name == "convert_element_type":
            # casts absorb the read at the SOURCE width (int8 cache reads
            # charge int8 bytes; the upcast happens in-registers) and the
            # result stays fused — consumers don't re-charge it.
            src = eqn.invars[0]
            if hasattr(src, "aval"):
                self._read(src)
            self._mark_fused(eqn)
            return

        if name in _FUSED_SHAPE_PRIMS:
            # views flow through registers: propagate fusion status;
            # a view of unfused data stays unfused (consumers charge it)
            src = eqn.invars[0] if (eqn.invars and hasattr(
                eqn.invars[0], "aval")) else None
            if src is None or id(src) in self.fused \
                    or self._resident(eqn.outvars[0].aval):
                self._mark_fused(eqn)
            return

        # generic elementwise: fused chain — reads charged for non-fused
        # operands, output stays in registers
        self.flops += sum(_size(v.aval) for v in eqn.outvars)
        for v in eqn.invars:
            self._read(v)
            if hasattr(v, "aval"):
                self.fused.add(id(v))   # subsequent uses are re-reads of
                # a now-resident value within the fusion scope
        self._mark_fused(eqn)

    def jaxpr(self, jaxpr, charge_outvars: bool = False):
        for eqn in jaxpr.eqns:
            self.eqn(eqn)
        if charge_outvars:
            for v in jaxpr.outvars:
                if id(v) in self.fused:   # fused chains must materialize
                    self.bytes += _bytes(getattr(v, "aval", v))


def step_cost(fn, *args, vmem_bytes: float = 0.0,
              n_chips: int = 1) -> Dict[str, float]:
    """Trace fn(*args) and return {'flops', 'bytes'} (global, exact trips).

    vmem_bytes > 0 enables the VMEM-residency fusion model (per-device
    tensors under the budget never hit HBM inside kernels).
    """
    closed = jax.jit(fn).trace(*args).jaxpr
    w = _Walker(vmem_bytes, n_chips)
    w.jaxpr(closed.jaxpr, charge_outvars=True)
    return {FLOPS: w.flops, BYTES: w.bytes}
