"""Post-SPMD HLO analysis: collective bytes, op census, roofline terms.

``compiled.cost_analysis()`` gives FLOPs and bytes accessed but NOT
collective traffic; we parse the optimized HLO text and sum operand sizes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, converting to per-device wire bytes with ring-algorithm
factors (convention documented in EXPERIMENTS.md §Roofline):

    all-gather         out_bytes * (n-1)/n
    reduce-scatter     out_bytes * (n-1)
    all-reduce         2 * bytes * (n-1)/n
    all-to-all         bytes * (n-1)/n
    collective-permute bytes
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_NEW_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_NEW_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_OLD_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0          # per-device wire bytes (ring model)
    result_bytes: int = 0
    counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    by_op_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, op: str, wire: float, result: int):
        self.wire_bytes += wire
        self.result_bytes += result
        self.counts[op] = self.counts.get(op, 0) + 1
        self.by_op_bytes[op] = self.by_op_bytes.get(op, 0.0) + wire


_COMP_HEAD_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[\\\":{\s]+n[\\\":\s]+(\d+)')
_CALL_RE = re.compile(r"(?:to_apply|calls|body|condition|branch_computations)="
                      r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")


def _parse_computations(hlo_text: str) -> Dict[str, List[str]]:
    """Split HLO text into computation-name -> list of instruction lines."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        m = _COMP_HEAD_RE.match(s)
        if m and s.endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            continue
        if s == "}":
            cur = None
            continue
        if cur is not None and s:
            comps[cur].append(s)
    return comps


def _line_collective(s: str, n_devices: int):
    """Return (op, wire_bytes, result_bytes) if the line is a collective."""
    op = None
    for c in _COLLECTIVES:
        if f" {c}(" in s or f" {c}-start(" in s:
            op = c
            break
    if op is None or "-done(" in s:
        return None
    try:
        _, rhs = s.split("=", 1)
    except ValueError:
        return None
    type_part = rhs.split(op)[0]
    rbytes = sum(_shape_bytes(d, dims)
                 for d, dims in _SHAPE_RE.findall(type_part))
    if rbytes == 0:
        return None
    n = _group_size(s, n_devices)
    if n <= 1:
        return None
    frac = (n - 1) / n
    if op == "all-gather":
        wire = rbytes * frac
    elif op == "reduce-scatter":
        wire = rbytes * (n - 1)
    elif op == "all-reduce":
        wire = 2.0 * rbytes * frac
    elif op == "all-to-all":
        wire = rbytes * frac
    else:  # collective-permute
        wire = float(rbytes)
    return op, wire, rbytes


def collective_bytes(hlo_text: str, n_devices: int) -> CollectiveStats:
    """Collective wire bytes per device, with while-loop trip counts.

    Walks the computation graph from ENTRY; a ``while`` op multiplies its
    body/condition computations by the ``known_trip_count`` XLA records in
    backend_config (1 if absent).  Fusion computations (kLoop/kOutput) hold
    no collectives, so only call/while/cond edges matter.
    """
    comps = _parse_computations(hlo_text)
    entry = None
    for raw in hlo_text.splitlines():
        s = raw.strip()
        if s.startswith("ENTRY"):
            m = _COMP_HEAD_RE.match(s)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: treat whole text as one computation, trips=1
        stats = CollectiveStats()
        for line in hlo_text.splitlines():
            r = _line_collective(line.strip(), n_devices)
            if r:
                stats.add(*r)
        return stats

    stats = CollectiveStats()
    import functools

    @functools.lru_cache(maxsize=None)
    def comp_cost(name: str) -> Tuple[Tuple[str, float, int], ...]:
        """Flattened (op, wire, result) contributions of one computation."""
        out: List[Tuple[str, float, int]] = []
        for line in comps.get(name, ()):
            r = _line_collective(line, n_devices)
            if r:
                out.append(r)
            if " while(" in line:
                m = _WHILE_RE.search(line)
                trips = 1
                tm = _TRIP_RE.search(line)
                if tm:
                    trips = int(tm.group(1))
                if m:
                    body = m.group(1)
                    for (op, w, rb) in comp_cost(body):
                        out.append((op, w * trips, rb))
            elif "fusion(" in line or " call(" in line or " conditional(" \
                    in line or "to_apply=" in line:
                for mm in _CALL_RE.finditer(line):
                    for cname in mm.group(1).split(","):
                        cname = cname.strip().lstrip("%")
                        if cname in comps and cname != name:
                            out.extend(comp_cost(cname))
        return tuple(out)

    for (op, w, rb) in comp_cost(entry):
        stats.add(op, w, rb)
    return stats


# ---------------------------------------------------------------------- #
# Roofline terms (TPU v5e constants per the assignment).                  #
# ---------------------------------------------------------------------- #
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # per chip
ICI_BW = 50e9                  # per link (wire-byte convention above)


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float             # total across chips
    hlo_bytes: float             # total across chips
    wire_bytes_per_dev: float
    model_flops: float
    n_chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the bound term that is the compute term — how close
        the step is to being compute-limited at peak."""
        bound = max(self.compute_s, self.memory_s, self.collective_s)
        return self.compute_s / max(bound, 1e-30)

    def to_dict(self) -> Dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "wire_bytes_per_dev": self.wire_bytes_per_dev,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "n_chips": self.n_chips,
        }


def roofline_terms(total_flops: float, total_bytes: float,
                   stats: CollectiveStats, n_chips: int,
                   model_flops: float) -> Roofline:
    """total_flops/bytes are GLOBAL (jaxpr_cost.step_cost, exact trips)."""
    return Roofline(
        compute_s=total_flops / (n_chips * PEAK_FLOPS_BF16),
        memory_s=total_bytes / (n_chips * HBM_BW),
        collective_s=stats.wire_bytes / ICI_BW,
        hlo_flops=total_flops,
        hlo_bytes=total_bytes,
        wire_bytes_per_dev=stats.wire_bytes,
        model_flops=model_flops,
        n_chips=n_chips,
    )


_DUS_RE = re.compile(r"= (\w+)\[([\d,]+)\]\{[^}]*\} dynamic-update-slice\(")


def saved_stack_bytes(hlo_text: str) -> Dict[str, int]:
    """Unique dynamic-update-slice result shapes = persistent scan stacks
    (remat-saved residuals / ys buffers), one buffer per shape.

    This is the *structural* per-device activation-stack footprint; the
    XLA:CPU temp_size additionally holds transients its scheduler keeps
    alive that a TPU buffer assignment would not (documented in
    EXPERIMENTS.md §Dry-run)."""
    shapes = {}
    for m in _DUS_RE.finditer(hlo_text):
        d, dims = m.groups()
        n = 1
        for x in dims.split(","):
            n *= int(x)
        shapes[f"{d}[{dims}]"] = n * _DTYPE_BYTES.get(d, 4)
    total = sum(shapes.values())
    top = dict(sorted(shapes.items(), key=lambda kv: -kv[1])[:8])
    return {"total_bytes": total, "top_stacks": top}


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N_active·D for training, 2·N_active·D for inference
    steps (D = tokens processed by the step)."""
    n_active = cfg.active_param_count()
    if shape.step == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.step == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n_active * tokens
