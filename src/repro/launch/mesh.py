"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

from typing import Tuple

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """axis_types=Auto where the jax version has AxisType (>=0.5);
    older versions default to Auto semantics without the kwarg."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests / examples / elastic restore)."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def dp_axes(mesh) -> Tuple[str, ...]:
    """Axes that carry data parallelism (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def dp_size(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in dp_axes(mesh):
        n *= sizes[a]
    return n


def tp_size(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("model", 1)
