"""Training CLI: config-driven, sharded, checkpointed, elastic.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
        --steps 50 --mesh 1x1 --ckpt-dir /tmp/ck

On a real pod, --mesh 16x16 (or 2x16x16 with a pod axis) applies the
production shardings (FSDP x TP, ZeRO state, donated buffers); --restore
re-shards the latest checkpoint onto whatever mesh is given (elastic).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import store
from ..configs import get_config, get_smoke_config
from ..data.pipeline import DataConfig, DataIterator
from ..models import lm, psharding as PS, shardings as sh
from ..optim import AdamConfig, init_state
from . import steps as steps_mod
from .mesh import dp_axes, make_mesh


def parse_mesh(spec: str):
    dims = tuple(int(x) for x in spec.split("x"))
    axes = {1: ("model",), 2: ("data", "model"),
            3: ("pod", "data", "model")}[len(dims)]
    return make_mesh(dims, axes)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    mesh = parse_mesh(args.mesh)
    dp = dp_axes(mesh)
    PS.set_mesh(mesh, dp=dp, tp="model")
    acfg = AdamConfig(lr=args.lr, compress_grads=args.compress_grads)

    with mesh:
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        p_specs = sh.param_pspecs(jax.eval_shape(lambda: params), mesh)
        params = jax.tree.map(
            lambda x, s: jax.device_put(
                x, jax.sharding.NamedSharding(mesh, s)),
            params, p_specs)
        opt = init_state(params, acfg)
        step_fn = jax.jit(steps_mod.make_train_step(cfg, acfg),
                          donate_argnums=(0, 1))

        dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                        global_batch=args.batch)
        it = DataIterator(dc)
        start = 0
        if args.ckpt_dir and store.latest_step(args.ckpt_dir) is not None:
            state = {"params": params, "opt": opt}
            state, meta = store.restore(args.ckpt_dir, state)
            params, opt = state["params"], state["opt"]
            start = int(meta.get("step", 0))
            it.restore({"step": start})
            print(f"restored step {start} (elastic re-shard onto "
                  f"{args.mesh})")

        for i in range(start, args.steps):
            b = next(it)
            t0 = time.perf_counter()
            params, opt, loss = step_fn(
                params, opt, {"tokens": jnp.asarray(b["tokens"]),
                              "labels": jnp.asarray(b["labels"])})
            if i % 10 == 0 or i == args.steps - 1:
                jax.block_until_ready(loss)
                print(f"step {i:4d} loss={float(loss):.4f} "
                      f"({(time.perf_counter()-t0)*1e3:.0f} ms)")
            if args.ckpt_dir and args.ckpt_every and i \
                    and i % args.ckpt_every == 0:
                store.save(args.ckpt_dir, i,
                           {"params": params, "opt": opt},
                           metadata={"step": i})
        if args.ckpt_dir:
            store.save(args.ckpt_dir, args.steps,
                       {"params": params, "opt": opt},
                       metadata={"step": args.steps})
    print("done")


if __name__ == "__main__":
    main()
