"""Training CLI: config-driven, sharded, checkpointed, elastic.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
        --steps 50 --mesh 1x1 --ckpt-dir /tmp/ck

On a real pod, --mesh 16x16 (or 2x16x16 with a pod axis) applies the
production shardings (FSDP x TP, ZeRO state, donated buffers); --restore
re-shards the latest checkpoint onto whatever mesh is given (elastic).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..checkpoint import store
from ..configs import get_config, get_smoke_config
from ..data.pipeline import DataConfig, DataIterator
from ..models import lm, psharding as PS, shardings as sh
from ..optim import AdamConfig, init_state
from . import steps as steps_mod
from .mesh import dp_axes, make_mesh


def parse_mesh(spec: str):
    dims = tuple(int(x) for x in spec.split("x"))
    axes = {1: ("model",), 2: ("data", "model"),
            3: ("pod", "data", "model")}[len(dims)]
    return make_mesh(dims, axes)


class _TrainTelemetry:
    """Telemetry + placement sidecar for the training loop (--adaptive).

    Records the step's per-phase traffic (params fwd/bwd, grad transfer,
    optimizer sweep over fp32 state) through a sampling front-end, runs
    phase detection, and periodically re-plans the training-state
    placement over the TPU tier set from the *measured* traffic —
    printing every costmodel-gated decision.

    Placement is no longer plan-only: the fp32 optimizer state (Adam
    master/m/v) is mirrored into a ``repro.pool.TieredStateStore``
    registered under the ``tenant`` namespace of a ``ResidencyLedger``,
    and the replanner's ``MigrationExecutor`` executes applied deltas
    through the store's ``move_fn`` — real ``jax.device_put`` block
    re-placements between memory kinds, refreshed with the live
    optimizer values right before each due replan and recorded in the
    ledger (closing the ROADMAP "executing replans for training state"
    item).
    """

    OPT_OBJ = "opt_state_fp32"

    def __init__(self, params, opt, replan_every: int, sample_rate: float,
                 topology: str = None, tenant: str = "train",
                 predictive: bool = False, calibrate: bool = False):
        from ..core.migration import MigrationExecutor
        from ..core.tiers import tpu_v5e_tiers
        from ..pool import ResidencyLedger, TieredStateStore
        from ..obs import MetricsRegistry, PredictionLedger, TraceRecorder
        from ..telemetry import (AccessSampler, AccessTrace,
                                 AdaptiveReplanner, PhaseDetector,
                                 ReplanConfig, SamplerConfig)
        self.trace = AccessTrace()
        self.sampler = AccessSampler(
            self.trace, SamplerConfig(sample_rate=sample_rate))
        self.phases = PhaseDetector(self.trace)
        # observability plane: control-plane trace (step-indexed clock —
        # the loop drives epochs, not wall time) + metrics registry
        self._epoch = 0
        self.tracer = TraceRecorder(clock=lambda: float(self._epoch))
        self.registry = MetricsRegistry()
        graph, fast = None, "HBM"
        if topology:
            from ..topology import build_topology
            tb = build_topology(topology)
            graph, fast = tb.graph, tb.fast
            tiers = {k: v for k, v in tb.tiers.items()
                     if v.kind != "nvme"}
            for line in tb.describe():
                print(line)
        else:
            tiers = {k: v for k, v in tpu_v5e_tiers().items()
                     if k in ("HBM", "HOST")}
        self.fast = fast
        self.tenant = tenant
        self.predictive = predictive
        self.replan_every = max(replan_every, 1)
        slow = [t for t in tiers if t != fast][-1]
        self.ledger = ResidencyLedger(tiers)
        self.ledger.register_tenant(tenant, trace=self.trace)
        self.store = TieredStateStore(self.ledger, tenant)
        self.param_bytes = sum(
            p.nbytes for p in jax.tree.leaves(params))
        # fp32 optimizer state lives in the store, first-touch on the
        # slow tier (where a host-offload allocator would put it)
        self.store.put(self.OPT_OBJ, self._opt_fp32(opt),
                       [(slow, 1.0)])
        # bf16 params are device-resident by construction: client-origin
        # fast residency the planner may pin but never has to move
        self.ledger.register(tenant, "params_bf16",
                             {fast: self.param_bytes})
        # prediction audit plane: always on — move-time forecasts join
        # wall-clock outcomes (the store's move_fn does real device_put)
        self.audit = PredictionLedger(registry=self.registry,
                                      tracer=self.tracer)
        # QoS flow attribution: with a topology, each step's optimizer
        # sweep is published as a write-class flow (fp32 state streamed
        # from its resident tier to the fast tier), so a co-located
        # serving tenant's blame plane can name this trainer as the
        # antagonist — and qos.offered.* gauges land in --metrics-out
        self.blame = None
        self.graph = graph
        if graph is not None:
            from ..obs import BlameLedger
            self.blame = BlameLedger(
                graph, registry=self.registry, tracer=self.tracer,
                clock=lambda: float(self._epoch))
        self.calibrator = None
        if calibrate:
            from ..core.tiered_array import TIER_TO_MEMORY_KIND
            from ..obs import (CostModelCalibrator, TierProbe,
                               measure_transfer_probes)
            self.calibrator = CostModelCalibrator(tiers, graph=graph)
            # probe each movable tier's memory kind with real transfers,
            # then re-key the bandwidth observations by tier name (the
            # fit wants tier-space probes; kinds may be shared)
            tier_kind = {t: TIER_TO_MEMORY_KIND.get(t, "device")
                         for t in tiers if t != fast}
            by_kind = {p.tier: p for p in measure_transfer_probes(
                kinds=sorted(set(tier_kind.values()) - {"device"}),
                n_mb=16, iters=2)}
            self.calibrator.fit_probes(
                TierProbe(t, by_kind[k].bw_GBps)
                for t, k in sorted(tier_kind.items()) if k in by_kind)
        self.replanner = AdaptiveReplanner(
            self.trace, tiers, fast,
            cfg=ReplanConfig(replan_every=self.replan_every,
                             window_epochs=self.replan_every),
            executor=MigrationExecutor(tiers, move_fn=self.store.move_fn,
                                       topology=graph),
            default_tier=slow,
            topology=graph, ledger=self.ledger, tenant=tenant,
            tracer=self.tracer, audit=self.audit,
            calibrator=self.calibrator)
        self.replanner.executor.tracer = self.tracer
        self.replanner.executor.audit = self.audit
        self.replanner.executor.calibrator = self.calibrator
        # the store's move_fn performs physical jax.device_put block
        # re-placements, so executor wall times share the model's unit
        self.replanner.executor.physical_moves = True
        self.replanner.executor.recalibrate()
        self.nbytes = {
            "params_bf16": self.param_bytes,
            "grads_bf16": self.param_bytes,
            self.OPT_OBJ: self.store.nbytes(self.OPT_OBJ),
        }

    @staticmethod
    def _opt_fp32(opt):
        """The movable fp32 subtree of the Adam state."""
        return {k: opt[k] for k in ("master", "m", "v") if k in opt}

    def on_step(self, step: int, opt=None) -> None:
        from ..offload.train_engine import emit_step_traffic
        emit_step_traffic(self.sampler, self.param_bytes)
        self.phases.update()
        epoch = step + 1
        self._epoch = epoch
        self.tracer.event("phase.update", cat="phase", epoch=epoch,
                          label=str(self.phases.label),
                          shifts=len(self.phases.shifts))
        if self.blame is not None:
            self._publish_qos_flows(epoch)
        if opt is not None and epoch % self.replan_every == 0:
            # refresh the mirror so an applied replan migrates the
            # *current* optimizer bytes, not the init-time ones
            self.store.update(self.OPT_OBJ, self._opt_fp32(opt))
        if self.calibrator is not None \
                and epoch % self.replan_every == 0:
            # fold online residual corrections into the planning tiers
            self.replanner.recalibrate()
        d = None
        if self.predictive and self.phases.signature is not None:
            # key plans by recurrence signature; pre-stage the proven
            # plan of a phase predicted to start next epoch
            cur = self.phases.expected_signature(1)
            nxt = self.phases.expected_signature(2)
            if nxt is not None and nxt != cur:
                d = self.replanner.prefetch_phase(epoch, self.nbytes,
                                                  nxt)
            if d is None:
                d = self.replanner.maybe_replan(
                    epoch, self.nbytes, pin_fast=("params_bf16",),
                    phase=cur)
        else:
            d = self.replanner.maybe_replan(epoch, self.nbytes,
                                            pin_fast=("params_bf16",),
                                            phase=self.phases.label)
        if d is not None and d.reason != "initial":
            print(f"  replan@{step}: {'applied' if d.applied else 'kept'} "
                  f"({d.reason}) old={d.old_step_s*1e3:.1f} ms "
                  f"new={d.new_step_s*1e3:.1f} ms "
                  f"migration={d.migration_s*1e3:.1f} ms "
                  f"moved={d.moved_bytes/1e6:.2f} MB")

    def _publish_qos_flows(self, epoch: int) -> None:
        """Publish this step's optimizer-sweep traffic into the blame
        book: the fp32 state resident off the fast tier streams across
        the topology every step (normalized to a 1 s step period, so
        offered GB/s == GB moved per step)."""
        from ..topology import Flow
        dst = self.graph.node_of(self.fast)
        if dst is None:
            return
        flows = []
        place = self.ledger.placement(self.tenant, self.OPT_OBJ)
        for tier, nbytes in sorted(place.items()):
            src = self.graph.node_of(tier)
            if src is None or src == dst or nbytes <= 0:
                continue
            flows.append(Flow(src, dst, nbytes / 1e9, cls="write",
                              tenant=self.tenant))
        self.blame.publish_flows(self.tenant, flows, now=float(epoch))

    def opt_bytes_on(self, tier: str) -> int:
        """Ledger view of the optimizer state's tier residency."""
        return self.ledger.object_bytes(self.tenant, self.OPT_OBJ, tier)

    def write_artifacts(self, trace_out=None, metrics_out=None,
                        audit_out=None) -> None:
        """--trace-out / --metrics-out / --audit-out exports."""
        if trace_out:
            if trace_out.endswith(".jsonl"):
                n = self.tracer.to_jsonl(trace_out)
                kind = "jsonl"
            else:
                n = self.tracer.to_chrome(trace_out)
                kind = "chrome trace_event"
            print(f"trace: wrote {n} events ({kind}) -> {trace_out}")
        if metrics_out:
            self.registry.set_gauges(self.replanner.summary(),
                                     prefix="train.replan")
            self.registry.set_gauges(
                {"trace_events": float(self.trace.total_events),
                 "profiling_samples": float(self.sampler.samples),
                 "profiling_overhead_s": self.sampler.overhead_s,
                 "phase_shifts": float(len(self.phases.shifts))},
                prefix="train.telemetry")
            self.ledger.publish(self.registry)
            self.registry.set_gauges(self.audit.summary())
            if self.calibrator is not None:
                self.calibrator.publish(self.registry)
            with open(metrics_out, "w") as fh:
                fh.write(self.registry.to_prometheus_text())
            print(f"metrics: wrote {len(self.registry.names())} series "
                  f"(prometheus text) -> {metrics_out}")
        if audit_out:
            import json

            payload = {"audit": self.audit.report()}
            if self.calibrator is not None:
                payload["calibration"] = self.calibrator.summary()
            with open(audit_out, "w") as fh:
                json.dump(payload, fh, indent=2, sort_keys=True)
            print(f"audit: wrote prediction residual report -> "
                  f"{audit_out}")

    def report(self) -> None:
        place = self.ledger.placement(self.tenant, self.OPT_OBJ)
        placed = " ".join(f"{t}={b/1e6:.1f}MB"
                          for t, b in sorted(place.items()))
        print(f"telemetry: {self.trace.total_events} events, "
              f"{self.sampler.samples} samples, "
              f"overhead={self.sampler.overhead_s*1e3:.2f} ms, "
              f"phase={self.phases.label} "
              f"(shifts={len(self.phases.shifts)}), "
              f"replans={self.replanner.replans_applied}/"
              f"{len(self.replanner.decisions)} "
              f"(cache_hits={self.replanner.plan_cache_hits}, "
              f"prefetches={self.replanner.prefetches}), "
              f"tier_order={'>'.join(self.replanner.tier_order)}")
        print(f"ledger[{self.tenant}]: opt_state moved="
              f"{self.ledger.counters.migrated_bytes/1e6:.2f} MB "
              f"placement: {placed}")
        if self.audit.matched:
            accs = " ".join(
                f"acc[{m}]={self.audit.accuracy(m):.2f}"
                for m in self.audit.models())
            print(f"audit: joins={self.audit.matched} {accs}"
                  + (f" calib_obs={self.calibrator.observations}"
                     if self.calibrator is not None else ""))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--adaptive", action="store_true",
                    help="record per-phase access telemetry, replan "
                         "host-tier placement online, and migrate the "
                         "fp32 optimizer state through a "
                         "TieredStateStore (repro.telemetry/pool)")
    ap.add_argument("--replan-every", type=int, default=None,
                    help="steps between adaptive replan attempts "
                         "(default 10; requires --adaptive)")
    ap.add_argument("--sample-rate", type=float, default=None,
                    help="telemetry sampling rate (fraction of cache "
                         "lines); 1.0 = full instrumentation, right "
                         "for smoke-scale traffic — drop toward "
                         "PEBS-like 1e-6 on production-size models "
                         "(default 1.0; requires --adaptive)")
    ap.add_argument("--tenant", default=None,
                    help="residency-ledger tenant namespace for this "
                         "run's training state (default: train; "
                         "requires --adaptive)")
    ap.add_argument("--predictive", action="store_true",
                    help="key replans by phase recurrence signature "
                         "and pre-stage the proven plan of a predicted "
                         "next phase (requires --adaptive)")
    ap.add_argument("--trace-out", default=None,
                    help="write the control-plane trace here after the "
                         "run: .jsonl = one event per line, else Chrome "
                         "trace_event JSON (requires --adaptive)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics registry as Prometheus "
                         "text exposition here (requires --adaptive)")
    ap.add_argument("--calibrate", action="store_true",
                    help="self-calibrating cost model: probe the "
                         "movable tiers' memory kinds with real "
                         "transfers at startup and keep correcting "
                         "planning bandwidths online from audited "
                         "move-time residuals (requires --adaptive)")
    ap.add_argument("--audit-out", default=None,
                    help="write the prediction-audit residual report "
                         "(JSON: per-model accuracy, p95 relative "
                         "error, drift state) here (requires "
                         "--adaptive)")
    from ..topology import TOPOLOGY_CHOICES
    ap.add_argument("--topology", default=None,
                    choices=list(TOPOLOGY_CHOICES),
                    help="with --adaptive: plan over this machine "
                         "topology (hop distance, link bandwidth) "
                         "instead of the flat HBM/HOST pair")
    args = ap.parse_args(argv)
    if not args.adaptive:
        # these knobs only affect the adaptive path: accepting them
        # silently would let a typo'd run think it was adaptive
        for flag, val in (("--replan-every", args.replan_every),
                          ("--sample-rate", args.sample_rate),
                          ("--tenant", args.tenant),
                          ("--trace-out", args.trace_out),
                          ("--metrics-out", args.metrics_out),
                          ("--audit-out", args.audit_out)):
            if val is not None:
                ap.error(f"{flag} only takes effect with --adaptive "
                         f"(the telemetry sidecar is what consumes it)")
        if args.predictive:
            ap.error("--predictive requires --adaptive (prediction "
                     "pre-stages the adaptive replanner's phase-cached "
                     "plans)")
        if args.calibrate:
            ap.error("--calibrate requires --adaptive (the corrections "
                     "feed the adaptive replanner's cost model)")
    if args.replan_every is None:
        args.replan_every = 10
    if args.sample_rate is None:
        args.sample_rate = 1.0
    if args.tenant is None:
        args.tenant = "train"
    if not 0.0 < args.sample_rate <= 1.0:
        ap.error(f"--sample-rate must be in (0, 1], "
                 f"got {args.sample_rate}")
    if args.topology and not args.adaptive:
        ap.error("--topology only takes effect with --adaptive (the "
                 "replanner is what plans over the topology)")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    mesh = parse_mesh(args.mesh)
    dp = dp_axes(mesh)
    PS.set_mesh(mesh, dp=dp, tp="model")
    acfg = AdamConfig(lr=args.lr, compress_grads=args.compress_grads)

    with mesh:
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        p_specs = sh.param_pspecs(jax.eval_shape(lambda: params), mesh)
        params = jax.tree.map(
            lambda x, s: jax.device_put(
                x, jax.sharding.NamedSharding(mesh, s)),
            params, p_specs)
        opt = init_state(params, acfg)
        step_fn = jax.jit(steps_mod.make_train_step(cfg, acfg),
                          donate_argnums=(0, 1))

        dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                        global_batch=args.batch)
        it = DataIterator(dc)
        start = 0
        if args.ckpt_dir and store.latest_step(args.ckpt_dir) is not None:
            state = {"params": params, "opt": opt}
            state, meta = store.restore(args.ckpt_dir, state)
            params, opt = state["params"], state["opt"]
            start = int(meta.get("step", 0))
            it.restore({"step": start})
            print(f"restored step {start} (elastic re-shard onto "
                  f"{args.mesh})")

        telem = (_TrainTelemetry(params, opt, args.replan_every,
                                 args.sample_rate, args.topology,
                                 tenant=args.tenant,
                                 predictive=args.predictive,
                                 calibrate=args.calibrate)
                 if args.adaptive else None)
        for i in range(start, args.steps):
            b = next(it)
            t0 = time.perf_counter()
            params, opt, loss = step_fn(
                params, opt, {"tokens": jnp.asarray(b["tokens"]),
                              "labels": jnp.asarray(b["labels"])})
            if telem is not None:
                telem.on_step(i, opt)
            if i % 10 == 0 or i == args.steps - 1:
                jax.block_until_ready(loss)
                print(f"step {i:4d} loss={float(loss):.4f} "
                      f"({(time.perf_counter()-t0)*1e3:.0f} ms)")
            if args.ckpt_dir and args.ckpt_every and i \
                    and i % args.ckpt_every == 0:
                store.save(args.ckpt_dir, i,
                           {"params": params, "opt": opt},
                           metadata={"step": i})
        if args.ckpt_dir:
            store.save(args.ckpt_dir, args.steps,
                       {"params": params, "opt": opt},
                       metadata={"step": args.steps})
        if telem is not None:
            telem.report()
            telem.write_artifacts(args.trace_out, args.metrics_out,
                                  args.audit_out)
    print("done")
    return telem


if __name__ == "__main__":
    main()
