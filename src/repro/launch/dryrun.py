import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
initialization, and the production meshes need 512 placeholder devices.
Do NOT import this module from tests/benchmarks (they want 1 device);
run it as ``python -m repro.launch.dryrun``.

Per cell this produces a JSON artifact with:
  * memory_analysis (per-device bytes — proves the cell fits),
  * cost_analysis (FLOPs / bytes for the roofline),
  * parsed collective wire bytes + op census,
  * the three roofline terms + dominant bottleneck.
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from ..configs.base import SHAPES
from ..configs.registry import ASSIGNED_ARCHS, assigned_cells, get_config
from ..optim import adam
from . import hlo_analysis as H
from .mesh import make_production_mesh
from .specs import build_cell

ART_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _mem_dict(mem) -> dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["total_per_device"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0))
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             adam_cfg: adam.AdamConfig | None = None,
             save: bool = True, verbose: bool = True) -> dict:
    mesh_tag = "multipod" if multi_pod else "singlepod"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    cell = build_cell(arch, shape_name, mesh, adam_cfg=adam_cfg)

    with mesh:
        lowered = cell.jit().lower(*cell.args)
        compiled = lowered.compile()

    mem = _mem_dict(compiled.memory_analysis())
    cost = dict(compiled.cost_analysis() or {})
    hlo = compiled.as_text()
    stats = H.collective_bytes(hlo, n_chips)
    # structural memory: exact per-device argument shards + scan stacks
    import numpy as _np

    def _shard_bytes(s):
        shsh = s.sharding.shard_shape(s.shape) if getattr(
            s, "sharding", None) is not None else s.shape
        return int(_np.prod(shsh)) * s.dtype.itemsize

    arg_bytes = sum(_shard_bytes(l) for l in jax.tree.leaves(cell.args))
    stacks = H.saved_stack_bytes(hlo)
    structural = {
        "argument_bytes_per_dev": arg_bytes,
        "saved_stack_bytes_per_dev": stacks["total_bytes"],
        "top_stacks": stacks["top_stacks"],
        "structural_total_per_dev": arg_bytes + stacks["total_bytes"],
    }
    mf = H.model_flops_estimate(cell.model_cfg, cell.shape)
    # exact-trip-count global flops/bytes from the traced program
    # (XLA cost_analysis undercounts while bodies; see jaxpr_cost.py)
    from . import jaxpr_cost as JC
    jc = JC.step_cost(cell.fn, *cell.args)
    # VMEM-residency model: block-sized tensors stay on-chip inside the
    # Pallas-kernel-fused attention/softmax chains (64 MiB budget)
    jc_fused = JC.step_cost(cell.fn, *cell.args,
                            vmem_bytes=64 * 1024**2, n_chips=n_chips)
    roof = H.roofline_terms(jc["flops"], jc["bytes"], stats, n_chips, mf)
    roof_fused = H.roofline_terms(jc_fused["flops"], jc_fused["bytes"],
                                  stats, n_chips, mf)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "n_chips": n_chips, "step": cell.step_name,
        "status": "ok", "compile_s": round(time.time() - t0, 1),
        "memory_analysis": mem,
        "memory_structural": structural,
        "cost_analysis_raw": {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))},
        "jaxpr_cost_global": jc,
        "jaxpr_cost_vmem_fused": jc_fused,
        "roofline_vmem_fused": roof_fused.to_dict(),
        "collectives": {
            "wire_bytes_per_dev": stats.wire_bytes,
            "counts": stats.counts,
            "by_op_bytes": stats.by_op_bytes,
        },
        "roofline": roof.to_dict(),
    }
    if verbose:
        hbm = mem.get("total_per_device", 0) / 2**30
        sm = structural["structural_total_per_dev"] / 2**30
        print(f"[{arch} x {shape_name} x {mesh_tag}] OK "
              f"compile={result['compile_s']}s "
              f"mem/dev={hbm:.2f} GiB (structural {sm:.2f}) "
              f"flops/dev={jc['flops']/n_chips:.3e} "
              f"wire/dev={stats.wire_bytes/2**20:.1f} MiB "
              f"dominant={roof.dominant} "
              f"useful={roof.useful_flops_ratio:.2f} "
              f"roofline_frac={roof.roofline_fraction:.3f}")
    if save:
        ART_DIR.mkdir(parents=True, exist_ok=True)
        out = ART_DIR / f"{arch}__{shape_name}__{mesh_tag}.json"
        out.write_text(json.dumps(result, indent=1))
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every assigned (arch x shape) cell")
    ap.add_argument("--compress-grads", action="store_true",
                    help="bf16+error-feedback gradient compression")
    args = ap.parse_args(argv)

    adam_cfg = adam.AdamConfig(compress_grads=args.compress_grads) \
        if args.compress_grads else None

    cells = []
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    for a in archs:
        shapes = assigned_cells(a) if (args.all or not args.shape) \
            else [args.shape]
        for s in shapes:
            if args.both_meshes:
                cells.append((a, s, False))
                cells.append((a, s, True))
            else:
                cells.append((a, s, args.multi_pod))

    failures = 0
    for a, s, mp in cells:
        try:
            run_cell(a, s, mp, adam_cfg=adam_cfg)
        except Exception:
            failures += 1
            tag = "multipod" if mp else "singlepod"
            print(f"[{a} x {s} x {tag}] FAILED", file=sys.stderr)
            traceback.print_exc()
            ART_DIR.mkdir(parents=True, exist_ok=True)
            (ART_DIR / f"{a}__{s}__{tag}.json").write_text(json.dumps(
                {"arch": a, "shape": s, "mesh": tag, "status": "failed",
                 "error": traceback.format_exc()[-2000:]}, indent=1))
    print(f"\ndry-run complete: {len(cells) - failures}/{len(cells)} OK")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
