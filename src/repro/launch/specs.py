"""input_specs(): ShapeDtypeStruct stand-ins + shardings for every cell.

Builds, for a given (arch x shape x mesh), everything the dry-run needs:
the step callable, its abstract arguments (weak-type-correct, shardable,
zero allocation), and pinned output shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig, SHAPES
from ..configs.registry import get_config
from ..models import lm, shardings as sh
from ..optim import adam
from . import steps as steps_mod
from .mesh import dp_axes as mesh_dp_axes


def _struct(tree_shapes, tree_specs, mesh: Mesh, memory_kind=None):
    """Attach NamedShardings to a ShapeDtypeStruct pytree."""
    kw = {"memory_kind": memory_kind} if memory_kind else {}

    def one(s, spec):
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, spec, **kw))

    return jax.tree.map(one, tree_shapes, tree_specs,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass
class Cell:
    """One (arch x shape) dry-run cell, ready to lower."""

    arch: str
    shape: ShapeConfig
    step_name: str            # train_step | prefill_step | serve_step
    fn: Callable
    args: Tuple               # abstract args with shardings
    out_shardings: Any        # or None to let XLA infer
    model_cfg: ModelConfig
    donate_argnums: Tuple[int, ...] = ()

    def jit(self):
        return jax.jit(self.fn, out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)


def param_structs(cfg: ModelConfig, mesh: Mesh,
                  fsdp: Optional[str] = "data"):
    shapes = jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    specs = sh.param_pspecs(shapes, mesh, fsdp=fsdp)
    return shapes, specs


# per-device budget under which inference replicates weights over the
# data axes (TP-only "serving sharding": no per-step FSDP all-gather)
SERVE_REPLICATED_BUDGET = 8 * 1024**3


def _serve_fsdp(cfg: ModelConfig, mesh: Mesh) -> Optional[str]:
    from .mesh import tp_size
    per_dev = 2 * cfg.param_count() / max(tp_size(mesh), 1)
    return None if per_dev <= SERVE_REPLICATED_BUDGET else "data"


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               adam_cfg: Optional[adam.AdamConfig] = None,
               cfg_override: Optional[ModelConfig] = None,
               serve_tp_only: bool = True) -> Cell:
    from ..models import psharding as PS

    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    dp = mesh_dp_axes(mesh)
    B, S = shape.global_batch, shape.seq_len
    # activate logical-axis constraints for everything this cell lowers
    PS.set_mesh(mesh, dp=dp, tp="model")

    fsdp = "data"
    if shape.step in ("prefill", "decode") and serve_tp_only:
        fsdp = _serve_fsdp(cfg, mesh)
    p_shapes, p_specs = param_structs(cfg, mesh, fsdp=fsdp)
    params = _struct(p_shapes, p_specs, mesh)
    tok_spec = sh.batch_pspec(B, mesh, dp)

    if shape.step == "train":
        adam_cfg = adam_cfg or adam.AdamConfig()
        opt_shapes = adam.init_state_shapes(p_shapes, adam_cfg)
        opt_specs = sh.opt_state_pspecs(p_specs, mesh)
        if adam_cfg.compress_grads:
            opt_specs = dict(opt_specs)
            opt_specs["err"] = p_specs
        opt = _struct(opt_shapes, opt_specs, mesh)
        batch = {
            "tokens": jax.ShapeDtypeStruct(
                (B, S), jnp.int32, sharding=NamedSharding(mesh, tok_spec)),
            "labels": jax.ShapeDtypeStruct(
                (B, S), jnp.int32, sharding=NamedSharding(mesh, tok_spec)),
        }
        if cfg.n_frontend_tokens:
            fdim = P(tok_spec[0] if len(tok_spec) else None, None, None)
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16,
                sharding=NamedSharding(mesh, fdim))
        fn = steps_mod.make_train_step(cfg, adam_cfg)
        out_shardings = (
            jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                         is_leaf=lambda x: isinstance(x, P)),
            jax.tree.map(lambda s: NamedSharding(mesh, s), opt_specs,
                         is_leaf=lambda x: isinstance(x, P)),
            NamedSharding(mesh, P()),
        )
        # donate params + opt state: in-place buffer reuse (without it the
        # step holds OLD and NEW optimizer state simultaneously — +2x).
        return Cell(arch, shape, "train_step", fn, (params, opt, batch),
                    out_shardings, cfg, donate_argnums=(0, 1))

    if shape.step == "prefill":
        batch = {
            "tokens": jax.ShapeDtypeStruct(
                (B, S), jnp.int32, sharding=NamedSharding(mesh, tok_spec)),
        }
        if cfg.n_frontend_tokens:
            fdim = P(tok_spec[0] if len(tok_spec) else None, None, None)
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16,
                sharding=NamedSharding(mesh, fdim))
        fn = steps_mod.make_prefill_step(cfg)
        # pin cache output shardings (inference leaves the scan-stacked KV
        # partially replicated otherwise)
        out_shapes = jax.eval_shape(fn, params, batch)
        cache_specs = sh.cache_pspecs(out_shapes[1], mesh, B, dp)
        out_shardings = (
            None,
            jax.tree.map(lambda s: NamedSharding(mesh, s), cache_specs,
                         is_leaf=lambda x: isinstance(x, P)),
        )
        return Cell(arch, shape, "prefill_step", fn, (params, batch),
                    out_shardings, cfg)

    # decode: serve_step with a KV/state cache of seq_len
    max_seq = round_up(S + 64, 4096)
    cache_shapes = jax.eval_shape(
        lambda: lm.make_decode_cache(cfg, B, max_seq,
                                     enc_len=cfg.n_frontend_tokens))
    cache_specs = sh.cache_pspecs(cache_shapes, mesh, B, dp)
    cache = _struct(cache_shapes, cache_specs, mesh)
    tokens = jax.ShapeDtypeStruct(
        (B, 1), jnp.int32, sharding=NamedSharding(mesh, tok_spec))
    fn = steps_mod.make_serve_step(cfg)
    out_shardings = (
        None,  # logits: inferred
        jax.tree.map(lambda s: NamedSharding(mesh, s), cache_specs,
                     is_leaf=lambda x: isinstance(x, P)),
    )
    # donate the cache: decode updates it in place (KV buffers are the
    # dominant memory at 32k/500k context).
    return Cell(arch, shape.__class__(shape.name, S, B, "decode"),
                "serve_step", fn, (params, cache, tokens), out_shardings,
                cfg, donate_argnums=(1,))


def input_specs(arch: str, shape_name: str, mesh: Mesh, **kw):
    """The dry-run entry: abstract inputs for the cell's step function."""
    return build_cell(arch, shape_name, mesh, **kw).args
