"""Serving CLI: one-shot batch or tier-aware continuous batching.

One-shot (FlexGen-style, statically split KV):

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --batch 4 --prompt-len 32 --new-tokens 16 --kv-host-frac 0.5

Continuous batching over the paged, tier-migrating KV pool:

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --scheduler continuous --policy tiering08 --num-requests 6

Adaptive object-level re-interleaving from observed access telemetry
(repro.telemetry) on top of a static split:

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --scheduler continuous --policy static --adaptive \
        --replan-every 8 --sample-rate 1.0

Price placements over a real machine topology (repro.topology) instead
of a flat tier list — e.g. the paper's system A with the CXL card
behind the far socket:

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --scheduler continuous --policy static --adaptive \
        --topology far-socket
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, get_smoke_config
from ..models import lm
from ..offload.serve_engine import FlexGenEngine, ServeConfig


def _fraction(name: str):
    """argparse type: a float that must land in [0, 1]."""
    def parse(text: str) -> float:
        try:
            val = float(text)
        except ValueError as e:
            raise argparse.ArgumentTypeError(
                f"{name} must be a number, got {text!r}") from e
        if not 0.0 <= val <= 1.0:
            raise argparse.ArgumentTypeError(
                f"{name} must be in [0, 1], got {val}")
        return val
    return parse


def _rate(name: str):
    """argparse type: a float in (0, 1] (a sampling rate cannot be 0)."""
    frac = _fraction(name)

    def parse(text: str) -> float:
        val = frac(text)
        if val <= 0.0:
            raise argparse.ArgumentTypeError(
                f"{name} must be positive (use a small rate like 1e-6 "
                f"to minimize profiling, not 0)")
        return val
    return parse


def run_oneshot(args, cfg, params) -> None:
    w = args.weights_host_frac
    k = args.kv_host_frac
    eng = FlexGenEngine(cfg, params, ServeConfig(
        max_new_tokens=args.new_tokens, prompt_len=args.prompt_len,
        weight_shares=[("device", 1 - w), ("pinned_host", w)],
        kv_shares=[("device", 1 - k), ("pinned_host", k)]))
    prompts = np.random.RandomState(0).randint(
        0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
    st = eng.run(prompts)
    print(f"batch={st.batch} prefill={st.prefill_s*1e3:.1f} ms "
          f"decode={st.decode_tok_s:.1f} tok/s "
          f"({st.new_tokens} new tokens/seq; weights {w:.0%} host, "
          f"KV {k:.0%} host)")


def run_continuous(args, cfg, params) -> None:
    from ..serving import ServingConfig, ServingEngine

    # the one builder both the CLI and programmatic callers share:
    # cross-field validation + flat->section migration live in
    # repro.serving.config, not in per-flag parser.error calls here
    sv = ServingConfig.from_args(args)
    eng = ServingEngine(cfg, params, sv)
    rs = np.random.RandomState(0)
    lens = [args.prompt_len, max(args.prompt_len // 2, 4)]
    for i in range(args.num_requests):
        plen = lens[i % len(lens)]
        eng.submit(rs.randint(0, cfg.vocab, (plen,)).astype(np.int32),
                   max_new_tokens=args.new_tokens,
                   arrival_s=i * args.arrival_gap_s)
    t0 = time.perf_counter()
    rep = eng.run()
    wall = time.perf_counter() - t0
    s = rep.summary
    print(f"policy={rep.policy} requests={int(s['requests'])} "
          f"finished={int(s['finished'])} "
          f"iterations={int(s['iterations'])} wall={wall:.2f} s")
    print(f"throughput={s['throughput_tok_s']:.1f} tok/s "
          f"mean_ttft={s['mean_ttft_s']*1e3:.1f} ms "
          f"mean_decode={s['mean_decode_tok_s']:.1f} tok/s/req "
          f"preemptions={int(s['preemptions'])}")
    print(f"kv-pool: blocks={eng.pool.num_blocks} "
          f"fast_budget={eng.pool.fast_block_budget} "
          f"mean_used={s['mean_pool_blocks']:.1f} "
          f"promoted={rep.tiering['promoted']} "
          f"demoted={rep.tiering['demoted']} "
          f"hint_faults={rep.tiering['hint_faults']}")
    t = rep.telemetry
    if t.get("audit.matched", 0.0) > 0:
        acc = {k.split("prediction.accuracy.", 1)[1]: v
               for k, v in sorted(t.items())
               if k.startswith("prediction.accuracy.")}
        print("audit: "
              + f"joins={int(t['audit.matched'])} "
              + " ".join(f"acc[{m}]={v:.2f}" for m, v in acc.items())
              + (f" probes={int(t['calibration.probes'])} "
                 f"obs={int(t['calibration.observations'])}"
                 if args.calibrate else ""))
    print(f"telemetry: events={int(t['trace_events'])} "
          f"samples={int(t['profiling_samples'])} "
          f"overhead={t['profiling_overhead_s']*1e3:.2f} ms "
          f"phase_shifts={int(t['phase_shifts'])}"
          + (f" replans={int(t['replans_applied'])}/"
             f"{int(t['replans_considered'])} "
             f"moved={t['moved_bytes']/1e6:.2f} MB "
             f"denied={t['denied_bytes']/1e6:.2f} MB "
             f"plan_cache_hits={int(t['plan_cache_hits'])}"
             if args.adaptive else "")
          + (f" prefetches={int(t['prefetches'])} "
             f"budget_preemptions={int(t['budget_preemptions'])}"
             if args.predictive else ""))
    if args.expert_policy:
        print(f"experts: policy={args.expert_policy} "
              f"fast={int(t['expert.fast_residents'])} "
              f"hit_ratio={t.get('expert.fast_hit_ratio', 0.0):.2f} "
              f"promoted={int(t['expert.promoted'])} "
              f"demoted={int(t['expert.demoted'])}"
              + (f" prefetch_hit_ratio="
                 f"{t['expert.prefetch_hit_ratio']:.2f}"
                 if "expert.prefetch_hit_ratio" in t else ""))
    if rep.slo.get("targets"):
        for tgt in rep.slo["targets"]:
            rate = tgt.get("violation_rate")
            print(f"slo: {tgt['metric']} "
                  f"p{round(tgt['quantile']*100, 4):g} <= "
                  f"{tgt['threshold_s']*1e3:.1f} ms -> "
                  f"{tgt['violations']} violation(s) over "
                  f"{rep.slo['checks']} check(s)"
                  + (f" rate={rate:.2f}" if rate is not None else ""))
    if args.qos:
        blame = rep.slo.get("blame", {})
        print(f"qos: deferrals={int(t['qos_deferrals'])} "
              f"slo_preemptions={int(t['slo_preemptions'])} "
              f"excursions={blame.get('total_excursions', 0)}"
              + (f" antagonist={blame['top_antagonist']} "
                 f"link={blame['top_link']}"
                 if blame.get("top_antagonist") else ""))
    for rid, row in rep.per_request:
        # undefined latencies are omitted from the row, not -1.0
        ttft = row.get("ttft_s")
        dec = row.get("decode_tok_s")
        ttft_str = f"{ttft*1e3:.1f} ms" if ttft is not None else "n/a"
        dec_str = f"{dec:.1f} tok/s" if dec is not None else "n/a"
        print(f"  req{rid}: prompt={int(row['prompt_tokens'])} "
              f"new={int(row['new_tokens'])} "
              f"ttft={ttft_str} decode={dec_str} "
              f"preempted={int(row['preemptions'])}x")
    _write_obs_artifacts(args, eng)


def run_cluster(args, cfg, params) -> None:
    """Multi-host plane: route the trace across ``--replicas`` engines."""
    from ..cluster import ClusterPlane
    from ..serving import ServingConfig

    sv = ServingConfig.from_args(args)
    plane = ClusterPlane(
        cfg, params, serving=sv, n_replicas=args.replicas,
        router_policy=args.router or "headroom-distance")
    for line in plane.testbed.describe():
        print(line)
    rs = np.random.RandomState(0)
    lens = [args.prompt_len, max(args.prompt_len // 2, 4)]
    for i in range(args.num_requests):
        plen = lens[i % len(lens)]
        plane.submit(rs.randint(0, cfg.vocab, (plen,)).astype(np.int32),
                     args.new_tokens, arrival_s=i * args.arrival_gap_s)
    t0 = time.perf_counter()
    rep = plane.run()
    wall = time.perf_counter() - t0
    s = rep.summary
    print(f"cluster: replicas={int(s['replicas'])} "
          f"router={plane.router.policy} "
          f"requests={int(s['requests'])} "
          f"finished={int(s['finished'])} wall={wall:.2f} s")
    print(f"aggregate: throughput={s['throughput_tok_s']:.1f} tok/s "
          f"worst_p95_latency={s['worst_p95_latency_s']*1e3:.1f} ms "
          f"preemptions={int(s['preemptions'])}")
    for host, n in sorted(rep.routed.items()):
        rsum = getattr(rep.per_replica.get(host), "summary", {})
        print(f"  {host}: routed={n} "
              f"throughput={rsum.get('throughput_tok_s', 0.0):.1f} tok/s "
              f"fast_headroom={plane.replicas[host].fast_headroom_bytes()}"
              f" B dist={plane.testbed.distance_ns('router', host):.0f} ns")
    cons = plane.namespace_conservation()
    total = cons.pop("total")
    assert sum(cons.values()) == total, "namespace aggregation leaked"
    print(f"ledger: tenants={sorted(str(t) for t in plane.ledger.tenants)}"
          f" fast_bytes_by_replica={cons} (sum == replica/* aggregate)")
    if args.trace_out:
        import json

        events = [ev.to_dict() for ev in plane.merged_trace()]
        with open(args.trace_out, "w") as fh:
            for ev in events:
                fh.write(json.dumps(ev, sort_keys=True) + "\n")
        print(f"trace: wrote {len(events)} merged events -> "
              f"{args.trace_out}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as fh:
            fh.write(plane.registry.to_prometheus_text())
        print(f"metrics: wrote {len(plane.registry.names())} series "
              f"(prometheus text) -> {args.metrics_out}")


def _write_obs_artifacts(args, eng) -> None:
    """--trace-out / --metrics-out exports for a continuous run."""
    if args.trace_out:
        if args.trace_out.endswith(".jsonl"):
            n = eng.tracer.to_jsonl(args.trace_out)
            kind = "jsonl"
        else:
            n = eng.tracer.to_chrome(args.trace_out)
            kind = "chrome trace_event"
        print(f"trace: wrote {n} events ({kind}) -> {args.trace_out}")
    if args.metrics_out:
        text = eng.registry.to_prometheus_text()
        with open(args.metrics_out, "w") as fh:
            fh.write(text)
        print(f"metrics: wrote {len(eng.registry.names())} series "
              f"(prometheus text) -> {args.metrics_out}")
    if args.audit_out:
        import json

        with open(args.audit_out, "w") as fh:
            json.dump(eng.audit_report(), fh, indent=2, sort_keys=True)
        print(f"audit: wrote prediction residual report -> "
              f"{args.audit_out}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--weights-host-frac",
                    type=_fraction("--weights-host-frac"), default=0.0,
                    help="fraction of weights resident on the host tier")
    ap.add_argument("--kv-host-frac",
                    type=_fraction("--kv-host-frac"), default=0.0,
                    help="fraction of the KV cache on the host tier")
    ap.add_argument("--scheduler", choices=["oneshot", "continuous"],
                    default="oneshot",
                    help="oneshot = FlexGen batch; continuous = "
                         "paged-KV continuous batching")
    ap.add_argument("--policy", default="tiering08",
                    choices=["static", "autonuma", "tiering08", "tpp"],
                    help="KV-block tiering policy (continuous only)")
    ap.add_argument("--num-requests", type=int, default=6)
    ap.add_argument("--arrival-gap-s", type=float, default=0.0)
    ap.add_argument("--block-tokens", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="total KV pool blocks (default: sized to batch)")
    ap.add_argument("--fast-blocks", type=int, default=None,
                    help="fast-tier (HBM-analogue) block budget")
    ap.add_argument("--adaptive", action="store_true",
                    help="adaptive object-level re-interleaving from "
                         "observed access telemetry (continuous only)")
    ap.add_argument("--replan-every", type=int, default=8,
                    help="scheduler iterations between adaptive replans")
    ap.add_argument("--predictive", action="store_true",
                    help="predictive control plane: key replans by "
                         "phase recurrence signature and pre-stage the "
                         "proven plan of a predicted next phase "
                         "(requires --adaptive)")
    ap.add_argument("--calibrate", action="store_true",
                    help="self-calibrating cost model: probe the "
                         "pool's slow tier at startup and keep "
                         "correcting planning bandwidths online from "
                         "prediction-audit residuals (requires "
                         "--adaptive)")
    ap.add_argument("--sample-rate",
                    type=_rate("--sample-rate"), default=1.0,
                    help="telemetry sampling rate (fraction of cache "
                         "lines; 1.0 = full instrumentation)")
    from ..topology import TOPOLOGY_CHOICES
    ap.add_argument("--topology", default=None,
                    choices=list(TOPOLOGY_CHOICES),
                    help="budget shared links in admission and (with "
                         "--adaptive) price placements over this "
                         "machine topology instead of a flat tier list")
    ap.add_argument("--tenant", default=None,
                    help="residency-ledger tenant namespace for this "
                         "engine's KV pool (default: serving; "
                         "continuous only)")
    ap.add_argument("--trace-out", default=None,
                    help="write the control-plane trace here after the "
                         "run: .jsonl = one event per line, anything "
                         "else = Chrome trace_event JSON for "
                         "chrome://tracing / Perfetto (continuous only)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics registry as Prometheus "
                         "text exposition here (continuous only)")
    ap.add_argument("--audit-out", default=None,
                    help="write the prediction-audit residual report "
                         "(JSON: per-model accuracy, p95 relative "
                         "error, drift state) here (continuous only)")
    ap.add_argument("--slo-p95-ttft", type=float, default=None,
                    help="live SLO target: p95 TTFT threshold in "
                         "seconds (continuous only)")
    ap.add_argument("--slo-p95-decode", type=float, default=None,
                    help="live SLO target: p95 inter-token decode "
                         "latency threshold in seconds "
                         "(continuous only)")
    ap.add_argument("--slo-p99-decode", type=float, default=None,
                    help="live SLO target: p99 inter-token decode "
                         "latency threshold in seconds "
                         "(continuous only)")
    ap.add_argument("--slo-p999-decode", type=float, default=None,
                    help="live SLO target: p99.9 inter-token decode "
                         "latency threshold in seconds; the monitor "
                         "window auto-grows to hold the 1/(1-q) "
                         "warmup (continuous only)")
    ap.add_argument("--slo-window", type=int, default=512,
                    help="rolling SLO window size in samples "
                         "(continuous only)")
    ap.add_argument("--fused-gather", action="store_true",
                    help="fused tiered-gather decode: attention (and "
                         "MoE expert FFN) read blocks straight from "
                         "the pooled KV/expert layout via scalar-"
                         "prefetched index tables — no per-iteration "
                         "staging copy (continuous only)")
    ap.add_argument("--expert-policy", default=None,
                    choices=["lru", "predictive"],
                    help="MoE expert tier residency: experts become "
                         "tiered objects with routing-driven heat; "
                         "predictive additionally prefetches the "
                         "predicted next phase's hot experts "
                         "(continuous + MoE arch only)")
    ap.add_argument("--expert-fast-frac",
                    type=_fraction("--expert-fast-frac"), default=0.25,
                    help="share of experts that may be fast-resident")
    ap.add_argument("--qos", action="store_true",
                    help="interference-class QoS plane: class-tagged "
                         "flow attribution (blame ledger naming the "
                         "noisy neighbor per tail excursion) and "
                         "violation-predictive admission in place of "
                         "the flat link-efficiency floor (requires "
                         "--topology and a decode SLO)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="multi-host serving plane: shard the model "
                         "over this many replica meshes, one paged "
                         "engine each, sharing one namespaced "
                         "residency ledger (continuous only)")
    from ..serving.config import ROUTER_POLICIES
    ap.add_argument("--router", default=None,
                    choices=list(ROUTER_POLICIES),
                    help="session-placement policy for --replicas > 1 "
                         "(default: headroom-distance — fast-tier "
                         "headroom first, front-end ICI distance as "
                         "the tiebreak)")
    args = ap.parse_args(argv)

    # every cross-field rule lives in repro.serving.config now; the
    # CLI just maps ConfigError onto argparse's exit-with-usage
    from ..serving.config import ConfigError, validate_args
    try:
        validate_args(args)
    except ConfigError as e:
        ap.error(str(e))
    if args.tenant is None:
        args.tenant = "serving"

    if args.topology:
        from ..topology import build_topology
        for line in build_topology(args.topology).describe():
            print(line)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    if args.scheduler == "continuous" and args.replicas > 1:
        run_cluster(args, cfg, params)
    elif args.scheduler == "continuous":
        run_continuous(args, cfg, params)
    else:
        run_oneshot(args, cfg, params)


if __name__ == "__main__":
    main()
