"""Serving CLI: batched prefill + decode with tier-aware placement.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --batch 4 --prompt-len 32 --new-tokens 16 --kv-host-frac 0.5
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, get_smoke_config
from ..models import lm
from ..offload.serve_engine import FlexGenEngine, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--weights-host-frac", type=float, default=0.0,
                    help="fraction of weights resident on the host tier")
    ap.add_argument("--kv-host-frac", type=float, default=0.0,
                    help="fraction of the KV cache on the host tier")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    w = args.weights_host_frac
    k = args.kv_host_frac
    eng = FlexGenEngine(cfg, params, ServeConfig(
        max_new_tokens=args.new_tokens, prompt_len=args.prompt_len,
        weight_shares=[("device", 1 - w), ("pinned_host", w)],
        kv_shares=[("device", 1 - k), ("pinned_host", k)]))
    prompts = np.random.RandomState(0).randint(
        0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
    st = eng.run(prompts)
    print(f"batch={st.batch} prefill={st.prefill_s*1e3:.1f} ms "
          f"decode={st.decode_tok_s:.1f} tok/s "
          f"({st.new_tokens} new tokens/seq; weights {w:.0%} host, "
          f"KV {k:.0%} host)")


if __name__ == "__main__":
    main()
