"""Logical-axis sharding constraints for model internals.

GSPMD propagation through nested lax.scan bodies is best-effort; without
hints it can leave big intermediates (attention score chunks, MoE dispatch
buffers) replicated, exploding per-device memory.  Models call
``constrain(x, "dp", None, "tp", None)`` with *logical* axes; the launcher
activates a mapping to concrete mesh axes per run.

Inactive by default, so eager smoke tests and single-device runs are
untouched.  Dimensions that don't divide their mesh axes are silently
replicated (same policy as shardings._fit).
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = {"mesh": None, "dp": (), "tp": None}


def set_mesh(mesh: Optional[Mesh], dp: Sequence[str] = ("data",),
             tp: Optional[str] = "model") -> None:
    _STATE["mesh"] = mesh
    _STATE["dp"] = tuple(dp)
    _STATE["tp"] = tp


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], dp: Sequence[str] = ("data",),
             tp: Optional[str] = "model"):
    old = dict(_STATE)
    set_mesh(mesh, dp, tp)
    try:
        yield
    finally:
        _STATE.update(old)


def active() -> bool:
    return _STATE["mesh"] is not None


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply with_sharding_constraint using logical axes 'dp'/'tp'/None."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = []
    for dim, l in zip(x.shape, logical):
        if l == "dp":
            axes = [a for a in _STATE["dp"] if a in sizes]
            total = 1
            for a in axes:
                total *= sizes[a]
            spec.append(tuple(axes) if axes and dim % total == 0 else None)
        elif l == "tp":
            a = _STATE["tp"]
            spec.append(a if a in sizes and dim % sizes[a] == 0 else None)
        else:
            spec.append(None)
    spec += [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
