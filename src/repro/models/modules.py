"""Model building blocks (pure JAX, shard_map/pjit-friendly).

Everything here is a pure function over explicit parameter pytrees —
no framework dependency.  Blocks are designed to be stacked and driven by
``lax.scan`` over layer-stacked parameters (models/lm.py), so all shapes are
static and HLO stays compact at 94 layers.

Conventions:
  x        : (B, S, D) activations, compute dtype bf16 unless stated
  params   : nested dict of jnp arrays
  cfg      : repro.configs.base.ModelConfig (static dataclass)
  cache    : per-layer decode state (KV / SSM / shift), updated functionally

Attention uses a pure-JAX chunked flash algorithm (two-level lax.scan with
running max/sum) so 32k-token prefill never materializes an (S, S) score
matrix; the Pallas kernel in repro.kernels mirrors the same blocking.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Params = Dict[str, Any]
DEFAULT_DTYPE = jnp.bfloat16


# ====================================================================== #
# Norms                                                                  #
# ====================================================================== #
def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


def init_layernorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layer_norm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


def apply_norm(kind: str, p: Params, x: jax.Array) -> jax.Array:
    return rms_norm(p, x) if kind == "rms" else layer_norm(p, x)


def init_norm(kind: str, d: int) -> Params:
    return init_rmsnorm(d) if kind == "rms" else init_layernorm(d)


# ====================================================================== #
# RoPE                                                                   #
# ====================================================================== #
def rope_freqs(rotary_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, rotary_dim, 2,
                                       dtype=jnp.float32) / rotary_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               rotary_pct: float = 1.0) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    rotary_dim = int(hd * rotary_pct)
    rotary_dim -= rotary_dim % 2
    if rotary_dim == 0:
        return x
    freqs = rope_freqs(rotary_dim, theta)                   # (rd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,rd/2)
    cos = jnp.cos(ang)[:, :, None, :]                        # (B,S,1,rd/2)
    sin = jnp.sin(ang)[:, :, None, :]
    xr, xp = x[..., :rotary_dim], x[..., rotary_dim:]
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    xf1 = x1.astype(jnp.float32)
    xf2 = x2.astype(jnp.float32)
    o1 = xf1 * cos - xf2 * sin
    o2 = xf2 * cos + xf1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if rotary_dim < hd else out


# ====================================================================== #
# Chunked flash attention (pure JAX; oracle for the Pallas kernel)        #
# ====================================================================== #
def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      *, causal: bool, q_offset: int = 0,
                      chunk_q: int = 1024, chunk_kv: int = 1024,
                      kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Flash-style attention without materializing (Sq, Sk) scores.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd) with H % KV == 0.
    GQA is handled by repeating each chunk's K/V to H heads (chunk-sized,
    cheap) so every intermediate keeps a flat head axis — TP-shardable for
    any KV count.  kv_len: optional dynamic valid length (decode).
    Returns (B, Sq, H, hd).
    """
    from . import psharding as PS

    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    rep = H // KV
    scale = 1.0 / math.sqrt(hd)
    cq = min(chunk_q, Sq)
    ck = min(chunk_kv, Sk)
    nq = -(-Sq // cq)
    nk = -(-Sk // ck)
    # pad to multiples
    qp = jnp.pad(q, ((0, 0), (0, nq * cq - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * ck - Sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * ck - Sk), (0, 0), (0, 0)))
    qh = qp.reshape(B, nq, cq, H, hd)
    kh = kp.reshape(B, nk, ck, KV, hd)
    vh = vp.reshape(B, nk, ck, KV, hd)
    valid_k = kv_len if kv_len is not None else Sk

    def q_step(_, qi):
        qc, iq = qi  # (B,cq,H,hd), scalar
        qc = PS.constrain(qc, "dp", None, "tp", None)
        q_pos = q_offset + iq * cq + jnp.arange(cq)

        def kv_step(carry, ki):
            m, l, acc = carry
            kc, vc, ik = ki
            if rep > 1:  # GQA: expand chunk KV to flat heads
                kc = jnp.repeat(kc, rep, axis=2)
                vc = jnp.repeat(vc, rep, axis=2)
            kc = PS.constrain(kc, "dp", None, "tp", None)
            vc = PS.constrain(vc, "dp", None, "tp", None)
            k_pos = ik * ck + jnp.arange(ck)
            s = jnp.einsum("bqhd,bkhd->bhqk",
                           qc.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            s = PS.constrain(s, "dp", "tp", None, None)
            mask = k_pos[None, :] < valid_k
            if causal:
                mask = mask & (k_pos[None, :] <= q_pos[:, None])
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vc.astype(jnp.float32))
            acc_new = PS.constrain(acc_new, "dp", "tp", None, None)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        a0 = jnp.zeros((B, H, cq, hd), jnp.float32)
        # nested remat: never save the (cq, ck) score/prob chunk — the
        # backward recomputes it (flash-attention backward semantics)
        (m, l, acc), _ = lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0),
            (kh.swapaxes(0, 1), vh.swapaxes(0, 1), jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (B,H,cq,hd) -> (B,cq,H,hd)
        return None, out.transpose(0, 2, 1, 3).astype(q.dtype)

    _, outs = lax.scan(jax.checkpoint(q_step), None,
                       (qh.swapaxes(0, 1), jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * cq, H, hd)
    return out[:, :Sq]


def dense_attention(q, k, v, *, causal: bool, q_offset: int = 0,
                    kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Direct softmax attention (decode path / small-S oracle).

    Stays in grouped (KV, rep) layout: the decode cost is the KV-cache
    read, and repeating the cache to H heads would multiply it.  With a
    seq-sharded cache this becomes flash-decode (partial softmax combined
    by GSPMD collectives over the seq shards)."""
    from . import psharding as PS

    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    rep = H // KV
    qf = q.reshape(B, Sq, KV, rep, hd).astype(jnp.float32)
    s = jnp.einsum("bqgrh,bkgh->bgrqk", qf, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    k_pos = jnp.arange(Sk)
    q_pos = q_offset + jnp.arange(Sq)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if kv_len is not None:
        mask = mask & (k_pos[None, :] < kv_len)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqk,bkgh->bgrqh", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)


# ====================================================================== #
# KV-cache quantization (int8, per-(position, head) symmetric scales)    #
# ====================================================================== #
def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, KV, hd) -> (int8 values, bf16 scales (B, S, KV))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q: jax.Array, scale: jax.Array,
                  dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)[..., None]).astype(dtype)


# ====================================================================== #
# GQA attention block                                                    #
# ====================================================================== #
def init_attention(key, d_model: int, n_heads: int, n_kv: int,
                   head_dim: int, qkv_bias: bool = False,
                   dtype=DEFAULT_DTYPE) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d_model)
    p = {
        "wq": (jax.random.normal(k1, (d_model, n_heads * head_dim)) * s
               ).astype(dtype),
        "wk": (jax.random.normal(k2, (d_model, n_kv * head_dim)) * s
               ).astype(dtype),
        "wv": (jax.random.normal(k3, (d_model, n_kv * head_dim)) * s
               ).astype(dtype),
        "wo": (jax.random.normal(k4, (n_heads * head_dim, d_model)) * s
               ).astype(dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def attention_fwd(p: Params, x: jax.Array, *, n_heads: int, n_kv: int,
                  head_dim: int, rope_theta: float = 10000.0,
                  rotary_pct: float = 1.0, causal: bool = True,
                  positions: Optional[jax.Array] = None,
                  kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
                  cache_index: Optional[jax.Array] = None,
                  cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                  use_rope: bool = True,
                  attn_chunk: int = 1024
                  ) -> Tuple[jax.Array,
                             Optional[Tuple[jax.Array, jax.Array]]]:
    """GQA attention with optional KV cache (decode) or cross-KV.

    Returns (out, new_kv_cache).  Modes:
      * train/prefill: kv_cache=None           -> causal self-attn
      * decode:        kv_cache=(K, V) buffers, cache_index=pos
      * cross:         cross_kv=(K, V) precomputed (encoder/image)
    """
    from . import psharding as PS

    B, S, D = x.shape
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = PS.constrain(q.reshape(B, S, n_heads, head_dim),
                     "dp", None, "tp", None)

    if cross_kv is not None:
        k, v = cross_kv
        if use_rope and positions is not None:
            q = apply_rope(q, positions, rope_theta, rotary_pct)
        out = chunked_attention(q, k, v, causal=False,
                                chunk_q=attn_chunk, chunk_kv=attn_chunk)
        new_cache = None
    else:
        k = x @ p["wk"]
        v = x @ p["wv"]
        if "bk" in p:
            k = k + p["bk"]
            v = v + p["bv"]
        k = PS.constrain(k.reshape(B, S, n_kv, head_dim),
                         "dp", None, "tp", None)
        v = PS.constrain(v.reshape(B, S, n_kv, head_dim),
                         "dp", None, "tp", None)
        if positions is None:
            positions = jnp.arange(S)
        if kv_cache is None:
            if use_rope:
                q = apply_rope(q, positions, rope_theta, rotary_pct)
                k = apply_rope(k, positions, rope_theta, rotary_pct)
            out = chunked_attention(q, k, v, causal=causal,
                                    chunk_q=attn_chunk, chunk_kv=attn_chunk)
            new_cache = (k, v)
        else:
            idx = cache_index           # scalar: next write position
            if use_rope:
                pos = idx + jnp.arange(S)
                q = apply_rope(q, pos, rope_theta, rotary_pct)
                k = apply_rope(k, pos, rope_theta, rotary_pct)
            if len(kv_cache) == 4:      # int8-quantized cache
                ck, cv, cks, cvs = kv_cache
                kq, ks = quantize_kv(k)
                vq, vs = quantize_kv(v)
                ck = lax.dynamic_update_slice(ck, kq, (0, idx, 0, 0))
                cv = lax.dynamic_update_slice(cv, vq, (0, idx, 0, 0))
                cks = lax.dynamic_update_slice(cks, ks, (0, idx, 0))
                cvs = lax.dynamic_update_slice(cvs, vs, (0, idx, 0))
                out = dense_attention(q, dequantize_kv(ck, cks),
                                      dequantize_kv(cv, cvs),
                                      causal=False, kv_len=idx + S)
                new_cache = (ck, cv, cks, cvs)
            else:
                ck, cv = kv_cache       # (B, S_max, KV, hd)
                ck = lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, idx, 0, 0))
                cv = lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, idx, 0, 0))
                out = dense_attention(q, ck, cv, causal=False,
                                      kv_len=idx + S)
                new_cache = (ck, cv)

    out = out.reshape(B, S, n_heads * head_dim)
    out = PS.constrain(out @ p["wo"], "dp", None, None)
    return out, new_cache


# ====================================================================== #
# MLP (SwiGLU / GELU)                                                    #
# ====================================================================== #
def init_mlp(key, d_model: int, d_ff: int, act: str = "silu",
             dtype=DEFAULT_DTYPE) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d_model)
    sf = 1.0 / math.sqrt(d_ff)
    p = {"w_up": (jax.random.normal(k2, (d_model, d_ff)) * s).astype(dtype),
         "w_down": (jax.random.normal(k3, (d_ff, d_model)) * sf
                    ).astype(dtype)}
    if act == "silu":  # SwiGLU needs the gate
        p["w_gate"] = (jax.random.normal(k1, (d_model, d_ff)) * s
                       ).astype(dtype)
    return p


def mlp_fwd(p: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    from . import psharding as PS

    if act == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    h = PS.constrain(h, "dp", None, "tp")
    return PS.constrain(h @ p["w_down"], "dp", None, None)


# ====================================================================== #
# Mixture of Experts (group-local capacity dispatch, EP-shardable)        #
# ====================================================================== #
def init_moe(key, d_model: int, d_ff: int, n_experts: int,
             act: str = "silu", dtype=DEFAULT_DTYPE) -> Params:
    k0, k1, k2, k3 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d_model)
    sf = 1.0 / math.sqrt(d_ff)
    p = {
        "router": (jax.random.normal(k0, (d_model, n_experts)) * s
                   ).astype(jnp.float32),
        "w_up": (jax.random.normal(k2, (n_experts, d_model, d_ff)) * s
                 ).astype(dtype),
        "w_down": (jax.random.normal(k3, (n_experts, d_ff, d_model)) * sf
                   ).astype(dtype),
    }
    if act == "silu":
        p["w_gate"] = (jax.random.normal(k1, (n_experts, d_model, d_ff)) * s
                       ).astype(dtype)
    return p


def moe_fwd(p: Params, x: jax.Array, *, top_k: int, capacity_factor: float,
            n_groups: int, act: str = "silu",
            ) -> Tuple[jax.Array, jax.Array]:
    """Token-choice top-k MoE with group-local capacity and drop.

    Dispatch is scatter-based (no (T, E, C) one-hot einsum): tokens are
    scattered into a (G, E, C, D) buffer sharded G->data / E->model, so
    GSPMD realizes the all_to_all between the data and model axes.  Dropped
    tokens (over capacity) pass through the residual only — standard
    "dropping" MoE.

    Returns (out, aux_loss) where aux_loss is the load-balancing loss.
    """
    from . import psharding as PS

    B, S, D = x.shape
    E = p["router"].shape[1]
    N = B * S
    G = min(n_groups, N)
    while N % G:  # largest divisor of N not exceeding n_groups
        G -= 1
    T = N // G
    xt = PS.constrain(x.reshape(G, T, D), "dp", None, None)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (G,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = lax.top_k(probs, top_k)                     # (G,T,k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style)
    me = probs.mean(axis=(0, 1))                             # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[topi.reshape(-1)].add(
        1.0 / (N * top_k))
    aux = E * jnp.sum(me * ce)

    C = max(int(T * top_k * capacity_factor / E), 4)

    # position of each (token, slot) within its expert bucket, per group
    oh = jax.nn.one_hot(topi.reshape(G, T * top_k), E,
                        dtype=jnp.int32)                      # (G,T*k,E)
    oh = PS.constrain(oh, "dp", None, None)
    pos_all = jnp.cumsum(oh, axis=1) - 1                      # (G,T*k,E)
    pos = jnp.take_along_axis(
        pos_all, topi.reshape(G, T * top_k)[..., None], axis=-1
    )[..., 0].reshape(G, T, top_k)                            # (G,T,k)
    keep = pos < C
    safe_pos = jnp.where(keep, pos, C)                        # dump slot C

    # scatter tokens -> (G, E, C+1, D) buffer.  vmapped per group with a
    # static top_k loop: keeps the G axis explicit so its 'data' sharding
    # survives (a flat (G*T*k, D) scatter would replicate ~100 GiB/dev).
    def disp_group(xg, eg, pg):
        b = jnp.zeros((E, C + 1, D), x.dtype)
        for j in range(top_k):
            b = b.at[eg[:, j], pg[:, j]].add(xg)
        return b[:, :C]

    buf = jax.vmap(disp_group)(xt, topi, safe_pos)            # (G,E,C,D)
    # EP boundary: the scatter above is the data->expert all_to_all
    buf = PS.constrain(buf, "dp", "tp", None, None)

    # expert FFN, batched over experts (EP: E sharded over 'model')
    if act == "silu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])) \
            * jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", buf, p["w_up"]))
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"])    # (G,E,C,D)
    out_buf = PS.constrain(out_buf, "dp", "tp", None, None)

    # combine: gather each slot's result, weight, sum over k (per group)
    w_comb = (topw * keep).astype(x.dtype)                    # (G,T,k)

    def comb_group(og, eg, pg, wg):
        acc = jnp.zeros((T, D), x.dtype)
        for j in range(top_k):
            gat = og[eg[:, j], jnp.minimum(pg[:, j], C - 1)]  # (T,D)
            acc = acc + gat * wg[:, j][:, None]
        return acc

    out = jax.vmap(comb_group)(out_buf, topi, safe_pos, w_comb)
    out = PS.constrain(out.reshape(B, S, D), "dp", None, None)
    return out, aux


# ====================================================================== #
# Mamba (SSD / Mamba-2 form — TPU adaptation, see DESIGN.md §2)           #
# ====================================================================== #
@dataclasses.dataclass(frozen=True)
class MambaDims:
    d_model: int
    d_inner: int
    n_heads: int     # d_inner // head_dim
    head_dim: int
    d_state: int
    d_conv: int = 4
    chunk: int = 128


def mamba_dims(d_model: int, expand: int = 2, head_dim: int = 64,
               d_state: int = 16, d_conv: int = 4,
               chunk: int = 128) -> MambaDims:
    d_inner = expand * d_model
    return MambaDims(d_model, d_inner, d_inner // head_dim, head_dim,
                     d_state, d_conv, chunk)


def init_mamba(key, dims: MambaDims, dtype=DEFAULT_DTYPE) -> Params:
    ks = jax.random.split(key, 6)
    di, H, P, N = dims.d_inner, dims.n_heads, dims.head_dim, dims.d_state
    s = 1.0 / math.sqrt(dims.d_model)
    return {
        # in_proj -> [x (di), z (di), B (H*N), C (H*N), dt (H)]
        "w_in": (jax.random.normal(
            ks[0], (dims.d_model, 2 * di + 2 * H * N + H)) * s
        ).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (dims.d_conv, di)) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "w_out": (jax.random.normal(ks[2], (di, dims.d_model)) /
                  math.sqrt(di)).astype(dtype),
        "norm": init_rmsnorm(di),
    }


def _mamba_split(p, x, dims: MambaDims):
    di, H, N = dims.d_inner, dims.n_heads, dims.d_state
    proj = x @ p["w_in"]
    xs, z, Bm, Cm, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + H * N, 2 * di + 2 * H * N], axis=-1)
    return xs, z, Bm, Cm, dt


def _ssd_chunk_scan(xh, dt, A, Bm, Cm, dims: MambaDims,
                    init_state: Optional[jax.Array] = None):
    """Chunked SSD: y_t = C_t^T sum_{s<=t} (prod_{r=s+1..t} a_r) dt_s B_s x_s.

    xh: (B, S, H, P); dt: (B, S, H) (softplus'd); Bm, Cm: (B, S, H, N).
    a_t = exp(-dt_t * A_h) scalar-per-head decay (Mamba-2 / SSD form).
    Returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    B, S, H, P = xh.shape
    N = dims.d_state
    L = min(dims.chunk, S)
    nC = -(-S // L)
    Sp = nC * L
    if Sp != S:
        # zero-pad: dt=0 gives identity decay and zero input contribution,
        # so the final carried state is exact.
        pad = ((0, 0), (0, Sp - S), (0, 0))
        xh = jnp.pad(xh, pad + ((0, 0),))
        Bm = jnp.pad(Bm, pad + ((0, 0),))
        Cm = jnp.pad(Cm, pad + ((0, 0),))
        dt = jnp.pad(dt, pad)
    S_out, S = S, Sp

    loga = (-dt * A[None, None, :]).astype(jnp.float32)      # (B,S,H) <= 0
    x_dt = (xh.astype(jnp.float32) * dt[..., None])          # (B,S,H,P)

    xc = x_dt.reshape(B, nC, L, H, P).swapaxes(0, 1)
    bc = Bm.reshape(B, nC, L, H, N).swapaxes(0, 1).astype(jnp.float32)
    cc = Cm.reshape(B, nC, L, H, N).swapaxes(0, 1).astype(jnp.float32)
    lc = loga.reshape(B, nC, L, H).swapaxes(0, 1)

    if init_state is None:
        init_state = jnp.zeros((B, H, N, P), jnp.float32)

    def chunk_step(state, inp):
        from . import psharding as PS

        xk, bk, ck, lk = inp                     # (B,L,H,P/N/N/·)
        xk = PS.constrain(xk, "dp", None, "tp", None)
        bk = PS.constrain(bk, "dp", None, "tp", None)
        ck = PS.constrain(ck, "dp", None, "tp", None)
        cum = jnp.cumsum(lk, axis=1)             # (B,L,H) log decay to t
        total = cum[:, -1]                       # (B,H)
        # intra-chunk: G[t,s] = exp(cum_t - cum_s) * (C_t . B_s), s <= t
        gmat = cum[:, :, None, :] - cum[:, None, :, :]       # (B,L,L,H)
        tri = jnp.tril(jnp.ones((L, L), bool))
        gmat = jnp.where(tri[None, :, :, None], gmat, -jnp.inf)
        cb = jnp.einsum("blhn,bshn->blsh", ck, bk)           # (B,L,L,H)
        w = PS.constrain(jnp.exp(gmat) * cb, "dp", None, None, "tp")
        y_intra = jnp.einsum("blsh,bshp->blhp", w, xk)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("blhn,bhnp->blhp", ck * jnp.exp(
            cum)[..., None], state)
        # state update: S' = exp(total) S + sum_s exp(total - cum_s) B_s x_s
        decay_s = jnp.exp(total[:, None, :] - cum)           # (B,L,H)
        state_new = (jnp.exp(total)[..., None, None] * state
                     + jnp.einsum("bshn,bshp->bhnp",
                                  bk * decay_s[..., None], xk))
        state_new = PS.constrain(state_new, "dp", "tp", None, None)
        return state_new, y_intra + y_inter

    final_state, ys = lax.scan(jax.checkpoint(chunk_step), init_state,
                               (xc, bc, cc, lc))
    y = ys.swapaxes(0, 1).reshape(B, S, H, P)
    return y[:, :S_out], final_state


def mamba_fwd(p: Params, x: jax.Array, dims: MambaDims,
              conv_state: Optional[jax.Array] = None,
              ssm_state: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Mamba block forward.

    Train/prefill: states None -> full sequence, returns final states.
    Decode: S == 1 with states provided -> O(1) step.
    conv_state: (B, d_conv-1, d_inner); ssm_state: (B, H, N, P).
    """
    B, S, D = x.shape
    di, H, P, N = dims.d_inner, dims.n_heads, dims.head_dim, dims.d_state
    xs, z, Bm, Cm, dt = _mamba_split(p, x, dims)

    # causal depthwise conv along seq
    K = dims.d_conv
    if conv_state is None:
        pad = jnp.zeros((B, K - 1, di), xs.dtype)
    else:
        pad = conv_state.astype(xs.dtype)
    xpad = jnp.concatenate([pad, xs], axis=1)                # (B,S+K-1,di)
    conv = sum(xpad[:, i:i + S, :] * p["conv_w"][i] for i in range(K))
    conv = jax.nn.silu(conv + p["conv_b"])
    new_conv_state = xpad[:, -(K - 1):, :] if K > 1 else pad

    xh = conv.reshape(B, S, H, P)
    Bm = Bm.reshape(B, S, H, N)
    Cm = Cm.reshape(B, S, H, N)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = jnp.exp(p["A_log"])

    if S == 1 and ssm_state is not None:
        # decode: one recurrence step
        a = jnp.exp(-dtf[:, 0] * A[None, :])                 # (B,H)
        bx = jnp.einsum("bhn,bhp->bhnp", Bm[:, 0].astype(jnp.float32),
                        xh[:, 0].astype(jnp.float32) * dtf[:, 0, :, None])
        state = a[..., None, None] * ssm_state + bx
        y = jnp.einsum("bhn,bhnp->bhp", Cm[:, 0].astype(jnp.float32),
                       state)[:, None]
        final_state = state
    else:
        y, final_state = _ssd_chunk_scan(xh, dtf, A, Bm, Cm, dims,
                                         init_state=ssm_state)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(p["norm"], y) * jax.nn.silu(z)
    out = y @ p["w_out"]
    return out, (new_conv_state.astype(jnp.bfloat16), final_state)


# ====================================================================== #
# RWKV6 ("Finch") — data-dependent decay linear attention                 #
# ====================================================================== #
@dataclasses.dataclass(frozen=True)
class RwkvDims:
    d_model: int
    n_heads: int
    head_dim: int
    d_ff: int
    chunk: int = 64


def rwkv_dims(d_model: int, d_ff: int, head_dim: int = 64,
              chunk: int = 64) -> RwkvDims:
    return RwkvDims(d_model, d_model // head_dim, head_dim, d_ff, chunk)


def init_rwkv_tmix(key, dims: RwkvDims, dtype=DEFAULT_DTYPE) -> Params:
    ks = jax.random.split(key, 8)
    D, H, P = dims.d_model, dims.n_heads, dims.head_dim
    s = 1.0 / math.sqrt(D)
    lora = max(32, D // 64)
    return {
        "mix_r": jnp.full((D,), 0.5, dtype),
        "mix_k": jnp.full((D,), 0.5, dtype),
        "mix_v": jnp.full((D,), 0.5, dtype),
        "mix_w": jnp.full((D,), 0.5, dtype),
        "mix_g": jnp.full((D,), 0.5, dtype),
        "wr": (jax.random.normal(ks[0], (D, D)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (D, D)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (D, D)) * s).astype(dtype),
        "wg": (jax.random.normal(ks[3], (D, D)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[4], (D, D)) * s).astype(dtype),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((D,), -2.0, jnp.float32),
        "wA": (jax.random.normal(ks[5], (D, lora)) * s).astype(dtype),
        "wB": (jax.random.normal(ks[6], (lora, D)) * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[7], (H, P)) * 0.1).astype(jnp.float32),
        "ln_x": init_layernorm(D),
    }


def _token_shift(x: jax.Array, shift_state: Optional[jax.Array]):
    """prev-token features: (B,S,D) -> shifted; carry last token for decode."""
    B, S, D = x.shape
    if shift_state is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :S]
    else:
        prev = jnp.concatenate([shift_state[:, None, :], x[:, :S - 1]],
                               axis=1) if S > 1 else shift_state[:, None, :]
    return prev, x[:, -1, :]


def rwkv_tmix_fwd(p: Params, x: jax.Array, dims: RwkvDims,
                  wkv_state: Optional[jax.Array] = None,
                  shift_state: Optional[jax.Array] = None,
                  ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """RWKV6 time-mix.  wkv_state: (B,H,P,P); shift_state: (B,D)."""
    B, S, D = x.shape
    H, P = dims.n_heads, dims.head_dim
    prev, last = _token_shift(x, shift_state)

    def mix(m):
        return x * p[m] + prev * (1.0 - p[m])

    r = (mix("mix_r") @ p["wr"]).reshape(B, S, H, P)
    k = (mix("mix_k") @ p["wk"]).reshape(B, S, H, P)
    v = (mix("mix_v") @ p["wv"]).reshape(B, S, H, P)
    g = jax.nn.silu(mix("mix_g") @ p["wg"])
    # data-dependent decay (per channel): logw in (-inf, 0)
    wx = jnp.tanh(mix("mix_w") @ p["wA"]) @ p["wB"]
    logw = -jnp.exp(p["w0"] + wx.astype(jnp.float32))        # (B,S,D)
    logw = logw.reshape(B, S, H, P)

    if wkv_state is None:
        wkv_state = jnp.zeros((B, H, P, P), jnp.float32)

    if S == 1:
        rf = r[:, 0].astype(jnp.float32)
        kf = k[:, 0].astype(jnp.float32)
        vf = v[:, 0].astype(jnp.float32)
        kv = jnp.einsum("bhp,bhq->bhpq", kf, vf)
        y = jnp.einsum("bhp,bhpq->bhq", rf,
                       wkv_state + p["u"][None, :, :, None] * kv)
        state = wkv_state * jnp.exp(logw[:, 0])[..., None] + kv
        out = y[:, None].reshape(B, 1, D)
    else:
        out, state = _rwkv_chunk_scan(r, k, v, logw, p["u"], dims,
                                      wkv_state)
        out = out.reshape(B, S, D)
    out = layer_norm(p["ln_x"], out.astype(x.dtype)) * g
    return out @ p["wo"], (state, last.astype(jnp.bfloat16))


def _rwkv_chunk_scan(r, k, v, logw, u, dims: RwkvDims, init_state):
    """Chunked RWKV6 recurrence.

    state S_t (P_k x P_v per head): S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    Chunked: intra-chunk pairwise decays + inter-chunk carried state.
    """
    B, S, H, P = r.shape
    L = min(dims.chunk, S)
    nC = -(-S // L)
    Sp = nC * L
    if Sp != S:
        # zero-pad: logw=0 gives identity decay; k=v=0 adds nothing, so the
        # carried wkv state is exact.
        pad = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        r, k, v, logw = (jnp.pad(a, pad) for a in (r, k, v, logw))
    S_out, S = S, Sp

    rc = r.reshape(B, nC, L, H, P).swapaxes(0, 1).astype(jnp.float32)
    kc = k.reshape(B, nC, L, H, P).swapaxes(0, 1).astype(jnp.float32)
    vc = v.reshape(B, nC, L, H, P).swapaxes(0, 1).astype(jnp.float32)
    wc = logw.reshape(B, nC, L, H, P).swapaxes(0, 1)

    def chunk_step(state, inp):
        from . import psharding as PS

        rk, kk, vk, wk = inp                    # (B,L,H,P)
        rk = PS.constrain(rk, "dp", None, "tp", None)
        kk = PS.constrain(kk, "dp", None, "tp", None)
        vk = PS.constrain(vk, "dp", None, "tp", None)
        cum = jnp.cumsum(wk, axis=1)            # decay from chunk start to t
        # r~_t = r_t * exp(cum_{t-1}); cum_{t-1} = cum_t - w_t
        r_dec = rk * jnp.exp(cum - wk)
        # k^_s = k_s * exp(-cum_s)  (valid: within-chunk, bounded by L decays)
        k_dec = kk * jnp.exp(-cum)
        att = jnp.einsum("blhp,bshp->blsh", r_dec, k_dec)   # (B,L,L,H)
        tri = jnp.tril(jnp.ones((L, L), bool), k=-1)        # strict: s < t
        att = PS.constrain(att * tri[None, :, :, None],
                           "dp", None, None, "tp")
        y_intra = jnp.einsum("blsh,bshq->blhq", att, vk)
        # current-token bonus: r_t . (u * k_t) v_t
        bonus = jnp.einsum("blhp,blhp->blh", rk, u[None, None] * kk)
        y_bonus = bonus[..., None] * vk
        # inter: y += (r_t exp(cum_{t-1}))^T S_carry
        y_inter = jnp.einsum("blhp,bhpq->blhq", r_dec, state)
        # state update: S' = diag(exp(cum_L)) S + sum_s exp(cum_L-cum_s) k v^T
        total = cum[:, -1]                                   # (B,H,P)
        k_tail = kk * jnp.exp(total[:, None] - cum)
        state_new = state * jnp.exp(total)[..., None] + jnp.einsum(
            "bshp,bshq->bhpq", k_tail, vk)
        state_new = PS.constrain(state_new, "dp", "tp", None, None)
        return state_new, y_intra + y_bonus + y_inter

    final, ys = lax.scan(jax.checkpoint(chunk_step), init_state,
                         (rc, kc, vc, wc))
    return ys.swapaxes(0, 1).reshape(B, S, H, P)[:, :S_out], final


def init_rwkv_cmix(key, dims: RwkvDims, dtype=DEFAULT_DTYPE) -> Params:
    k1, k2 = jax.random.split(key)
    D, F = dims.d_model, dims.d_ff
    s = 1.0 / math.sqrt(D)
    return {
        "mix_k": jnp.full((D,), 0.5, dtype),
        "wk": (jax.random.normal(k1, (D, F)) * s).astype(dtype),
        "wv": (jax.random.normal(k2, (F, D)) / math.sqrt(F)).astype(dtype),
    }


def rwkv_cmix_fwd(p: Params, x: jax.Array,
                  shift_state: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    prev, last = _token_shift(x, shift_state)
    xk = x * p["mix_k"] + prev * (1.0 - p["mix_k"])
    h = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return h @ p["wv"], last.astype(jnp.bfloat16)
