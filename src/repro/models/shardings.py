"""Partition-spec rules: params / optimizer state / cache / inputs.

Layout (DESIGN.md §4):
  mesh axes: ("pod", "data", "model") multi-pod, ("data", "model") single.
  * DP over pod+data for the batch;
  * FSDP over "data" for parameter storage (all-gathered per scanned unit);
  * TP over "model" for heads / d_ff / experts / vocab.

Every rule is validated against divisibility: a dimension that does not
divide by its assigned axis size is silently replicated instead (e.g.
25 GPT-2 heads over 16-way TP), keeping all (arch x mesh) combinations
lowerable.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

F = "__fsdp__"   # placeholder resolved to the fsdp axis
T = "__tp__"     # placeholder resolved to the tp axis

# (parent, leaf-name) -> base spec (without the stacked-unit leading axis).
# Fallback: replicate.
_RULES: Dict[Tuple[str, str], Tuple] = {
    ("*", "embed"): (T, F),
    ("*", "lm_head"): (T, F),
    ("*", "pos_emb"): (None, F),
    # attention
    ("attn", "wq"): (F, T), ("attn", "wk"): (F, T), ("attn", "wv"): (F, T),
    ("attn", "wo"): (T, F),
    ("attn", "bq"): (T,), ("attn", "bk"): (T,), ("attn", "bv"): (T,),
    ("cross", "wq"): (F, T), ("cross", "wk"): (F, T),
    ("cross", "wv"): (F, T), ("cross", "wo"): (T, F),
    ("cross", "bq"): (T,), ("cross", "bk"): (T,), ("cross", "bv"): (T,),
    ("xkv", "wk"): (F, T), ("xkv", "wv"): (F, T),
    # dense MLP
    ("mlp", "w_gate"): (F, T), ("mlp", "w_up"): (F, T),
    ("mlp", "w_down"): (T, F),
    # MoE (experts over TP = expert parallelism)
    ("moe", "router"): (F, None),
    ("moe", "w_gate"): (T, F, None), ("moe", "w_up"): (T, F, None),
    ("moe", "w_down"): (T, None, F),
    # mamba
    ("mamba", "w_in"): (F, T), ("mamba", "w_out"): (T, F),
    ("mamba", "conv_w"): (None, T), ("mamba", "conv_b"): (T,),
    ("mamba", "A_log"): (T,), ("mamba", "D"): (T,),
    ("mamba", "dt_bias"): (T,),
    ("norm", "scale"): (T,),   # mamba-internal norm over d_inner
    # rwkv time-mix
    ("tmix", "wr"): (F, T), ("tmix", "wk"): (F, T), ("tmix", "wv"): (F, T),
    ("tmix", "wg"): (F, T), ("tmix", "wo"): (T, F),
    ("tmix", "wA"): (F, None), ("tmix", "wB"): (None, T),
    ("tmix", "w0"): (T,), ("tmix", "u"): (T, None),
    ("ln_x", "scale"): (T,), ("ln_x", "bias"): (T,),
    # rwkv channel-mix
    ("cmix", "wk"): (F, T), ("cmix", "wv"): (T, F),
}


def _path_keys(path) -> Tuple[str, ...]:
    out = []
    for pp in path:
        if hasattr(pp, "key"):
            out.append(str(pp.key))
        elif hasattr(pp, "idx"):
            out.append(str(pp.idx))
        else:
            out.append(str(pp))
    return tuple(out)


def _base_spec(keys: Tuple[str, ...]) -> Optional[Tuple]:
    name = keys[-1]
    parents = [k for k in keys[:-1] if not k.isdigit()]
    parent = parents[-1] if parents else "*"
    if (parent, name) in _RULES:
        return _RULES[(parent, name)]
    if ("*", name) in _RULES:
        return _RULES[("*", name)]
    return None


def _fit(shape, spec, mesh: Mesh, fsdp: Optional[str], tp: str) -> P:
    """Resolve placeholders + drop axes that don't divide the dim."""
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, ax in zip(shape, spec):
        ax = {F: fsdp, T: tp}.get(ax, ax)
        if ax is None or ax not in axis_size or dim % axis_size[ax] != 0:
            out.append(None)
        else:
            out.append(ax)
    return P(*out)


def param_pspecs(param_shapes: Any, mesh: Mesh,
                 fsdp: Optional[str] = "data",
                 tp: str = "model") -> Any:
    """PartitionSpec pytree mirroring the params (from eval_shape).

    fsdp=None replicates over the data axes (inference sharding: weights
    stay resident, no per-step all-gather)."""

    def spec_of(path, leaf):
        keys = _path_keys(path)
        base = _base_spec(keys)
        stacked = "units" in keys
        nd = len(leaf.shape)
        if base is None:
            return P(*([None] * nd))
        if stacked:
            base = (None,) + tuple(base)
        base = tuple(base) + (None,) * (nd - len(base))
        base = base[:nd]
        return _fit(leaf.shape, base, mesh, fsdp, tp)

    return jax.tree_util.tree_map_with_path(spec_of, param_shapes)


def opt_state_pspecs(param_specs: Any, mesh: Mesh) -> Dict[str, Any]:
    """Adam state specs: master/m/v/err shaped like params; scalar step."""
    return {
        "master": param_specs, "m": param_specs, "v": param_specs,
        "step": P(),
    }


def cache_pspecs(cache_shapes: Any, mesh: Mesh, batch: int,
                 dp_axes: Tuple[str, ...], tp: str = "model") -> Any:
    """Decode-cache specs.

    Two regimes (DESIGN.md §4):
      * batch divisible by DP  -> batch-sharded cache, kv-heads over TP if
        divisible (falls back to seq over TP);
      * batch=1 long-context   -> sequence-parallel cache: seq dim sharded
        over (dp + tp) — flash-decode with partial-softmax collectives.
    """
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = [a for a in dp_axes if a in axis_size]
    dp_total = 1
    for a in dp:
        dp_total *= axis_size[a]
    batch_ok = batch % dp_total == 0

    def spec_of(path, leaf):
        keys = _path_keys(path)
        name = keys[0] if keys else ""
        nd = len(leaf.shape)
        if name == "index":
            return P()
        if name in ("kv_k_scale", "kv_v_scale"):
            # (U, n, B, S, KV) — follows the value cache's regime
            U, n, B, S, KV = leaf.shape
            if batch_ok:
                kv_ax = tp if KV % axis_size.get(tp, 1) == 0 else None
                seq_ax = None if kv_ax else (
                    tp if S % axis_size.get(tp, 1) == 0 else None)
                return P(None, None, tuple(dp), seq_ax, kv_ax)
            seq_axes = tuple(dp) + ((tp,) if tp in axis_size else ())
            total = 1
            for a in seq_axes:
                total *= axis_size[a]
            if S % total == 0:
                return P(None, None, None, seq_axes, None)
            return P(*([None] * nd))
        if name in ("kv_k", "kv_v", "cross_k", "cross_v"):
            # (U, n, B, S, KV, hd)
            U, n, B, S, KV, hd = leaf.shape
            if batch_ok:
                kv_ax = tp if KV % axis_size.get(tp, 1) == 0 else None
                seq_ax = None if kv_ax else (
                    tp if S % axis_size.get(tp, 1) == 0 else None)
                return P(None, None, tuple(dp), seq_ax, kv_ax, None)
            seq_axes = tuple(dp) + ((tp,) if tp in axis_size else ())
            total = 1
            for a in seq_axes:
                total *= axis_size[a]
            if S % total == 0:
                return P(None, None, None, seq_axes, None, None)
            if S % dp_total == 0:
                return P(None, None, None, tuple(dp), None, None)
            return P(*([None] * nd))
        if name == "ssm":
            # (U, n, B, H, N, P)
            U, n, B, H, _, _ = leaf.shape
            b_ax = tuple(dp) if batch_ok else None
            h_ax = tp if H % axis_size.get(tp, 1) == 0 else None
            return P(None, None, b_ax, h_ax, None, None)
        if name == "wkv":
            U, n, B, H, _, _ = leaf.shape
            b_ax = tuple(dp) if batch_ok else None
            h_ax = tp if H % axis_size.get(tp, 1) == 0 else None
            return P(None, None, b_ax, h_ax, None, None)
        if name == "conv":
            # (U, n, B, K-1, d_inner)
            U, n, B, K1, di = leaf.shape
            b_ax = tuple(dp) if batch_ok else None
            d_ax = tp if di % axis_size.get(tp, 1) == 0 else None
            return P(None, None, b_ax, None, d_ax)
        if name in ("shift_t", "shift_c"):
            U, n, B, D = leaf.shape
            b_ax = tuple(dp) if batch_ok else None
            return P(None, None, b_ax, None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(spec_of, cache_shapes)


def batch_pspec(batch: int, mesh: Mesh, dp_axes: Tuple[str, ...]) -> P:
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = [a for a in dp_axes if a in axis_size]
    total = 1
    for a in dp:
        total *= axis_size[a]
    if batch % total == 0:
        return P(tuple(dp))
    # try the first axis alone
    if dp and batch % axis_size[dp[0]] == 0:
        return P(dp[0])
    return P(None)


def to_named(tree, mesh: Mesh, memory_kind: Optional[str] = None):
    kw = {"memory_kind": memory_kind} if memory_kind else {}
    return jax.tree.map(lambda s: NamedSharding(mesh, s, **kw), tree,
                        is_leaf=lambda x: isinstance(x, P))
