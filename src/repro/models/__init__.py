from . import modules, lm
