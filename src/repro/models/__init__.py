from . import lm, modules

__all__ = ["lm", "modules"]
