"""Unified pattern-based language model.

One implementation drives all ten assigned architectures: a repeating
*unit* of heterogeneous layers (attention / Mamba / RWKV / cross-attention,
each optionally MoE) scanned ``n_units`` times over stacked parameters.
This keeps HLO size O(unit) instead of O(n_layers) — essential for the
94-layer MoE and 72-layer hybrid dry-runs.

Modes:
  * forward(..., mode="train")   -> chunked-CE loss (never materializes
                                    full (B,S,V) logits)
  * forward(..., mode="prefill") -> last-token logits + decode cache
  * forward(..., mode="decode")  -> next-token logits + updated cache

Cache layout (pytree of stacked arrays, axis 0 = unit):
  kv_k/kv_v     (U, n_attn,  B, S_max, KV, hd)
  conv/ssm      (U, n_mamba, B, K-1, d_inner) / (U, n_mamba, B, H, N, P)
  wkv/shift_*   (U, n_rwkv,  B, H, P, P) / (U, n_rwkv, B, D)
  cross_k/v     (U, n_cross, B, S_enc, KV, hd)
plus a scalar "index" (tokens already in cache).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import LayerSpec, ModelConfig
from . import modules as M

Params = Dict[str, Any]


# ====================================================================== #
# Init                                                                   #
# ====================================================================== #
def _init_layer(key, cfg: ModelConfig, spec: LayerSpec) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {}
    D = cfg.d_model
    if spec.kind == "attn":
        p["norm1"] = M.init_norm(cfg.norm, D)
        p["attn"] = M.init_attention(ks[0], D, cfg.n_heads, cfg.n_kv,
                                     cfg.head_dim, cfg.qkv_bias)
    elif spec.kind == "cross":
        p["norm1"] = M.init_norm(cfg.norm, D)
        p["attn"] = M.init_attention(ks[0], D, cfg.n_heads, cfg.n_kv,
                                     cfg.head_dim, cfg.qkv_bias)
        p["xkv"] = {  # projections applied to the cross inputs
            "wk": M.init_attention(ks[1], D, cfg.n_heads, cfg.n_kv,
                                   cfg.head_dim)["wk"],
            "wv": M.init_attention(ks[2], D, cfg.n_heads, cfg.n_kv,
                                   cfg.head_dim)["wv"]}
        p["gate_attn"] = jnp.zeros((), jnp.float32)
        p["gate_mlp"] = jnp.zeros((), jnp.float32)
    elif spec.kind == "mamba":
        p["norm1"] = M.init_norm(cfg.norm, D)
        p["mamba"] = M.init_mamba(ks[0], _mdims(cfg))
    elif spec.kind == "rwkv":
        p["norm1"] = M.init_norm("ln", D)
        p["tmix"] = M.init_rwkv_tmix(ks[0], _rdims(cfg))
        p["norm2"] = M.init_norm("ln", D)
        p["cmix"] = M.init_rwkv_cmix(ks[1], _rdims(cfg))
        return p
    else:
        raise ValueError(spec.kind)

    if spec.cross_attn:  # whisper-style extra cross sublayer
        p["cross_norm"] = M.init_norm(cfg.norm, D)
        p["cross"] = M.init_attention(ks[3], D, cfg.n_heads, cfg.n_kv,
                                      cfg.head_dim)

    p["norm2"] = M.init_norm(cfg.norm, D)
    if spec.moe:
        p["moe"] = M.init_moe(ks[4], D, cfg.d_ff, cfg.n_experts, cfg.act)
    else:
        p["mlp"] = M.init_mlp(ks[4], D, cfg.d_ff, cfg.act)
    return p


def _init_unit(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, len(cfg.pattern))
    return {"layers": tuple(_init_layer(k, cfg, s)
                            for k, s in zip(keys, cfg.pattern))}


def _mdims(cfg: ModelConfig) -> M.MambaDims:
    return M.mamba_dims(cfg.d_model, cfg.mamba_expand, cfg.mamba_head_dim,
                        cfg.mamba_d_state, cfg.mamba_d_conv, cfg.ssd_chunk)


def _rdims(cfg: ModelConfig) -> M.RwkvDims:
    return M.rwkv_dims(cfg.d_model, cfg.d_ff, cfg.rwkv_head_dim,
                       cfg.rwkv_chunk)


ENC_SPEC = LayerSpec(kind="attn")


def init_params(key, cfg: ModelConfig) -> Params:
    k_e, k_u, k_h, k_enc, k_pos = jax.random.split(key, 5)
    D = cfg.d_model
    p: Params = {
        "embed": (jax.random.normal(k_e, (cfg.vocab, D)) * 0.02
                  ).astype(jnp.bfloat16),
        "final_norm": M.init_norm(cfg.norm, D),
        "units": jax.vmap(lambda k: _init_unit(k, cfg))(
            jax.random.split(k_u, cfg.n_units)),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(k_h, (cfg.vocab, D)) * 0.02
                        ).astype(jnp.bfloat16)
    if cfg.pos_emb == "learned":
        p["pos_emb"] = (jax.random.normal(k_pos, (cfg.max_pos, D)) * 0.02
                        ).astype(jnp.bfloat16)
    if cfg.encoder_layers:
        enc_cfg = cfg
        p["encoder"] = {
            "units": jax.vmap(
                lambda k: {"layers": (
                    _init_layer(k, enc_cfg, ENC_SPEC),)})(
                jax.random.split(k_enc, cfg.encoder_layers)),
            "final_norm": M.init_norm(cfg.norm, D),
        }
    return p


# ====================================================================== #
# Unit forward                                                           #
# ====================================================================== #
def _sinusoidal(S: int, D: int, offset=0) -> jax.Array:
    pos = jnp.arange(S)[:, None] + offset
    dim = jnp.arange(0, D, 2)[None, :]
    ang = pos / jnp.power(10000.0, dim / D)
    out = jnp.zeros((S, D))
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang))
    return out


def _attn_kwargs(cfg: ModelConfig) -> Dict[str, Any]:
    return dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
                rope_theta=cfg.rope_theta, rotary_pct=cfg.rotary_pct,
                use_rope=(cfg.pos_emb == "rope"),
                attn_chunk=cfg.attn_chunk)


def _unit_fwd(cfg: ModelConfig, up: Params, x: jax.Array, *,
              mode: str, positions, cross_inputs, unit_cache,
              cache_index, causal: bool = True
              ) -> Tuple[jax.Array, jax.Array, Optional[Params]]:
    """Forward one repeating unit; returns (x, moe_aux, new_unit_cache)."""
    decode = mode == "decode"
    aux_total = jnp.zeros((), jnp.float32)
    want_cache = mode in ("prefill", "decode")
    B = x.shape[0]
    new_cache: Dict[str, list] = {k: [] for k in
                                  ("kv_k", "kv_v", "kv_k_scale",
                                   "kv_v_scale", "conv", "ssm", "wkv",
                                   "shift_t", "shift_c", "cross_k",
                                   "cross_v")}
    kv_int8 = cfg.kv_cache_dtype == "int8"
    i_attn = i_mamba = i_rwkv = i_cross = 0
    akw = _attn_kwargs(cfg)

    for li, spec in enumerate(cfg.pattern):
        lp = up["layers"][li]

        if spec.kind == "attn":
            h = M.apply_norm(cfg.norm, lp["norm1"], x)
            kv = None
            if decode:
                if kv_int8:
                    kv = (unit_cache["kv_k"][i_attn],
                          unit_cache["kv_v"][i_attn],
                          unit_cache["kv_k_scale"][i_attn],
                          unit_cache["kv_v_scale"][i_attn])
                else:
                    kv = (unit_cache["kv_k"][i_attn],
                          unit_cache["kv_v"][i_attn])
            out, new_kv = M.attention_fwd(
                lp["attn"], h, causal=causal, positions=positions,
                kv_cache=kv, cache_index=cache_index if decode else None,
                **akw)
            x = x + out
            if want_cache:
                if decode and kv_int8:
                    new_cache["kv_k"].append(new_kv[0])
                    new_cache["kv_v"].append(new_kv[1])
                    new_cache["kv_k_scale"].append(new_kv[2])
                    new_cache["kv_v_scale"].append(new_kv[3])
                elif kv_int8:  # prefill: quantize for the cache
                    kq, ks = M.quantize_kv(new_kv[0])
                    vq, vs = M.quantize_kv(new_kv[1])
                    new_cache["kv_k"].append(kq)
                    new_cache["kv_v"].append(vq)
                    new_cache["kv_k_scale"].append(ks)
                    new_cache["kv_v_scale"].append(vs)
                else:
                    new_cache["kv_k"].append(
                        new_kv[0].astype(jnp.bfloat16))
                    new_cache["kv_v"].append(
                        new_kv[1].astype(jnp.bfloat16))
            i_attn += 1

        elif spec.kind == "cross":
            # cross-only layer (Llama-3.2-Vision image layers)
            h = M.apply_norm(cfg.norm, lp["norm1"], x)
            if decode:
                xk = unit_cache["cross_k"][i_cross]
                xv = unit_cache["cross_v"][i_cross]
            else:
                S_enc = cross_inputs.shape[1]
                xk = (cross_inputs @ lp["xkv"]["wk"]).reshape(
                    B, S_enc, cfg.n_kv, cfg.head_dim)
                xv = (cross_inputs @ lp["xkv"]["wv"]).reshape(
                    B, S_enc, cfg.n_kv, cfg.head_dim)
            out, _ = M.attention_fwd(lp["attn"], h, causal=False,
                                     positions=None,
                                     cross_kv=(xk, xv), **akw)
            x = x + jnp.tanh(lp["gate_attn"]).astype(out.dtype) * out
            if want_cache:
                new_cache["cross_k"].append(xk.astype(jnp.bfloat16))
                new_cache["cross_v"].append(xv.astype(jnp.bfloat16))
            i_cross += 1

        elif spec.kind == "mamba":
            h = M.apply_norm(cfg.norm, lp["norm1"], x)
            cs = ss = None
            if decode:
                cs = unit_cache["conv"][i_mamba]
                ss = unit_cache["ssm"][i_mamba]
            out, (cs2, ss2) = M.mamba_fwd(lp["mamba"], h, _mdims(cfg),
                                          conv_state=cs, ssm_state=ss)
            x = x + out
            if want_cache:
                new_cache["conv"].append(cs2)
                new_cache["ssm"].append(ss2)
            i_mamba += 1

        elif spec.kind == "rwkv":
            h = M.apply_norm("ln", lp["norm1"], x)
            ws = sh = None
            if decode:
                ws = unit_cache["wkv"][i_rwkv]
                sh = unit_cache["shift_t"][i_rwkv]
            out, (ws2, sh2) = M.rwkv_tmix_fwd(lp["tmix"], h, _rdims(cfg),
                                              wkv_state=ws, shift_state=sh)
            x = x + out
            h = M.apply_norm("ln", lp["norm2"], x)
            shc = unit_cache["shift_c"][i_rwkv] if decode else None
            out, shc2 = M.rwkv_cmix_fwd(lp["cmix"], h, shift_state=shc)
            x = x + out
            if want_cache:
                new_cache["wkv"].append(ws2)
                new_cache["shift_t"].append(sh2)
                new_cache["shift_c"].append(shc2)
            i_rwkv += 1
            continue  # rwkv unit has no separate MLP block

        # whisper-style additional cross sublayer
        if spec.cross_attn:
            h = M.apply_norm(cfg.norm, lp["cross_norm"], x)
            if decode:
                xk = unit_cache["cross_k"][i_cross]
                xv = unit_cache["cross_v"][i_cross]
            else:
                S_enc = cross_inputs.shape[1]
                xk = (cross_inputs @ lp["cross"]["wk"]).reshape(
                    B, S_enc, cfg.n_kv, cfg.head_dim)
                xv = (cross_inputs @ lp["cross"]["wv"]).reshape(
                    B, S_enc, cfg.n_kv, cfg.head_dim)
            out, _ = M.attention_fwd(lp["cross"], h, causal=False,
                                     positions=None,
                                     cross_kv=(xk, xv), **akw)
            x = x + out
            if want_cache:
                new_cache["cross_k"].append(xk.astype(jnp.bfloat16))
                new_cache["cross_v"].append(xv.astype(jnp.bfloat16))
            i_cross += 1

        # MLP / MoE sublayer
        h = M.apply_norm(cfg.norm, lp["norm2"], x)
        if spec.moe:
            out, aux = M.moe_fwd(lp["moe"], h, top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor,
                                 n_groups=cfg.moe_groups, act=cfg.act)
            aux_total = aux_total + aux
        else:
            out = M.mlp_fwd(lp["mlp"], h, cfg.act)
        if spec.kind == "cross":
            out = jnp.tanh(lp["gate_mlp"]).astype(out.dtype) * out
        x = x + out

    cache_out = None
    if want_cache:
        cache_out = {k: jnp.stack(v) for k, v in new_cache.items() if v}
    return x, aux_total, cache_out


def _stack_fwd(cfg: ModelConfig, units: Params, x: jax.Array, *,
               mode: str, positions, cross_inputs,
               cache_units=None, cache_index=None, causal=True,
               pattern_override=None):
    """lax.scan over stacked unit params (and cache, in decode)."""

    from . import psharding as PS

    def body(carry, xs):
        h, aux = carry
        if mode == "decode":
            up, uc = xs
        else:
            up, uc = xs, None
        h, aux_u, new_uc = _unit_fwd(
            cfg, up, h, mode=mode, positions=positions,
            cross_inputs=cross_inputs, unit_cache=uc,
            cache_index=cache_index, causal=causal)
        # sequence parallelism at the unit boundary (Megatron-SP): the
        # scan-AD carry stack is S-sharded over the model axis, cutting
        # saved-activation memory by the TP degree.
        h = PS.constrain(h, "dp", "tp", None)
        return (h, aux + aux_u), new_uc

    if cfg.remat:
        body = jax.checkpoint(body)
    xs = (units, cache_units) if mode == "decode" else units
    (x, aux), caches = lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, aux, caches


# ====================================================================== #
# Public entry points                                                    #
# ====================================================================== #
def _embed_tokens(p: Params, cfg: ModelConfig, tokens: jax.Array,
                  index=None) -> jax.Array:
    from . import psharding as PS

    x = PS.constrain(p["embed"][tokens].astype(jnp.bfloat16),
                     "dp", None, None)
    S = tokens.shape[1]
    if cfg.pos_emb == "learned":
        if index is None:
            pe = p["pos_emb"][:S]
        else:
            pe = lax.dynamic_slice(p["pos_emb"], (index, 0),
                                   (S, cfg.d_model))
        x = x + pe[None]
    elif cfg.pos_emb == "sinusoidal":
        x = x + _sinusoidal(S, cfg.d_model,
                            0 if index is None else index
                            )[None].astype(x.dtype)
    return x


def encode(p: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Whisper-style encoder over stubbed frame embeddings (B, S_enc, D)."""
    x = frames.astype(jnp.bfloat16)
    x = x + _sinusoidal(frames.shape[1], cfg.d_model)[None].astype(x.dtype)
    enc = p["encoder"]
    x, _, _ = _stack_fwd(
        _enc_cfg(cfg), enc["units"], x, mode="train",
        positions=jnp.arange(frames.shape[1]), cross_inputs=None,
        causal=False)
    return M.apply_norm(cfg.norm, enc["final_norm"], x)


def _enc_cfg(cfg: ModelConfig) -> ModelConfig:
    import dataclasses
    return dataclasses.replace(cfg, pattern=(ENC_SPEC,),
                               n_layers=cfg.encoder_layers,
                               pos_emb="sinusoidal")


def _lm_head(p: Params, cfg: ModelConfig) -> jax.Array:
    return p["embed"] if cfg.tie_embeddings else p["lm_head"]


def forward_loss(p: Params, cfg: ModelConfig, tokens: jax.Array,
                 labels: jax.Array,
                 cross_inputs: Optional[jax.Array] = None) -> jax.Array:
    """Training loss with chunked cross-entropy (no (B,S,V) logits)."""
    if cfg.encoder_layers:
        cross_inputs = encode(p, cfg, cross_inputs)
    x = _embed_tokens(p, cfg, tokens)
    S = tokens.shape[1]
    x, aux, _ = _stack_fwd(cfg, p["units"], x, mode="train",
                           positions=jnp.arange(S),
                           cross_inputs=cross_inputs)
    x = M.apply_norm(cfg.norm, p["final_norm"], x)
    W = _lm_head(p, cfg)

    C = min(cfg.loss_chunk, S)
    nC = S // C
    assert S % C == 0
    xc = x.reshape(x.shape[0], nC, C, cfg.d_model).swapaxes(0, 1)
    yc = labels.reshape(labels.shape[0], nC, C).swapaxes(0, 1)

    from . import psharding as PS

    def chunk_ce(carry, xy):
        xi, yi = xy
        logits = (xi @ W.T).astype(jnp.float32)          # (B,C,V)
        logits = PS.constrain(logits, "dp", None, "tp")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yi[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    body = chunk_ce
    if cfg.remat:
        body = jax.checkpoint(body)
    total, _ = lax.scan(body, jnp.zeros((), jnp.float32), (xc, yc))
    ce = total / (labels.shape[0] * S)
    return ce + 0.01 * aux


def prefill(p: Params, cfg: ModelConfig, tokens: jax.Array,
            cross_inputs: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Params]:
    """Prefill: returns (last-token logits (B, V), cache)."""
    if cfg.encoder_layers:
        cross_inputs = encode(p, cfg, cross_inputs)
    x = _embed_tokens(p, cfg, tokens)
    S = tokens.shape[1]
    x, _, caches = _stack_fwd(cfg, p["units"], x, mode="prefill",
                              positions=jnp.arange(S),
                              cross_inputs=cross_inputs)
    x = M.apply_norm(cfg.norm, p["final_norm"], x[:, -1:])
    logits = (x[:, 0] @ _lm_head(p, cfg).T).astype(jnp.float32)
    caches["index"] = jnp.array(S, jnp.int32)
    return logits, caches


def decode_step(p: Params, cfg: ModelConfig, cache: Params,
                tokens: jax.Array) -> Tuple[jax.Array, Params]:
    """One decode step: tokens (B, 1) -> (logits (B, V), new cache)."""
    idx = cache["index"]
    x = _embed_tokens(p, cfg, tokens, index=idx)
    cache_units = {k: v for k, v in cache.items() if k != "index"}
    x, _, new_units = _stack_fwd(cfg, p["units"], x, mode="decode",
                                 positions=None, cross_inputs=None,
                                 cache_units=cache_units, cache_index=idx)
    x = M.apply_norm(cfg.norm, p["final_norm"], x)
    logits = (x[:, 0] @ _lm_head(p, cfg).T).astype(jnp.float32)
    new_units["index"] = idx + tokens.shape[1]
    return logits, new_units


def make_decode_cache(cfg: ModelConfig, batch: int, max_seq: int,
                      enc_len: int = 0, dtype=jnp.bfloat16) -> Params:
    """Zero-initialized decode cache (for dry-run serve_step lowering)."""
    U = cfg.n_units
    B = batch
    cache: Params = {"index": jnp.zeros((), jnp.int32)}
    n_attn = len(cfg.unit_attn_layers)
    n_mamba = len(cfg.unit_mamba_layers)
    n_rwkv = len(cfg.unit_rwkv_layers)
    n_cross = len([s for s in cfg.pattern
                   if s.cross_attn or s.kind == "cross"])
    hd, KV = cfg.head_dim, cfg.n_kv
    if n_attn:
        kv_dt = jnp.int8 if cfg.kv_cache_dtype == "int8" else dtype
        cache["kv_k"] = jnp.zeros((U, n_attn, B, max_seq, KV, hd), kv_dt)
        cache["kv_v"] = jnp.zeros((U, n_attn, B, max_seq, KV, hd), kv_dt)
        if cfg.kv_cache_dtype == "int8":
            cache["kv_k_scale"] = jnp.zeros((U, n_attn, B, max_seq, KV),
                                            dtype)
            cache["kv_v_scale"] = jnp.zeros((U, n_attn, B, max_seq, KV),
                                            dtype)
    if n_mamba:
        md = _mdims_cfg(cfg)
        cache["conv"] = jnp.zeros(
            (U, n_mamba, B, cfg.mamba_d_conv - 1, md.d_inner), dtype)
        cache["ssm"] = jnp.zeros(
            (U, n_mamba, B, md.n_heads, md.d_state, md.head_dim),
            jnp.float32)
    if n_rwkv:
        rd = _rdims_cfg(cfg)
        cache["wkv"] = jnp.zeros(
            (U, n_rwkv, B, rd.n_heads, rd.head_dim, rd.head_dim),
            jnp.float32)
        cache["shift_t"] = jnp.zeros((U, n_rwkv, B, cfg.d_model), dtype)
        cache["shift_c"] = jnp.zeros((U, n_rwkv, B, cfg.d_model), dtype)
    if n_cross:
        cache["cross_k"] = jnp.zeros((U, n_cross, B, enc_len, KV, hd),
                                     dtype)
        cache["cross_v"] = jnp.zeros((U, n_cross, B, enc_len, KV, hd),
                                     dtype)
    return cache


def _mdims_cfg(cfg):
    return M.mamba_dims(cfg.d_model, cfg.mamba_expand, cfg.mamba_head_dim,
                        cfg.mamba_d_state, cfg.mamba_d_conv, cfg.ssd_chunk)


def _rdims_cfg(cfg):
    return M.rwkv_dims(cfg.d_model, cfg.d_ff, cfg.rwkv_head_dim,
                       cfg.rwkv_chunk)
