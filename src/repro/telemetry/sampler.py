"""Sampling front-end: the hint-fault / PEBS analogue.

Full-resolution recording of every access is exactly the profiling
overhead the paper's PMO 2 warns about (TPP's every-touch hint faults
cost it the win).  Production profilers therefore *sample*: one record
per ``1/sample_rate`` cache lines (a PEBS period, or a hint-fault scan
interval).  ``AccessSampler`` models that: emitters call ``observe``
with true byte counts, the sampler deterministically takes
``lines * rate`` samples (a carry accumulator per (object, channel) —
no RNG, so runs are reproducible), scales the sampled lines back up by
``1/rate`` into an *estimated* event on the underlying AccessTrace, and
charges every sample a profiling cost.

The per-sample cost mirrors how core.migration charges hint faults
(``fault_cost_s``), plus — when a ``MemoryTier`` is given — the loaded
random-access time of the sampled cache line on that tier
(core.tiers.access_time_s): sampling slow-tier pages is more expensive,
which is the paper's PMO-2 observation that profiling overhead scales
with where the samples land.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from ..core.tiers import MemoryTier
from .events import AccessTrace

LINE_BYTES = 64


@dataclasses.dataclass
class SamplerConfig:
    """PEBS-analogue knobs.

    sample_rate   fraction of cache lines sampled (1e-6 = one sample per
                  million lines, a realistic PEBS period; >= 1.0 means
                  full instrumentation — every line recorded and paid).
    sample_cost_s CPU cost per retired sample (hint-fault analogue;
                  matches core.migration's fault_cost_s scale).
    tier          optional tier the samples land on; adds that tier's
                  loaded random cache-line access time per sample.
    """

    sample_rate: float = 1e-6
    sample_cost_s: float = 2e-6
    tier: Optional[MemoryTier] = None

    def __post_init__(self):
        if self.sample_rate <= 0:
            raise ValueError("sample_rate must be positive")


class AccessSampler:
    """Deterministic sampling layer over an AccessTrace."""

    def __init__(self, trace: AccessTrace,
                 cfg: Optional[SamplerConfig] = None):
        self.trace = trace
        self.cfg = cfg or SamplerConfig()
        self._carry: Dict[Tuple[str, str], float] = {}
        self.samples = 0
        self.overhead_s = 0.0

    # ------------------------------------------------------------------ #
    def _per_sample_cost(self) -> float:
        c = self.cfg.sample_cost_s
        if self.cfg.tier is not None:
            c += self.cfg.tier.access_time_s(LINE_BYTES, streams=1.0,
                                             random=True)
        return c

    def _sample(self, obj: str, channel: str, nbytes: int) -> int:
        """Sampled-line count -> estimated bytes for one channel."""
        if nbytes <= 0:
            return 0
        lines = nbytes / LINE_BYTES
        rate = self.cfg.sample_rate
        if rate >= 1.0:
            n = max(int(round(lines)), 1)
            self.samples += n
            self.overhead_s += n * self._per_sample_cost()
            return nbytes                      # exact at full rate
        acc = self._carry.get((obj, channel), 0.0) + lines * rate
        n = int(acc)
        self._carry[(obj, channel)] = acc - n
        if n == 0:
            return 0
        self.samples += n
        self.overhead_s += n * self._per_sample_cost()
        return int(n * LINE_BYTES / rate)      # scale back to bytes

    # ------------------------------------------------------------------ #
    def observe(self, obj: str, read_bytes: int = 0, write_bytes: int = 0,
                random_fraction: float = 0.0, phase: str = "",
                block: Optional[int] = None) -> None:
        """Record a (possibly sampled) access against the trace."""
        r = self._sample(obj, "r", int(read_bytes))
        w = self._sample(obj, "w", int(write_bytes))
        if r or w:
            self.trace.record(obj, r, w, random_fraction, phase=phase,
                              block=block)

    def advance_epoch(self) -> int:
        return self.trace.advance_epoch()

    def forget(self, obj: str) -> None:
        """Drop the carry state of a retired object (e.g. a finished
        sequence) so long-running emitters with ever-fresh object names
        cannot grow the accumulator without bound."""
        self._carry.pop((obj, "r"), None)
        self._carry.pop((obj, "w"), None)
        self.trace.forget(obj)

    # ------------------------------------------------------------------ #
    def overhead_fraction(self, step_time_s: float) -> float:
        """Profiling overhead as a fraction of the given run time."""
        return self.overhead_s / max(step_time_s, 1e-12)
