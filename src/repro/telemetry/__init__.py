"""repro.telemetry: online access telemetry + adaptive re-interleaving.

The profile -> re-plan -> re-place loop the paper's static §V-B policy
lacks:

- events:  per-object/per-block access-event recording into a
           ring-buffered, epoch-bucketed AccessTrace
- sampler: hint-fault/PEBS-analogue sampling front-end with a modeled
           profiling-overhead account (PMO 2)
- phases:  workload-phase detection (prefill vs decode, streaming vs
           random, request-mix drift) from trace deltas
- replan:  adaptive controller that rebuilds DataObjects from measured
           traffic, re-runs ObjectLevelInterleave, gates the new plan
           with core.costmodel, and executes the placement delta
           through core.migration.MigrationExecutor
"""
from .events import AccessEvent, AccessTrace, EpochBucket, ObjectTraffic
from .phases import (classify_traffic, PhaseDetector, PhaseShift,
                     traffic_distance, traffic_signature)
from .replan import AdaptiveReplanner, ReplanConfig, ReplanDecision
from .sampler import AccessSampler, LINE_BYTES, SamplerConfig

__all__ = [
    "AccessEvent", "AccessTrace", "EpochBucket", "ObjectTraffic",
    "LINE_BYTES", "AccessSampler", "SamplerConfig",
    "PhaseDetector", "PhaseShift", "classify_traffic", "traffic_distance",
    "traffic_signature",
    "AdaptiveReplanner", "ReplanConfig", "ReplanDecision",
]
