"""Workload-phase detection from trace deltas.

The paper's PMOs show static placement loses the moment the access
pattern shifts (PMO 1 vs PMO 5: which policy wins depends on the
workload's hot-set dynamics).  This module turns the access trace into
a phase signal the replanner can act on:

  * each completed epoch is summarized as a normalized per-object byte
    vector plus a coarse *label* from its aggregate character:
    ``random`` (CG/XSBench-style, latency-bound), ``write_heavy``
    (prefill / optimizer-update-style), ``streaming`` (MG/decode-style
    bandwidth-bound reads), or ``idle``;
  * a phase shift fires when the total-variation distance between
    consecutive epoch vectors exceeds ``threshold`` (request-mix /
    working-set drift) or the label flips (prefill -> decode,
    train -> eval), debounced by ``min_phase_epochs`` so transient
    epochs cannot thrash the replanner.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional

from .events import AccessTrace, EpochBucket, ObjectTraffic


def classify_traffic(bucket: Mapping[str, ObjectTraffic]) -> str:
    """Coarse phase label from one epoch's aggregate traffic."""
    reads = sum(t.read_bytes for t in bucket.values())
    writes = sum(t.write_bytes for t in bucket.values())
    total = reads + writes
    if total <= 0:
        return "idle"
    rand = sum(t.random_bytes for t in bucket.values()) / total
    if rand > 0.5:
        return "random"
    if writes / total > 0.35:
        return "write_heavy"
    return "streaming"


def traffic_distance(a: Mapping[str, float],
                     b: Mapping[str, float]) -> float:
    """Total-variation distance between two normalized traffic vectors
    (0 = identical mix, 1 = disjoint working sets)."""
    keys = set(a) | set(b)
    return 0.5 * sum(abs(a.get(k, 0.0) - b.get(k, 0.0)) for k in keys)


@dataclasses.dataclass(frozen=True)
class PhaseShift:
    """One detected phase boundary."""

    epoch: int
    distance: float
    old_label: str
    new_label: str


class PhaseDetector:
    """Online phase tracking over an AccessTrace.

    Call ``update()`` once per completed epoch (after
    ``advance_epoch``); it returns a PhaseShift when a boundary is
    crossed, else None.
    """

    def __init__(self, trace: AccessTrace, threshold: float = 0.35,
                 min_phase_epochs: int = 2):
        self.trace = trace
        self.threshold = threshold
        self.min_phase_epochs = min_phase_epochs
        self.phase_id = 0
        self.label = "idle"
        self.shifts: List[PhaseShift] = []
        self._prev_vec: Optional[Dict[str, float]] = None
        self._epochs_in_phase = 0
        self._last_seen_epoch = -1

    def update(self) -> Optional[PhaseShift]:
        if self.trace.epochs_recorded == 0:
            return None
        epoch_id, bucket = self.trace.buckets(1)[0]
        if epoch_id == self._last_seen_epoch:
            return None                      # nothing new completed
        self._last_seen_epoch = epoch_id
        vec = self.trace.epoch_vector(bucket)
        label = classify_traffic(bucket)
        shift: Optional[PhaseShift] = None
        if self._prev_vec is not None:
            d = traffic_distance(self._prev_vec, vec)
            moved = d > self.threshold or (label != self.label
                                           and label != "idle")
            if moved and self._epochs_in_phase >= self.min_phase_epochs:
                shift = PhaseShift(epoch_id, d, self.label, label)
                self.shifts.append(shift)
                self.phase_id += 1
                self._epochs_in_phase = 0
        elif label != "idle":
            self.label = label
        if shift is not None:
            self.label = label
        self._prev_vec = vec
        self._epochs_in_phase += 1
        return shift

    @property
    def epochs_in_phase(self) -> int:
        return self._epochs_in_phase
