"""Workload-phase detection from trace deltas.

The paper's PMOs show static placement loses the moment the access
pattern shifts (PMO 1 vs PMO 5: which policy wins depends on the
workload's hot-set dynamics).  This module turns the access trace into
a phase signal the replanner can act on:

  * each completed epoch is summarized as a normalized per-object byte
    vector plus a coarse *label* from its aggregate character:
    ``random`` (CG/XSBench-style, latency-bound), ``write_heavy``
    (prefill / optimizer-update-style), ``streaming`` (MG/decode-style
    bandwidth-bound reads), or ``idle``;
  * a phase shift fires when the total-variation distance between
    consecutive epoch vectors exceeds ``threshold`` (request-mix /
    working-set drift) or the label flips (prefill -> decode,
    train -> eval), debounced by ``min_phase_epochs`` so transient
    epochs cannot thrash the replanner;
  * each epoch also gets a quantized **recurrence signature**
    (``traffic_signature``): label + log-bucketed intensity + coarse
    per-object shares.  The detector tracks how long each signature
    runs and which signature follows it, so ``expected_signature``
    can predict the *next* epoch's phase for a periodic workload —
    the signal the predictive ``TierBudgetArbiter`` and the
    replanner's phase prefetch consume to grant budgets and pre-stage
    promotions *before* a recurring burst's first epoch.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Dict, Hashable, List, Mapping, Optional

from .events import AccessTrace, ObjectTraffic


def classify_traffic(bucket: Mapping[str, ObjectTraffic]) -> str:
    """Coarse phase label from one epoch's aggregate traffic."""
    reads = sum(t.read_bytes for t in bucket.values())
    writes = sum(t.write_bytes for t in bucket.values())
    total = reads + writes
    if total <= 0:
        return "idle"
    rand = sum(t.random_bytes for t in bucket.values()) / total
    if rand > 0.5:
        return "random"
    if writes / total > 0.35:
        return "write_heavy"
    return "streaming"


def traffic_signature(bucket: Mapping[str, ObjectTraffic],
                      levels: int = 4,
                      mag_base: float = 4.0) -> Hashable:
    """Quantized recurrence signature of one epoch's traffic.

    Two epochs of the same workload phase should hash to the same
    signature even under modest noise, while phases that differ in
    *intensity* (a decode burst vs a drained lull with the same object
    mix) must not collide — the coarse label and the normalized share
    vector are blind to absolute traffic, so the signature also carries
    a log-bucketed magnitude (``mag_base`` = one bucket per ~4x traffic
    change).  Shares are rounded to ``levels`` steps per object.
    """
    label = classify_traffic(bucket)
    total = sum(t.total_bytes for t in bucket.values())
    if total <= 0:
        return (label, 0, ())
    mag = int(round(math.log(max(total, 1), mag_base)))
    shares = tuple(sorted(
        (obj, q) for obj, t in bucket.items()
        if (q := round(t.total_bytes / total * levels)) > 0))
    return (label, mag, shares)


def traffic_distance(a: Mapping[str, float],
                     b: Mapping[str, float]) -> float:
    """Total-variation distance between two normalized traffic vectors
    (0 = identical mix, 1 = disjoint working sets)."""
    keys = set(a) | set(b)
    return 0.5 * sum(abs(a.get(k, 0.0) - b.get(k, 0.0)) for k in keys)


@dataclasses.dataclass(frozen=True)
class PhaseShift:
    """One detected phase boundary."""

    epoch: int
    distance: float
    old_label: str
    new_label: str


class PhaseDetector:
    """Online phase tracking over an AccessTrace.

    Call ``update()`` once per completed epoch (after
    ``advance_epoch``); it returns a PhaseShift when a boundary is
    crossed, else None.
    """

    def __init__(self, trace: AccessTrace, threshold: float = 0.35,
                 min_phase_epochs: int = 2,
                 max_signatures: int = 32,
                 signature_ttl_epochs: int = 256):
        self.trace = trace
        self.threshold = threshold
        self.min_phase_epochs = min_phase_epochs
        self.phase_id = 0
        self.label = "idle"
        self.shifts: List[PhaseShift] = []
        self._prev_vec: Optional[Dict[str, float]] = None
        self._epochs_in_phase = 0
        self._last_seen_epoch = -1
        # recurrence tracking: the signature of the last completed
        # epoch, how long its run has lasted, observed run lengths per
        # signature, and which signature historically follows which
        self.signature: Optional[Hashable] = None
        self.max_signatures = max_signatures
        self.signature_ttl_epochs = signature_ttl_epochs
        self._sig_run = 0
        self._sig_durations: Dict[Hashable, Deque[int]] = {}
        self._sig_successor: Dict[Hashable, Dict[Hashable, int]] = {}
        self._sig_seen: Dict[Hashable, int] = {}

    def _observe_signature(self, epoch_id: int, bucket) -> None:
        sig = traffic_signature(bucket)
        if sig == self.signature:
            self._sig_run += 1
        else:
            prev = self.signature
            if prev is not None and self._sig_run > 0:
                self._sig_durations.setdefault(
                    prev, deque(maxlen=8)).append(self._sig_run)
                succ = self._sig_successor.setdefault(prev, {})
                succ[sig] = succ.get(sig, 0) + 1
            self.signature = sig
            self._sig_run = 1
        self._sig_seen[sig] = epoch_id
        self._evict_stale_signatures(epoch_id)

    def _evict_stale_signatures(self, epoch_id: int) -> None:
        """Drop recurrence state for signatures not seen recently: a
        workload that stopped recurring must not keep predicting, and
        the tables stay bounded on long-lived processes."""
        stale = {s for s, last in self._sig_seen.items()
                 if epoch_id - last > self.signature_ttl_epochs}
        if len(self._sig_seen) - len(stale) > self.max_signatures:
            keep = sorted(self._sig_seen, key=self._sig_seen.get,
                          reverse=True)[: self.max_signatures]
            stale |= set(self._sig_seen) - set(keep) - {self.signature}
        for s in stale:
            self._sig_seen.pop(s, None)
            self._sig_durations.pop(s, None)
            self._sig_successor.pop(s, None)
        for succ in self._sig_successor.values():
            for s in stale:
                succ.pop(s, None)

    def typical_duration(self, sig: Hashable) -> Optional[int]:
        """Median observed run length of ``sig`` (None if never ended)."""
        runs = self._sig_durations.get(sig)
        if not runs:
            return None
        return sorted(runs)[len(runs) // 2]

    def likely_successor(self, sig: Hashable) -> Optional[Hashable]:
        """The signature that most often followed ``sig``."""
        succ = self._sig_successor.get(sig)
        if not succ:
            return None
        return max(sorted(succ), key=succ.get)

    def expected_signature(self, ahead: int = 1) -> Optional[Hashable]:
        """Signature predicted for the epoch ``ahead`` steps after the
        last completed one (``ahead=1`` = the epoch about to run).

        Walks the learned recurrence forward: while the current
        signature's run has not reached its typical duration the phase
        is expected to continue; once it has, the most common successor
        takes over.  Falls back to "more of the same" whenever duration
        or successor is unknown — the reactive behaviour.
        """
        sig, run = self.signature, self._sig_run
        if sig is None:
            return None
        for _ in range(max(ahead, 0)):
            dur = self.typical_duration(sig)
            succ = self.likely_successor(sig)
            if dur is not None and succ is not None and run + 1 > dur:
                sig, run = succ, 1
            else:
                run += 1
        return sig

    def update(self) -> Optional[PhaseShift]:
        if self.trace.epochs_recorded == 0:
            return None
        epoch_id, bucket = self.trace.buckets(1)[0]
        if epoch_id == self._last_seen_epoch:
            return None                      # nothing new completed
        self._last_seen_epoch = epoch_id
        self._observe_signature(epoch_id, bucket)
        vec = self.trace.epoch_vector(bucket)
        label = classify_traffic(bucket)
        shift: Optional[PhaseShift] = None
        if self._prev_vec is not None:
            d = traffic_distance(self._prev_vec, vec)
            moved = d > self.threshold or (label != self.label
                                           and label != "idle")
            if moved and self._epochs_in_phase >= self.min_phase_epochs:
                shift = PhaseShift(epoch_id, d, self.label, label)
                self.shifts.append(shift)
                self.phase_id += 1
                self._epochs_in_phase = 0
        elif label != "idle":
            self.label = label
        if shift is not None:
            self.label = label
        self._prev_vec = vec
        self._epochs_in_phase += 1
        return shift

    @property
    def epochs_in_phase(self) -> int:
        return self._epochs_in_phase

    @property
    def epochs_in_signature(self) -> int:
        """Run length of the current recurrence signature."""
        return self._sig_run
