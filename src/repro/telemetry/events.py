"""Access-event recording: the shared profiling substrate.

The paper derives placement from *application semantics*; its §VI study
(and "Dissecting CXL Memory Performance at Scale") shows production
placement must instead follow **observed** access heat.  This module is
the observation side: emitters (the serving KV pool, the offload
engines, benchmark workloads) record per-object access events, bucketed
into *epochs* (one scheduler iteration / train step / benchmark step),
and consumers (phase detection, the adaptive replanner) read aggregated
per-object traffic back out as ``core.objects.DataObject`` inventories.

The trace is a ring buffer of epoch buckets: memory stays bounded on a
production run, and old epochs age out exactly like a PEBS/hint-fault
history would.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, Iterable, List, Mapping, Optional, Tuple

from ..core.objects import DataObject


@dataclasses.dataclass(frozen=True)
class AccessEvent:
    """One recorded access aggregate against a named object."""

    obj: str
    read_bytes: int = 0
    write_bytes: int = 0
    random_fraction: float = 0.0
    phase: str = ""            # emitter tag: "prefill" / "decode" / ...
    block: Optional[int] = None

    @property
    def total_bytes(self) -> int:
        return self.read_bytes + self.write_bytes


@dataclasses.dataclass
class ObjectTraffic:
    """Aggregated traffic for one object over one or more epochs."""

    read_bytes: int = 0
    write_bytes: int = 0
    random_bytes: float = 0.0  # random-weighted bytes (rf * total)
    events: int = 0
    epochs: int = 1

    @property
    def total_bytes(self) -> int:
        return self.read_bytes + self.write_bytes

    @property
    def random_fraction(self) -> float:
        return self.random_bytes / max(self.total_bytes, 1)

    @property
    def read_bytes_per_epoch(self) -> float:
        return self.read_bytes / max(self.epochs, 1)

    @property
    def write_bytes_per_epoch(self) -> float:
        return self.write_bytes / max(self.epochs, 1)

    def add(self, ev: AccessEvent) -> None:
        self.read_bytes += ev.read_bytes
        self.write_bytes += ev.write_bytes
        self.random_bytes += ev.random_fraction * ev.total_bytes
        self.events += 1

    def merge(self, other: "ObjectTraffic") -> None:
        self.read_bytes += other.read_bytes
        self.write_bytes += other.write_bytes
        self.random_bytes += other.random_bytes
        self.events += other.events


EpochBucket = Dict[str, ObjectTraffic]


class AccessTrace:
    """Ring-buffered, epoch-bucketed access recorder.

    ``record`` adds an event to the *current* (open) epoch;
    ``advance_epoch`` closes it and pushes it into the ring (capacity
    ``capacity_epochs`` — the oldest bucket is dropped when full, and
    ``dropped_epochs`` counts the loss so consumers can tell a short
    history from a truncated one).
    """

    def __init__(self, capacity_epochs: int = 256):
        if capacity_epochs <= 0:
            raise ValueError("capacity_epochs must be positive")
        self.capacity_epochs = capacity_epochs
        self._ring: Deque[Tuple[int, EpochBucket]] = deque(
            maxlen=capacity_epochs)
        self._current: EpochBucket = {}
        self.epoch = 0             # id of the open epoch
        self.total_events = 0
        self.dropped_epochs = 0
        self.phase_events: Dict[str, int] = {}

    # ------------------------------------------------------------------ #
    # recording                                                          #
    # ------------------------------------------------------------------ #
    def record(self, obj: str, read_bytes: int = 0, write_bytes: int = 0,
               random_fraction: float = 0.0, phase: str = "",
               block: Optional[int] = None) -> None:
        ev = AccessEvent(obj, int(read_bytes), int(write_bytes),
                         float(random_fraction), phase, block)
        if ev.total_bytes <= 0:
            return
        self._current.setdefault(obj, ObjectTraffic()).add(ev)
        self.total_events += 1
        if phase:
            self.phase_events[phase] = self.phase_events.get(phase, 0) + 1

    # the emitter-facing alias shared with AccessSampler, so a pool or
    # engine can be handed either a raw trace or a sampling front-end
    observe = record

    def forget(self, obj: str) -> None:
        """Retire an object (interface shared with AccessSampler).

        History already in the ring stays — it is bounded and still
        describes past epochs — but the open bucket drops the object so
        a retired sequence cannot appear in the epoch that closes after
        its teardown."""
        self._current.pop(obj, None)

    def advance_epoch(self) -> int:
        """Close the current epoch; returns the id of the new open epoch."""
        if len(self._ring) == self._ring.maxlen:
            self.dropped_epochs += 1
        self._ring.append((self.epoch, self._current))
        self._current = {}
        self.epoch += 1
        return self.epoch

    # ------------------------------------------------------------------ #
    # reading                                                            #
    # ------------------------------------------------------------------ #
    @property
    def epochs_recorded(self) -> int:
        """Completed epochs still in the ring."""
        return len(self._ring)

    def buckets(self, window: Optional[int] = None
                ) -> List[Tuple[int, EpochBucket]]:
        """The last `window` completed epoch buckets (all if None)."""
        items = list(self._ring)
        if window is not None:
            items = items[-window:]
        return items

    def last_completed(self) -> Optional[EpochBucket]:
        return self._ring[-1][1] if self._ring else None

    def object_traffic(self, window: Optional[int] = None
                       ) -> Dict[str, ObjectTraffic]:
        """Per-object traffic aggregated over the window, with ``epochs``
        set so the per-epoch means divide correctly."""
        buckets = self.buckets(window)
        out: Dict[str, ObjectTraffic] = {}
        for _, bucket in buckets:
            for obj, t in bucket.items():
                agg = out.setdefault(obj, ObjectTraffic(epochs=0))
                agg.merge(t)
        n = max(len(buckets), 1)
        for agg in out.values():
            agg.epochs = n
        return out

    def epoch_vector(self, bucket: Optional[EpochBucket] = None
                     ) -> Dict[str, float]:
        """Normalized per-object byte shares of one epoch (for phase
        detection: request-mix / working-set drift shows up here)."""
        if bucket is None:
            bucket = self.last_completed() or {}
        total = sum(t.total_bytes for t in bucket.values())
        if total <= 0:
            return {}
        return {obj: t.total_bytes / total for obj, t in bucket.items()}

    # ------------------------------------------------------------------ #
    # bridge to the analytic layer                                       #
    # ------------------------------------------------------------------ #
    def to_data_objects(self, nbytes: Mapping[str, int],
                        window: Optional[int] = None,
                        pin_fast: Iterable[str] = (),
                        groups: Optional[Mapping[str, str]] = None,
                        group: str = "observed") -> List[DataObject]:
        """Rebuild DataObjects from *measured* traffic.

        ``nbytes`` names the placeable objects and their footprints (the
        trace only knows traffic); objects without observed traffic come
        back with zero per-step bytes — the planner treats them as cold.
        """
        traffic = self.object_traffic(window)
        pins = set(pin_fast)
        objs: List[DataObject] = []
        for name in nbytes:
            t = traffic.get(name)
            objs.append(DataObject(
                name=name, nbytes=int(nbytes[name]),
                read_bytes_per_step=int(t.read_bytes_per_epoch) if t else 0,
                write_bytes_per_step=int(t.write_bytes_per_epoch) if t
                else 0,
                random_fraction=t.random_fraction if t else 0.0,
                pin_fast=name in pins,
                group=(groups or {}).get(name, group)))
        return objs
